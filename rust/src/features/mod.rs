//! Derived TDA feature products served per query (ROADMAP item 4).
//!
//! One persistent-homology run is expensive; the products downstream
//! consumers actually read — Betti curves, persistence entropy,
//! landscapes, persistence images, representative loops — are cheap
//! pure functions of the finished diagram (+ the served filtration view
//! for representatives). This module computes them post-reduction
//! inside [`crate::homology::Session::query`], so N feature products
//! ride on one reduction and one ingest.
//!
//! **Determinism.** Every kernel is a pure function of the diagram and
//! the served `(EdgeFiltration, Neighborhoods)` view: diagram points
//! are gathered into a canonical `(birth, death)` order
//! ([`clamped_sorted`]) before any float accumulation, the image kernel
//! accumulates its Gaussian terms in that fixed point order per pixel,
//! and the pooled image path writes disjoint row bands with identical
//! per-pixel arithmetic — so every feature is bit-identical across
//! thread counts, steal schedules, batch sizes, and cached-handle vs
//! fresh-ingest queries (pinned by `rust/tests/features.rs`).
//!
//! **Essential classes.** Deaths of `+∞` would poison every finite
//! kernel (NaN/∞ bins). The pinned semantics: entropy, landscapes and
//! images clamp essential deaths to the feature *span* — the query's
//! `tau_effective` when finite, else the last (largest) edge value of
//! the served filtration — and report how many points were clamped in
//! [`FeatureStats::clamped_points`]. Betti curves need no clamp: they
//! count classes alive at each sample, and an essential class is simply
//! alive at every sample past its birth.

pub mod betti;
pub mod cycles;
pub mod entropy;
pub mod image;
pub mod landscape;

pub use cycles::CycleFeature;

use crate::error::DoryError;
use crate::filtration::{EdgeFiltration, Neighborhoods};
use crate::homology::{Diagram, PhResult};
use crate::reduction::pool::ThreadPool;
use crate::util::json::Json;

pub const DEFAULT_BETTI_GRID: usize = 64;
pub const DEFAULT_LANDSCAPE_LEVELS: usize = 5;
pub const DEFAULT_LANDSCAPE_GRID: usize = 64;
pub const DEFAULT_IMAGE_GRID: usize = 32;
/// Largest accepted sampling grid (an image allocates `grid²` f64s).
pub const MAX_GRID: usize = 1024;
/// Largest accepted landscape level count.
pub const MAX_LEVELS: usize = 64;

/// One typed feature request, plumbed end to end: `PhRequest.features`,
/// the coordinator's `[[query]] features = [...]`, the CLI `--features`
/// list, and the serve wire's `{"features":[…]}` field all parse into
/// this enum, so every layer agrees on the knob set and its defaults.
#[derive(Clone, Debug, PartialEq)]
pub enum FeatureSpec {
    /// Betti curve sampled at `grid + 1` points over `[0, span]`.
    BettiCurve { grid: usize },
    /// Persistence entropy `-Σ pᵢ ln pᵢ`, `pᵢ = persᵢ / Σ pers`.
    Entropy,
    /// First `levels` persistence landscapes, each sampled at
    /// `grid + 1` points over `[0, span]`.
    Landscape { levels: usize, grid: usize },
    /// Persistence image: `grid × grid` Gaussian raster over
    /// `[0, span]²` in (birth, persistence) coordinates, matching
    /// `python/compile/kernels/persistence_image.py`.
    Image { grid: usize },
    /// H1 representative loops with persistence above
    /// `min_persistence`, geometrically tightened (Aggarwal–Periwal).
    Representatives { min_persistence: f64 },
}

impl FeatureSpec {
    /// Parse one spec string: `betti[:GRID]`, `entropy`,
    /// `landscape[:LEVELS[:GRID]]`, `image[:GRID]`,
    /// `representatives[:MIN_PERSISTENCE]`.
    pub fn parse(s: &str) -> Result<FeatureSpec, String> {
        let mut parts = s.trim().split(':');
        let head = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let usize_arg = |v: &str| -> Result<usize, String> {
            v.parse::<usize>()
                .map_err(|_| format!("bad integer '{v}' in feature spec '{s}'"))
        };
        let spec = match head {
            "betti" => FeatureSpec::BettiCurve {
                grid: match args.as_slice() {
                    [] => DEFAULT_BETTI_GRID,
                    [g] => usize_arg(g)?,
                    _ => return Err(format!("betti takes at most one arg: '{s}'")),
                },
            },
            "entropy" => {
                if !args.is_empty() {
                    return Err(format!("entropy takes no args: '{s}'"));
                }
                FeatureSpec::Entropy
            }
            "landscape" => {
                let (levels, grid) = match args.as_slice() {
                    [] => (DEFAULT_LANDSCAPE_LEVELS, DEFAULT_LANDSCAPE_GRID),
                    [k] => (usize_arg(k)?, DEFAULT_LANDSCAPE_GRID),
                    [k, g] => (usize_arg(k)?, usize_arg(g)?),
                    _ => return Err(format!("landscape takes at most two args: '{s}'")),
                };
                FeatureSpec::Landscape { levels, grid }
            }
            "image" => FeatureSpec::Image {
                grid: match args.as_slice() {
                    [] => DEFAULT_IMAGE_GRID,
                    [g] => usize_arg(g)?,
                    _ => return Err(format!("image takes at most one arg: '{s}'")),
                },
            },
            "representatives" => FeatureSpec::Representatives {
                min_persistence: match args.as_slice() {
                    [] => 0.0,
                    [m] => m
                        .parse::<f64>()
                        .map_err(|_| format!("bad number '{m}' in feature spec '{s}'"))?,
                    _ => return Err(format!("representatives takes at most one arg: '{s}'")),
                },
            },
            _ => {
                return Err(format!(
                    "unknown feature '{head}' (expected betti, entropy, landscape, \
                     image, or representatives)"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a comma-separated spec list (the CLI `--features` form).
    pub fn parse_list(s: &str) -> Result<Vec<FeatureSpec>, String> {
        s.split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(FeatureSpec::parse)
            .collect()
    }

    /// Canonical spec string, echoed into responses so clients can match
    /// outputs back to requests.
    pub fn name(&self) -> String {
        match self {
            FeatureSpec::BettiCurve { grid } => format!("betti:{grid}"),
            FeatureSpec::Entropy => "entropy".into(),
            FeatureSpec::Landscape { levels, grid } => format!("landscape:{levels}:{grid}"),
            FeatureSpec::Image { grid } => format!("image:{grid}"),
            FeatureSpec::Representatives { min_persistence } => {
                format!("representatives:{min_persistence}")
            }
        }
    }

    /// Range checks, also applied to specs constructed directly through
    /// the API (not just the parsers).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            FeatureSpec::BettiCurve { grid }
            | FeatureSpec::Landscape { grid, .. }
            | FeatureSpec::Image { grid }
                if grid == 0 || grid > MAX_GRID =>
            {
                Err(format!(
                    "feature grid must be in 1..={MAX_GRID}, got {grid}"
                ))
            }
            FeatureSpec::Landscape { levels, .. } if levels == 0 || levels > MAX_LEVELS => Err(
                format!("landscape levels must be in 1..={MAX_LEVELS}, got {levels}"),
            ),
            FeatureSpec::Representatives { min_persistence }
                if min_persistence.is_nan() || min_persistence < 0.0 =>
            {
                Err(format!(
                    "representatives min_persistence must be >= 0, got {min_persistence}"
                ))
            }
            _ => Ok(()),
        }
    }
}

/// Aggregate accounting of one feature computation (per response; the
/// coordinator and serve summaries merge them across queries).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeatureStats {
    /// Feature specs computed.
    pub specs: u64,
    /// Diagram points consumed across dims and specs.
    pub diagram_points: u64,
    /// Essential (death = ∞) points whose death was clamped to the
    /// feature span by a finite-valued kernel.
    pub clamped_points: u64,
    /// Representative loops emitted.
    pub cycles: u64,
    /// Wall time of the whole feature pass, nanoseconds.
    pub feature_ns: u64,
}

impl FeatureStats {
    pub fn merge(&mut self, other: &FeatureStats) {
        self.specs += other.specs;
        self.diagram_points += other.diagram_points;
        self.clamped_points += other.clamped_points;
        self.cycles += other.cycles;
        self.feature_ns += other.feature_ns;
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("specs", self.specs)
            .field("diagram_points", self.diagram_points)
            .field("clamped_points", self.clamped_points)
            .field("cycles", self.cycles)
            .field("feature_ns", self.feature_ns)
    }
}

/// One computed feature: the spec echo plus its per-dimension payload.
#[derive(Clone, Debug)]
pub struct FeatureOutput {
    pub spec: FeatureSpec,
    pub value: FeatureValue,
}

/// Feature payloads. Vectorized kernels hold one entry per homology
/// dimension `0..=max_dim` of the served diagram; representatives are
/// H1-only (the paper's loop-calling scenario).
#[derive(Clone, Debug)]
pub enum FeatureValue {
    /// `[dim][sample]` class counts at `t_i = span·i/grid`.
    BettiCurve(Vec<Vec<u64>>),
    /// `[dim]` persistence entropy.
    Entropy(Vec<f64>),
    /// `[dim][level][sample]` landscape values.
    Landscape(Vec<Vec<Vec<f64>>>),
    /// `[dim][row·grid + col]` image rasters, row = persistence axis.
    Image(Vec<Vec<f64>>),
    /// H1 representative loops.
    Representatives(Vec<CycleFeature>),
}

/// All features of one response plus their accounting.
#[derive(Clone, Debug)]
pub struct FeatureOutputs {
    /// The sampling domain `[0, span]` every grid kernel used.
    pub span: f64,
    pub items: Vec<FeatureOutput>,
    pub stats: FeatureStats,
}

impl FeatureOutputs {
    /// Wire/summary form: `[{"spec":…, "dims":…}, …]` (stats are
    /// rendered separately via [`FeatureStats::to_json`]).
    pub fn to_json(&self) -> Json {
        let mut arr = Json::arr();
        for item in &self.items {
            let mut j = Json::obj().field("spec", item.spec.name());
            match &item.value {
                FeatureValue::BettiCurve(dims) => {
                    let mut dj = Json::arr();
                    for d in dims {
                        let mut row = Json::arr();
                        for &v in d {
                            row.push(v);
                        }
                        dj.push(row);
                    }
                    j = j.field("dims", dj);
                }
                FeatureValue::Entropy(dims) => {
                    let mut dj = Json::arr();
                    for &v in dims {
                        dj.push(v);
                    }
                    j = j.field("dims", dj);
                }
                FeatureValue::Landscape(dims) => {
                    let mut dj = Json::arr();
                    for levels in dims {
                        let mut lj = Json::arr();
                        for level in levels {
                            let mut row = Json::arr();
                            for &v in level {
                                row.push(v);
                            }
                            lj.push(row);
                        }
                        dj.push(lj);
                    }
                    j = j.field("dims", dj);
                }
                FeatureValue::Image(dims) => {
                    let mut dj = Json::arr();
                    for img in dims {
                        let mut row = Json::arr();
                        for &v in img {
                            row.push(v);
                        }
                        dj.push(row);
                    }
                    j = j.field("dims", dj);
                }
                FeatureValue::Representatives(cycles) => {
                    let mut cj = Json::arr();
                    for c in cycles {
                        cj.push(c.to_json());
                    }
                    j = j.field("cycles", cj);
                }
            }
            arr.push(j);
        }
        Json::obj().field("span", self.span).field("items", arr)
    }
}

/// The sampling span of every grid kernel: the query's `tau_effective`
/// when finite, else the largest edge value of the served filtration
/// (the last of the sorted value array), else 0 (empty filtration — all
/// kernels degenerate gracefully; the image's `+1e-30` regularizer
/// keeps even the zero-span Gaussian finite).
pub fn feature_span(tau_effective: f64, f: &EdgeFiltration) -> f64 {
    if tau_effective.is_finite() {
        tau_effective
    } else {
        f.values.last().copied().unwrap_or(0.0)
    }
}

/// Gather dimension `dim`'s points as `(birth, death·clamped·to·span)`
/// in canonical `(birth, death)` order — the fixed accumulation order
/// that makes every downstream float kernel permutation-invariant at
/// the bit level. Returns the points and how many were clamped.
pub fn clamped_sorted(diagram: &Diagram, dim: usize, span: f64) -> (Vec<(f64, f64)>, u64) {
    let mut clamped = 0u64;
    let mut pts: Vec<(f64, f64)> = diagram
        .points(dim)
        .iter()
        .map(|p| {
            if p.death > span {
                clamped += 1;
                (p.birth, span)
            } else {
                (p.birth, p.death)
            }
        })
        .collect();
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    (pts, clamped)
}

/// Compute `specs` against a finished result and the filtration view it
/// was served from. `f`/`nb` must be the *served cut* (the truncated
/// prefix view for sub-τ queries), so representative edge orders line
/// up with `result.h1_pairs`. `pool` accelerates the image raster;
/// output is bit-identical with or without it.
pub fn compute(
    specs: &[FeatureSpec],
    result: &PhResult,
    f: &EdgeFiltration,
    nb: &Neighborhoods,
    tau_effective: f64,
    pool: Option<&ThreadPool>,
) -> Result<FeatureOutputs, DoryError> {
    let t0 = std::time::Instant::now();
    for spec in specs {
        spec.validate().map_err(DoryError::Request)?;
    }
    let diagram = &result.diagram;
    let span = feature_span(tau_effective, f);
    let ndims = diagram.max_dim() + 1;
    let mut stats = FeatureStats::default();
    let mut items = Vec::with_capacity(specs.len());
    for spec in specs {
        stats.specs += 1;
        let value = match *spec {
            FeatureSpec::BettiCurve { grid } => {
                let mut dims = Vec::with_capacity(ndims);
                for dim in 0..ndims {
                    stats.diagram_points += diagram.points(dim).len() as u64;
                    dims.push(betti::curve(diagram, dim, grid, span));
                }
                FeatureValue::BettiCurve(dims)
            }
            FeatureSpec::Entropy => {
                let mut dims = Vec::with_capacity(ndims);
                for dim in 0..ndims {
                    let (pts, cl) = clamped_sorted(diagram, dim, span);
                    stats.diagram_points += pts.len() as u64;
                    stats.clamped_points += cl;
                    dims.push(entropy::entropy(&pts));
                }
                FeatureValue::Entropy(dims)
            }
            FeatureSpec::Landscape { levels, grid } => {
                let mut dims = Vec::with_capacity(ndims);
                for dim in 0..ndims {
                    let (pts, cl) = clamped_sorted(diagram, dim, span);
                    stats.diagram_points += pts.len() as u64;
                    stats.clamped_points += cl;
                    dims.push(landscape::landscape(&pts, levels, grid, span));
                }
                FeatureValue::Landscape(dims)
            }
            FeatureSpec::Image { grid } => {
                let mut dims = Vec::with_capacity(ndims);
                for dim in 0..ndims {
                    let (pts, cl) = clamped_sorted(diagram, dim, span);
                    stats.diagram_points += pts.len() as u64;
                    stats.clamped_points += cl;
                    dims.push(image::image(&pts, grid, span, pool));
                }
                FeatureValue::Image(dims)
            }
            FeatureSpec::Representatives { min_persistence } => {
                let cycles = cycles::representatives(nb, f, result, min_persistence)?;
                stats.cycles += cycles.len() as u64;
                stats.diagram_points += cycles.len() as u64;
                FeatureValue::Representatives(cycles)
            }
        };
        items.push(FeatureOutput {
            spec: spec.clone(),
            value,
        });
    }
    stats.feature_ns = t0.elapsed().as_nanos() as u64;
    Ok(FeatureOutputs { span, items, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_strings_round_trip() {
        for s in [
            "betti:8",
            "entropy",
            "landscape:3:16",
            "image:32",
            "representatives:0.5",
        ] {
            let spec = FeatureSpec::parse(s).unwrap();
            assert_eq!(FeatureSpec::parse(&spec.name()).unwrap(), spec, "{s}");
        }
        // Defaults fill in.
        assert_eq!(
            FeatureSpec::parse("betti").unwrap(),
            FeatureSpec::BettiCurve {
                grid: DEFAULT_BETTI_GRID
            }
        );
        assert_eq!(
            FeatureSpec::parse("landscape:7").unwrap(),
            FeatureSpec::Landscape {
                levels: 7,
                grid: DEFAULT_LANDSCAPE_GRID
            }
        );
        assert_eq!(
            FeatureSpec::parse("representatives").unwrap(),
            FeatureSpec::Representatives {
                min_persistence: 0.0
            }
        );
    }

    #[test]
    fn bad_specs_are_refused() {
        for s in [
            "bogus",
            "betti:0",
            "betti:9999",
            "betti:1:2",
            "entropy:3",
            "landscape:0",
            "landscape:3:0",
            "image:nan",
            "representatives:-1",
            "representatives:nan",
            "",
        ] {
            assert!(FeatureSpec::parse(s).is_err(), "{s:?} must be refused");
        }
        assert!(FeatureSpec::Image { grid: 0 }.validate().is_err());
        assert!(FeatureSpec::Landscape { levels: 0, grid: 8 }.validate().is_err());
    }

    #[test]
    fn parse_list_splits_and_trims() {
        let specs = FeatureSpec::parse_list("betti:8, entropy ,image").unwrap();
        assert_eq!(specs.len(), 3);
        assert!(FeatureSpec::parse_list("betti,,bogus").is_err());
        assert!(FeatureSpec::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn clamped_sorted_clamps_and_orders() {
        let mut d = Diagram::new(1);
        d.push(1, 0.5, f64::INFINITY);
        d.push(1, 0.1, 0.9);
        d.push(1, 0.1, 0.4);
        let (pts, clamped) = clamped_sorted(&d, 1, 1.0);
        assert_eq!(clamped, 1);
        assert_eq!(pts, vec![(0.1, 0.4), (0.1, 0.9), (0.5, 1.0)]);
        // No NaN/∞ survives the clamp.
        assert!(pts.iter().all(|&(b, dd)| b.is_finite() && dd.is_finite()));
    }
}
