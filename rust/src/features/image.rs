//! Persistence images (Adams et al.): a Gaussian-weighted raster of the
//! diagram in (birth, persistence) coordinates, matching the Pallas
//! reference kernel `python/compile/kernels/persistence_image.py`
//! (same σ fraction, `1e-30` regularizer, half-cell pixel centers,
//! persistence-weighted points) in f64.
//!
//! Layout: `out[row·grid + col]`, columns = birth axis, rows =
//! persistence axis, pixel centers at `(idx + 0.5)·cell` with
//! `cell = span/grid` — exactly the reference kernel's tiling.
//!
//! **Pooled row-band tiling.** The raster is embarrassingly parallel
//! across rows: [`pooled`] deals row bands onto the engine's
//! work-stealing pool through disjoint
//! [`SharedSlice`](crate::reduction::pool::SharedSlice) windows while
//! every pixel still accumulates its Gaussian terms sequentially in the
//! canonical point order — so the pooled raster is **bit-identical** to
//! [`serial`] for every thread count and steal schedule (hard-asserted
//! in `rust/benches/micro_hotpaths.rs` alongside the speedup gate).

use std::ops::Range;

use crate::reduction::pool::{SharedSlice, ThreadPool};

/// Gaussian bandwidth as a fraction of the span (reference kernel's
/// `SIGMA_FRAC`).
pub const SIGMA_FRAC: f64 = 0.05;

#[inline]
fn params(span: f64, grid: usize) -> (f64, f64) {
    let sigma = SIGMA_FRAC * span;
    // The 1e-30 regularizer (from the reference kernel) keeps the
    // exponent finite even at span 0: exp(-x·∞) never appears.
    let inv2s2 = 1.0 / (2.0 * sigma * sigma + 1e-30);
    let cell = span / grid as f64;
    (inv2s2, cell)
}

/// Rasterize `rows` into `out` (`out[0]` is row `rows.start`'s first
/// pixel). Every pixel sums `pers·exp(-(dx² + dy²)·inv2s2)` over the
/// points in their given (canonical) order — the one accumulation order
/// both the serial and pooled paths share.
fn fill_rows(
    points: &[(f64, f64)],
    grid: usize,
    inv2s2: f64,
    cell: f64,
    rows: Range<usize>,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), rows.len() * grid);
    for (ri, r) in rows.enumerate() {
        let y = (r as f64 + 0.5) * cell;
        let row = &mut out[ri * grid..(ri + 1) * grid];
        for (c, slot) in row.iter_mut().enumerate() {
            let x = (c as f64 + 0.5) * cell;
            let mut acc = 0.0f64;
            for &(b, d) in points {
                let pers = d - b;
                let dx = x - b;
                let dy = y - pers;
                acc += pers * (-(dx * dx + dy * dy) * inv2s2).exp();
            }
            *slot = acc;
        }
    }
}

/// Serial raster: `grid × grid` row-major image over `[0, span]²`.
pub fn serial(points: &[(f64, f64)], grid: usize, span: f64) -> Vec<f64> {
    let (inv2s2, cell) = params(span, grid);
    let mut out = vec![0.0f64; grid * grid];
    fill_rows(points, grid, inv2s2, cell, 0..grid, &mut out);
    out
}

/// Pooled raster: row bands dealt onto the work-stealing pool, each
/// task writing its own disjoint window of the output. Bit-identical to
/// [`serial`] — the per-pixel arithmetic and point order are the same;
/// only *which worker* computes a row varies.
pub fn pooled(points: &[(f64, f64)], grid: usize, span: f64, pool: &ThreadPool) -> Vec<f64> {
    let (inv2s2, cell) = params(span, grid);
    let mut out = vec![0.0f64; grid * grid];
    let shared = SharedSlice::new(&mut out);
    pool.run_stealing(grid, 1, |_tid, rows: Range<usize>| {
        // SAFETY: row ranges from one generation are pairwise disjoint,
        // so the `rows.start*grid..rows.end*grid` windows never overlap,
        // and `out` is not read until `run_stealing` returns.
        let dst = unsafe { shared.slice_mut(rows.start * grid..rows.end * grid) };
        fill_rows(points, grid, inv2s2, cell, rows, dst);
    });
    out
}

/// Dispatch: pooled when the engine has a pool, serial otherwise.
pub fn image(points: &[(f64, f64)], grid: usize, span: f64, pool: Option<&ThreadPool>) -> Vec<f64> {
    match pool {
        Some(p) => pooled(points, grid, span, p),
        None => serial(points, grid, span),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<(f64, f64)> {
        vec![(0.1, 0.9), (0.2, 0.4), (0.5, 1.3), (0.05, 1.45)]
    }

    #[test]
    fn pooled_is_bit_identical_to_serial() {
        let points = pts();
        let s = serial(&points, 16, 1.5);
        for threads in [2usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let p = pooled(&points, 16, 1.5, &pool);
            assert_eq!(
                s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn mass_sits_near_the_point() {
        // One persistent point: the hottest pixel is at its location.
        let points = vec![(0.25, 1.0)]; // birth 0.25, persistence 0.75
        let grid = 8;
        let img = serial(&points, grid, 1.0);
        let (mut best, mut best_v) = (0usize, f64::MIN);
        for (i, &v) in img.iter().enumerate() {
            if v > best_v {
                best = i;
                best_v = v;
            }
        }
        let (row, col) = (best / grid, best % grid);
        // birth 0.25 → col 2 (center 0.3125 closest of the 1/8 cells);
        // persistence 0.75 → row 5 or 6 (centers 0.6875 / 0.8125).
        assert_eq!(col, 2, "img={img:?}");
        assert!(row == 5 || row == 6, "row={row}");
        assert!(img.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn degenerate_spans_stay_finite() {
        // Zero span / empty diagram: all-zero (or finite) raster, never
        // NaN — the regularizer keeps the Gaussian defined.
        assert!(serial(&[], 4, 1.0).iter().all(|&v| v == 0.0));
        assert!(serial(&[(0.0, 0.0)], 4, 0.0).iter().all(|v| v.is_finite()));
    }
}
