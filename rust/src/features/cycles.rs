//! H1 cycle representatives as a served feature product — the paper's
//! §7 extension ("representative boundaries of the holes"), the Hi-C
//! loop-calling consumer's payload.
//!
//! The heavy lifting lives in [`crate::homology::representatives`]: a
//! geodesically tightened loop per H1 class (Dijkstra at birth over the
//! served filtration view — Aggarwal–Periwal's tight-representative
//! refinement of the hop-BFS loop). This module adapts those loops into
//! wire-ready [`CycleFeature`]s: anchor pair (the birth edge's
//! endpoints — for Hi-C, the loop's two genomic anchors), persistence,
//! and the total geometric perimeter, computed through the *total*
//! [`Cycle::perimeter`](crate::homology::representatives::Cycle::perimeter)
//! (a cycle edge missing from the truncated `Neighborhoods` view is a
//! typed [`DoryError::Feature`] — never a silent NaN).

use crate::error::DoryError;
use crate::filtration::{EdgeFiltration, Neighborhoods};
use crate::homology::representatives::tight_representatives_from_result;
use crate::homology::PhResult;
use crate::util::json::Json;

/// One representative loop, wire-ready.
#[derive(Clone, Debug)]
pub struct CycleFeature {
    /// Birth value of the H1 class.
    pub birth: f64,
    /// Death value (`+∞` for essential classes; rendered `1e999`).
    pub death: f64,
    /// Total geometric length of the loop under the filtration metric.
    pub perimeter: f64,
    /// The birth edge's endpoints — the loop's anchor pair.
    pub anchor: (u32, u32),
    /// The loop's vertices in cycle order (closed implicitly).
    pub vertices: Vec<u32>,
}

impl CycleFeature {
    pub fn persistence(&self) -> f64 {
        self.death - self.birth
    }

    pub fn to_json(&self) -> Json {
        let mut vs = Json::arr();
        for &v in &self.vertices {
            vs.push(v);
        }
        let mut anchor = Json::arr();
        anchor.push(self.anchor.0);
        anchor.push(self.anchor.1);
        Json::obj()
            .field("birth", self.birth)
            .field("death", self.death)
            .field("persistence", self.persistence())
            .field("perimeter", self.perimeter)
            .field("anchor", anchor)
            .field("vertices", vs)
    }
}

/// Representative loops for every H1 class of `result` with persistence
/// above `min_persistence` (essential classes always qualify), in a
/// canonical `(birth, death, anchor)` order so the list is identical
/// for every schedule. `nb`/`f` must be the served filtration view the
/// result was reduced from — `result.h1_pairs` edge orders index it.
pub fn representatives(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    result: &PhResult,
    min_persistence: f64,
) -> Result<Vec<CycleFeature>, DoryError> {
    if min_persistence.is_nan() || min_persistence < 0.0 {
        return Err(DoryError::Request(format!(
            "representatives min_persistence must be >= 0, got {min_persistence}"
        )));
    }
    let mut out = Vec::new();
    for c in tight_representatives_from_result(nb, f, result, min_persistence) {
        let perimeter = c.perimeter(nb, f)?;
        // The tightening path runs a→b for birth edge {a, b}: the
        // cycle's first and last vertices are exactly the anchors.
        let anchor = (
            *c.vertices.first().expect("representatives are non-empty"),
            *c.vertices.last().expect("representatives are non-empty"),
        );
        out.push(CycleFeature {
            birth: c.birth,
            death: c.death,
            perimeter,
            anchor,
            vertices: c.vertices,
        });
    }
    out.sort_by(|a, b| {
        a.birth
            .total_cmp(&b.birth)
            .then(a.death.total_cmp(&b.death))
            .then(a.anchor.cmp(&b.anchor))
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::geometry::MetricData;
    use crate::homology::{EngineOptions, PhRequest, Session};

    #[test]
    fn circle_loop_feature_is_complete_and_typed() {
        let data = datasets::circle(40, 1.0, 0.0, 1);
        let s = Session::new(EngineOptions {
            max_dim: 1,
            threads: 1,
            ..Default::default()
        });
        let h = s.ingest(&data, 3.0).unwrap();
        let resp = s.query(&h, &PhRequest::at(3.0)).unwrap();
        let cycles =
            representatives(h.neighborhoods(), h.filtration(), &resp.result, 0.5).unwrap();
        assert_eq!(cycles.len(), 1);
        let c = &cycles[0];
        assert!(c.perimeter.is_finite() && c.perimeter > 4.0, "{}", c.perimeter);
        assert!(c.vertices.len() >= 3);
        assert_eq!(c.anchor.0, *c.vertices.first().unwrap());
        assert_eq!(c.anchor.1, *c.vertices.last().unwrap());
        // The JSON form carries every field.
        let j = c.to_json().render();
        for key in ["birth", "death", "persistence", "perimeter", "anchor", "vertices"] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn nan_min_persistence_refused() {
        let data = datasets::circle(16, 1.0, 0.0, 1);
        let s = Session::new(EngineOptions {
            max_dim: 1,
            threads: 1,
            ..Default::default()
        });
        let h = s.ingest(&data, 3.0).unwrap();
        let resp = s.query(&h, &PhRequest::at(3.0)).unwrap();
        assert!(matches!(
            representatives(h.neighborhoods(), h.filtration(), &resp.result, f64::NAN),
            Err(DoryError::Request(_))
        ));
    }

    #[test]
    fn emptiness_when_nothing_qualifies() {
        let data = MetricData::Points(crate::geometry::PointCloud::new(
            1,
            vec![0.0, 1.0, 2.0, 3.0],
        ));
        let s = Session::new(EngineOptions {
            max_dim: 1,
            threads: 1,
            ..Default::default()
        });
        let h = s.ingest(&data, 10.0).unwrap();
        let resp = s.query(&h, &PhRequest::at(10.0)).unwrap();
        assert!(representatives(h.neighborhoods(), h.filtration(), &resp.result, 0.0)
            .unwrap()
            .is_empty());
    }
}
