//! Betti curves: the diagram's class count sampled on a uniform grid.
//!
//! The step function `β_dim(t) = #{classes with birth ≤ t < death}` is
//! sampled at `t_i = span·i/grid` for `i = 0..=grid` — exactly
//! [`Diagram::betti_at`]'s semantics at every sample, so the curve is a
//! pure integer summary with zero float accumulation: no clamping is
//! needed (an essential class is alive at every sample past its birth)
//! and cross-thread bit-identity is trivial.

use crate::homology::Diagram;

/// Sample dimension `dim`'s Betti curve at `grid + 1` uniform points
/// over `[0, span]`.
pub fn curve(diagram: &Diagram, dim: usize, grid: usize, span: f64) -> Vec<u64> {
    (0..=grid)
        .map(|i| {
            let t = span * i as f64 / grid as f64;
            diagram.betti_at(dim, t) as u64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_betti_at_at_every_sample() {
        let mut d = Diagram::new(1);
        d.push(1, 0.2, 0.8);
        d.push(1, 0.4, f64::INFINITY);
        d.push(1, 0.0, 0.3);
        let c = curve(&d, 1, 10, 1.0);
        assert_eq!(c.len(), 11);
        for (i, &v) in c.iter().enumerate() {
            let t = 1.0 * i as f64 / 10.0;
            assert_eq!(v, d.betti_at(1, t) as u64, "t={t}");
        }
        // The essential class stays alive at the last sample.
        assert_eq!(c[10], 1);
    }

    #[test]
    fn empty_dimension_is_flat_zero() {
        let d = Diagram::new(2);
        assert!(curve(&d, 2, 4, 1.0).iter().all(|&v| v == 0));
    }
}
