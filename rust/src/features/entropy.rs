//! Persistence entropy: the Shannon entropy of the normalized
//! persistence distribution, `E = -Σ pᵢ ln pᵢ` with
//! `pᵢ = persᵢ / Σⱼ persⱼ`.
//!
//! Input is the canonically sorted, span-clamped point list from
//! [`super::clamped_sorted`]; both the total-persistence sum and the
//! entropy sum accumulate in that fixed order, so the value is
//! bit-identical no matter how the diagram enumerated its points
//! (permutation invariance is pinned by `rust/tests/features.rs`).

/// Entropy of `points` (`(birth, death)`, deaths already finite).
/// Zero-persistence points contribute nothing (`p ln p → 0`); an empty
/// or all-zero diagram has entropy 0.
pub fn entropy(points: &[(f64, f64)]) -> f64 {
    let mut total = 0.0f64;
    for &(b, d) in points {
        total += d - b;
    }
    if !(total > 0.0) {
        return 0.0;
    }
    let mut e = 0.0f64;
    for &(b, d) in points {
        let p = (d - b) / total;
        if p > 0.0 {
            e -= p * p.ln();
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distribution_maximizes() {
        // k equal bars: entropy = ln k.
        let pts: Vec<(f64, f64)> = (0..4).map(|i| (i as f64, i as f64 + 1.0)).collect();
        assert!((entropy(&pts) - 4.0f64.ln()).abs() < 1e-15);
        // One bar: entropy 0.
        assert_eq!(entropy(&[(0.0, 2.0)]), 0.0);
        // Empty: entropy 0, no NaN.
        assert_eq!(entropy(&[]), 0.0);
        assert_eq!(entropy(&[(1.0, 1.0)]), 0.0);
    }

    #[test]
    fn scale_invariant() {
        let a = entropy(&[(0.0, 1.0), (0.0, 3.0)]);
        let b = entropy(&[(0.0, 2.0), (0.0, 6.0)]);
        assert!((a - b).abs() < 1e-15);
    }
}
