//! Persistence landscapes (Bubenik): the k-th largest tent function of
//! the diagram, sampled on a uniform grid.
//!
//! Each point `(b, d)` contributes the tent
//! `Λ(t) = max(0, min(t − b, d − t))`; the k-th landscape
//! `λ_k(t)` is the k-th largest tent value at `t`. Landscapes are
//! non-negative by construction and 1-Lipschitz in `t` (every tent has
//! slope ±1), which `rust/tests/features.rs` pins as properties.
//!
//! Determinism: tents are computed over the canonically sorted point
//! list and ranked with `total_cmp` — equal tent values are
//! interchangeable, so the sampled output is bit-identical for every
//! input permutation and thread count (the kernel itself is serial; it
//! is O((grid+1)·K log K) and never the hot path).

/// First `levels` landscapes of `points` (`(birth, death)`, deaths
/// already clamped finite), each sampled at `grid + 1` uniform points
/// over `[0, span]`. Missing levels (fewer than `k` overlapping tents)
/// are 0.
pub fn landscape(
    points: &[(f64, f64)],
    levels: usize,
    grid: usize,
    span: f64,
) -> Vec<Vec<f64>> {
    let mut out = vec![vec![0.0f64; grid + 1]; levels];
    let mut tents: Vec<f64> = Vec::with_capacity(points.len());
    for i in 0..=grid {
        let t = span * i as f64 / grid as f64;
        tents.clear();
        for &(b, d) in points {
            let v = (t - b).min(d - t);
            if v > 0.0 {
                tents.push(v);
            }
        }
        tents.sort_by(|a, b| b.total_cmp(a));
        for (k, level) in out.iter_mut().enumerate() {
            level[i] = tents.get(k).copied().unwrap_or(0.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_bar_tent() {
        // One bar (0, 1): λ₁ peaks at 0.5 with value 0.5.
        let l = landscape(&[(0.0, 1.0)], 2, 10, 1.0);
        assert_eq!(l.len(), 2);
        assert_eq!(l[0][5], 0.5);
        assert_eq!(l[0][0], 0.0);
        assert_eq!(l[0][10], 0.0);
        // No second class anywhere: λ₂ ≡ 0.
        assert!(l[1].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn second_level_needs_overlap() {
        // Two overlapping bars: λ₂ > 0 inside the overlap only.
        let pts = [(0.0, 0.6), (0.4, 1.0)];
        let l = landscape(&pts, 2, 10, 1.0);
        assert!(l[1][5] > 0.0, "overlap at t=0.5: {:?}", l[1]);
        assert_eq!(l[1][1], 0.0);
        // λ₁ ≥ λ₂ pointwise.
        for i in 0..=10 {
            assert!(l[0][i] >= l[1][i]);
        }
    }
}
