//! Point clouds and metric inputs.
//!
//! Dory consumes three input shapes (paper §5–6): raw point clouds in a
//! Euclidean space, dense distance matrices (the `fractal` benchmark), and
//! pre-thresholded *sparse* distance lists (the Hi-C data sets). All three
//! normalize into [`MetricData`] from which the edge filtration is built.

/// Row-major `n × dim` point cloud.
#[derive(Clone, Debug)]
pub struct PointCloud {
    pub dim: usize,
    pub coords: Vec<f64>,
}

impl PointCloud {
    pub fn new(dim: usize, coords: Vec<f64>) -> Self {
        assert!(dim > 0 && coords.len() % dim == 0);
        Self { dim, coords }
    }

    pub fn n(&self) -> usize {
        self.coords.len() / self.dim
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.coords[i * self.dim..(i + 1) * self.dim]
    }

    /// Euclidean distance between points `i` and `j`.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        let (p, q) = (self.point(i), self.point(j));
        let mut s = 0.0;
        for k in 0..self.dim {
            let d = p[k] - q[k];
            s += d * d;
        }
        s.sqrt()
    }

    /// Coordinates as f32, padded/truncated to `(rows, cols)` for the PJRT
    /// artifact path. Padding points are placed far away (`pad_value`) so
    /// padded edges exceed any finite `τ_m`.
    pub fn to_f32_padded(&self, rows: usize, cols: usize, pad_value: f32) -> Vec<f32> {
        let n = self.n();
        assert!(rows >= n && cols >= self.dim);
        let mut out = vec![pad_value; rows * cols];
        for i in 0..n {
            for k in 0..self.dim {
                out[i * cols + k] = self.coords[i * self.dim + k] as f32;
            }
            for k in self.dim..cols {
                out[i * cols + k] = 0.0;
            }
        }
        out
    }

    /// Bounding-box diagonal — a cheap scale reference for picking τ_m.
    pub fn bbox_diagonal(&self) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        let mut lo = vec![f64::INFINITY; self.dim];
        let mut hi = vec![f64::NEG_INFINITY; self.dim];
        for i in 0..self.n() {
            for (k, &c) in self.point(i).iter().enumerate() {
                lo[k] = lo[k].min(c);
                hi[k] = hi[k].max(c);
            }
        }
        lo.iter()
            .zip(&hi)
            .map(|(a, b)| (b - a) * (b - a))
            .sum::<f64>()
            .sqrt()
    }
}

/// Cache-aligned structure-of-arrays copy of a [`PointCloud`].
///
/// The row-band distance kernel streams one coordinate axis at a time
/// across many candidate points, so the hot loads are `coords[k][j..j+L]`
/// — contiguous in an SoA layout, strided `dim` apart in the row-major
/// [`PointCloud`]. Each axis row starts on a 64-byte boundary (one cache
/// line / one AVX2 lane group): the backing buffer is over-allocated and
/// the base offset chosen so `coord_row(0)` is 64-byte aligned, and the
/// stride is a multiple of 8 doubles so every subsequent row stays
/// aligned. Padding slots past `n` exist only for alignment and are never
/// read — the kernels bound every loop by `n` and handle remainders in
/// scalar code, so padding can stay uninitialised-by-convention zeros.
///
/// Values are bit-for-bit copies of the cloud's coordinates (including
/// `-0.0` and subnormals); the SIMD kernels that consume this layout are
/// pinned to produce the same bits as [`PointCloud::dist`].
#[derive(Clone, Debug)]
pub struct SoaPoints {
    n: usize,
    dim: usize,
    stride: usize,
    base: usize,
    buf: Vec<f64>,
}

impl SoaPoints {
    pub fn from_cloud(pc: &PointCloud) -> Self {
        let n = pc.n();
        let dim = pc.dim;
        // Stride in elements: n rounded up to a multiple of 8 (64 bytes).
        let stride = n.div_ceil(8).max(1) * 8;
        // Over-allocate by one cache line so a 64-byte-aligned base offset
        // always exists inside the buffer (Vec<f64> only guarantees 8).
        let mut buf = vec![0.0f64; stride * dim + 8];
        let misalign = (buf.as_ptr() as usize) % 64;
        let base = ((64 - misalign) % 64) / 8;
        for k in 0..dim {
            let row = base + k * stride;
            for j in 0..n {
                buf[row + j] = pc.coords[j * dim + k];
            }
        }
        Self {
            n,
            dim,
            stride,
            base,
            buf,
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// All coordinates along axis `k`, padded to `stride` elements; the
    /// first `n` entries are live, the slice starts 64-byte aligned.
    #[inline]
    pub fn coord_row(&self, k: usize) -> &[f64] {
        debug_assert!(k < self.dim);
        let start = self.base + k * self.stride;
        &self.buf[start..start + self.stride]
    }

    /// Coordinate `k` of point `j` (bit-equal to the source cloud's).
    #[inline]
    pub fn coord(&self, j: usize, k: usize) -> f64 {
        debug_assert!(j < self.n);
        self.buf[self.base + k * self.stride + j]
    }

    pub fn memory_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f64>()
    }
}

/// Dense symmetric distance matrix stored as the strict lower triangle,
/// packed row-wise: entry (i, j) with i > j at index `i*(i-1)/2 + j`.
#[derive(Clone, Debug)]
pub struct DenseDistances {
    pub n: usize,
    tri: Vec<f64>,
}

impl DenseDistances {
    pub fn new(n: usize, tri: Vec<f64>) -> Self {
        assert_eq!(tri.len(), n * (n - 1) / 2);
        Self { n, tri }
    }

    pub fn from_full(n: usize, full: &[f64]) -> Self {
        assert_eq!(full.len(), n * n);
        let mut tri = Vec::with_capacity(n * (n - 1) / 2);
        for i in 1..n {
            for j in 0..i {
                tri.push(full[i * n + j]);
            }
        }
        Self { n, tri }
    }

    pub fn from_points(pc: &PointCloud) -> Self {
        let n = pc.n();
        let mut tri = Vec::with_capacity(n * (n - 1) / 2);
        for i in 1..n {
            for j in 0..i {
                tri.push(pc.dist(i, j));
            }
        }
        Self { n, tri }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i != j);
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        self.tri[hi * (hi - 1) / 2 + lo]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        self.tri[hi * (hi - 1) / 2 + lo] = v;
    }
}

/// Sparse distance list: pre-thresholded edges `(u, v, d)` with `u < v`.
/// This is the Hi-C input format — only pairs within τ_m are present.
#[derive(Clone, Debug)]
pub struct SparseDistances {
    pub n: usize,
    pub entries: Vec<(u32, u32, f64)>,
}

/// Unified metric input for filtration construction.
#[derive(Clone, Debug)]
pub enum MetricData {
    Points(PointCloud),
    Dense(DenseDistances),
    Sparse(SparseDistances),
}

impl MetricData {
    pub fn n(&self) -> usize {
        match self {
            MetricData::Points(p) => p.n(),
            MetricData::Dense(d) => d.n,
            MetricData::Sparse(s) => s.n,
        }
    }

    /// Reject NaN coordinates/distances up front with a descriptive
    /// error. NaN is the front-end footgun: the old comparator sort
    /// panicked on `partial_cmp().unwrap()` deep inside
    /// `from_weighted_edges`, and the thresholded distance filter drops
    /// NaN pairs silently (`NaN <= τ` is false) — neither is an
    /// acceptable way to learn the input is bad. Called by every file
    /// ingestion path.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            MetricData::Points(pc) => {
                for (i, &c) in pc.coords.iter().enumerate() {
                    if c.is_nan() {
                        return Err(format!(
                            "point {} coordinate {} is NaN",
                            i / pc.dim,
                            i % pc.dim
                        ));
                    }
                }
            }
            MetricData::Dense(dd) => {
                for i in 1..dd.n {
                    for j in 0..i {
                        if dd.get(i, j).is_nan() {
                            return Err(format!("distance ({i}, {j}) is NaN"));
                        }
                    }
                }
            }
            MetricData::Sparse(sd) => {
                for &(u, v, d) in &sd.entries {
                    if d.is_nan() {
                        return Err(format!("sparse entry ({u}, {v}) is NaN"));
                    }
                    if u >= v {
                        return Err(format!("sparse entry ({u}, {v}) must have u < v"));
                    }
                    if v as usize >= sd.n {
                        return Err(format!(
                            "sparse entry ({u}, {v}) out of range for n = {}",
                            sd.n
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointcloud_dist() {
        let pc = PointCloud::new(2, vec![0.0, 0.0, 3.0, 4.0]);
        assert_eq!(pc.n(), 2);
        assert!((pc.dist(0, 1) - 5.0).abs() < 1e-12);
        assert!((pc.bbox_diagonal() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dense_roundtrip() {
        let pc = PointCloud::new(3, vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let dd = DenseDistances::from_points(&pc);
        for i in 0..3 {
            for j in 0..3 {
                if i != j {
                    assert!((dd.get(i, j) - pc.dist(i, j)).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn dense_from_full_symmetric() {
        let full = vec![0.0, 1.0, 2.0, 1.0, 0.0, 3.0, 2.0, 3.0, 0.0];
        let dd = DenseDistances::from_full(3, &full);
        assert_eq!(dd.get(0, 1), 1.0);
        assert_eq!(dd.get(2, 0), 2.0);
        assert_eq!(dd.get(1, 2), 3.0);
    }

    #[test]
    fn validate_rejects_nan_everywhere() {
        let good = MetricData::Points(PointCloud::new(2, vec![0.0, 0.0, 1.0, 1.0]));
        assert!(good.validate().is_ok());
        let bad = MetricData::Points(PointCloud::new(2, vec![0.0, 0.0, f64::NAN, 1.0]));
        let e = bad.validate().unwrap_err();
        assert!(e.contains("NaN"), "{e}");
        assert!(e.contains("point 1"), "{e}");

        let bad = MetricData::Dense(DenseDistances::new(3, vec![1.0, f64::NAN, 2.0]));
        assert!(bad.validate().unwrap_err().contains("NaN"));

        let bad = MetricData::Sparse(SparseDistances {
            n: 3,
            entries: vec![(0, 1, f64::NAN)],
        });
        assert!(bad.validate().unwrap_err().contains("NaN"));
        let bad = MetricData::Sparse(SparseDistances {
            n: 3,
            entries: vec![(2, 1, 0.5)],
        });
        assert!(bad.validate().unwrap_err().contains("u < v"));
        let bad = MetricData::Sparse(SparseDistances {
            n: 2,
            entries: vec![(0, 5, 0.5)],
        });
        assert!(bad.validate().unwrap_err().contains("out of range"));
        // Infinities are legal filtration values; only NaN is rejected.
        let inf = MetricData::Sparse(SparseDistances {
            n: 2,
            entries: vec![(0, 1, f64::INFINITY)],
        });
        assert!(inf.validate().is_ok());
    }

    #[test]
    fn soa_rows_are_aligned_bit_copies() {
        for &(n, dim) in &[(1usize, 2usize), (5, 3), (8, 2), (13, 20), (64, 8)] {
            let coords: Vec<f64> = (0..n * dim)
                .map(|i| {
                    // Mix signs, a negative zero, and a subnormal into the grid.
                    match i % 5 {
                        0 => -0.0,
                        1 => f64::MIN_POSITIVE / 4.0,
                        _ => (i as f64) * 0.37 - 3.0,
                    }
                })
                .collect();
            let pc = PointCloud::new(dim, coords);
            let soa = SoaPoints::from_cloud(&pc);
            assert_eq!(soa.n(), n);
            assert_eq!(soa.dim(), dim);
            for k in 0..dim {
                let row = soa.coord_row(k);
                assert_eq!(row.as_ptr() as usize % 64, 0, "axis {k} misaligned");
                assert!(row.len() >= n && row.len() % 8 == 0);
                for j in 0..n {
                    assert_eq!(
                        row[j].to_bits(),
                        pc.coords[j * dim + k].to_bits(),
                        "coord ({j}, {k}) not a bit copy"
                    );
                    assert_eq!(soa.coord(j, k).to_bits(), row[j].to_bits());
                }
            }
        }
    }

    #[test]
    fn padding_layout() {
        let pc = PointCloud::new(2, vec![1.0, 2.0]);
        let p = pc.to_f32_padded(3, 4, 9e8);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(p[4], 9e8);
    }
}
