//! Synthetic Hi-C substrate (paper §6 substitution — see DESIGN.md §4).
//!
//! The paper analyzes Rao et al. (2017) genome-wide Hi-C at 1 kb
//! resolution: ~3.09 M genomic bins whose pairwise spatial distances are
//! estimated from contact frequencies, thresholded at τ_m = 400, and fed
//! to Dory as a *sparse distance list*. Treating DNA with auxin degrades
//! cohesin and eliminates loop domains; the paper's Figure 21 shows the
//! loop (H1) and void (H2) counts collapsing.
//!
//! We reproduce the *relevant structure* of that data set synthetically:
//!
//! * a **polymer backbone** — per chromosome, nearby bins (|i−j| ≤ window)
//!   get sub-linear, noisy distances `step·|i−j|^0.6`, the contact decay
//!   of a folded chain;
//! * **cohesin loops** — anchor pairs (i, j) at log-normal genomic
//!   separation are pulled spatially close, with a zipped stem around the
//!   anchor (CTCF-convergent loop extrusion footprint). Each anchor
//!   closes a cycle through the backbone → an H1 class whose birth scale
//!   is the anchor distance;
//! * **domain shells** — compact domains arranged on spherical shells
//!   contribute H2 classes (voids);
//! * the **auxin condition** keeps only a small fraction of loops and
//!   shells (cohesin-dependent structures), leaving the backbone intact.
//!
//! Output is exactly the input format the paper uses (sparse entries with
//! d ≤ τ_m), at a configurable number of bins.

use crate::geometry::SparseDistances;
use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Condition {
    Control,
    Auxin,
}

#[derive(Clone, Debug)]
pub struct HiCParams {
    /// Total genomic bins (the paper: 3,087,941 at 1 kb).
    pub n_bins: usize,
    /// Number of chromosomes (independent backbone chains).
    pub chroms: usize,
    /// Backbone contact window (|i-j| <= window gets an entry).
    pub window: usize,
    /// Cohesin loops in the control condition.
    pub n_loops: usize,
    /// Fraction of loops surviving auxin (Rao 2017: "eliminates all loop
    /// domains" — a small residue remains).
    pub loop_retention: f64,
    /// Spherical domain shells (void generators) in control.
    pub n_domains: usize,
    /// Fraction of domains surviving auxin.
    pub domain_retention: f64,
    /// Distance threshold (the paper used τ_m = 400).
    pub tau_max: f64,
    pub seed: u64,
}

impl Default for HiCParams {
    fn default() -> Self {
        Self {
            n_bins: 20_000,
            chroms: 8,
            window: 24,
            n_loops: 220,
            loop_retention: 0.12,
            n_domains: 36,
            domain_retention: 0.15,
            tau_max: 400.0,
            seed: 2021,
        }
    }
}

/// Generate the sparse distance list for one experimental condition.
pub fn generate(params: &HiCParams, condition: Condition) -> SparseDistances {
    let mut rng = Pcg32::new(
        params.seed ^ 0x48_69_43, // same structural randomness per seed;
    );
    let n = params.n_bins;
    let per_chrom = n / params.chroms;
    let mut entries: Vec<(u32, u32, f64)> = Vec::new();

    // --- Backbone ---------------------------------------------------------
    let step = 36.0;
    for c in 0..params.chroms {
        let lo = c * per_chrom;
        let hi = if c == params.chroms - 1 {
            n
        } else {
            (c + 1) * per_chrom
        };
        for i in lo..hi {
            for k in 1..=params.window {
                let j = i + k;
                if j >= hi {
                    break;
                }
                let d = step * (k as f64).powf(0.6) * (1.0 + 0.08 * rng.normal());
                if d <= params.tau_max && d > 0.0 {
                    entries.push((i as u32, j as u32, d));
                }
            }
        }
    }

    // --- Cohesin loops ------------------------------------------------------
    // Structural randomness (anchor placement) is shared between
    // conditions; auxin *removes* loops rather than re-rolling them.
    let keep_loops = match condition {
        Condition::Control => params.n_loops,
        Condition::Auxin => ((params.n_loops as f64) * params.loop_retention).round() as usize,
    };
    let mut loop_rng = Pcg32::new(params.seed.wrapping_mul(0x9E37_79B9));
    for li in 0..params.n_loops {
        // Genomic separation: log-normal, 60–1200 bins typical.
        let sep = (loop_rng.log_normal(5.2, 0.55)).clamp(40.0, 2400.0) as usize;
        let c = loop_rng.gen_range(params.chroms as u32) as usize;
        let lo = c * per_chrom;
        let hi = if c == params.chroms - 1 {
            n
        } else {
            (c + 1) * per_chrom
        };
        if hi - lo <= sep + 2 {
            continue;
        }
        let i = lo + loop_rng.gen_range((hi - lo - sep) as u32) as usize;
        let j = i + sep;
        // Anchor spatial proximity: spread across the threshold axis so
        // Fig 21's per-threshold structure is non-trivial.
        let anchor_d = 20.0 + 330.0 * loop_rng.next_f64();
        if li >= keep_loops {
            continue; // removed by auxin
        }
        // Zipped stem around the anchor.
        let stem = 4 + loop_rng.gen_range(6) as usize;
        for k in 0..=stem {
            // Stay inside the chromosome on both sides.
            if i >= lo + k && j + k < hi {
                let d = anchor_d + 14.0 * k as f64 * (1.0 + 0.05 * loop_rng.normal());
                if d <= params.tau_max {
                    entries.push(((i - k) as u32, (j + k) as u32, d.max(1.0)));
                }
            }
        }
    }

    // --- Domain shells (voids) ---------------------------------------------
    let keep_domains = match condition {
        Condition::Control => params.n_domains,
        Condition::Auxin => {
            ((params.n_domains as f64) * params.domain_retention).round() as usize
        }
    };
    let mut dom_rng = Pcg32::new(params.seed.wrapping_mul(0x2545_F491));
    for di in 0..params.n_domains {
        let span = 60 + dom_rng.gen_range(60) as usize; // bins on the shell
        let c = dom_rng.gen_range(params.chroms as u32) as usize;
        let lo = c * per_chrom;
        let hi = if c == params.chroms - 1 {
            n
        } else {
            (c + 1) * per_chrom
        };
        if hi - lo <= span + 2 {
            continue;
        }
        let start = lo + dom_rng.gen_range((hi - lo - span) as u32) as usize;
        let radius = 70.0 + 90.0 * dom_rng.next_f64();
        if di >= keep_domains {
            continue;
        }
        // Place the domain's bins on a Fibonacci sphere of `radius`; add
        // all intra-domain pairs within τ_m. The shell's VR complex has a
        // genuine H2 class born ~ the sample spacing, dying ~ the radius.
        let phi = std::f64::consts::PI * (3.0 - 5f64.sqrt());
        let mut pos = Vec::with_capacity(span);
        for s in 0..span {
            let y = 1.0 - 2.0 * (s as f64 + 0.5) / span as f64;
            let r = (1.0 - y * y).sqrt();
            let t = phi * s as f64;
            pos.push((
                radius * r * t.cos(),
                radius * y,
                radius * r * t.sin(),
            ));
        }
        // Shuffle assignment so the shell is not aligned with the chain
        // (otherwise backbone distances fight the shell geometry).
        let mut order: Vec<usize> = (0..span).collect();
        dom_rng.shuffle(&mut order);
        for a in 0..span {
            for b in (a + 1)..span {
                let (p, q) = (pos[order[a]], pos[order[b]]);
                let d = ((p.0 - q.0).powi(2) + (p.1 - q.1).powi(2) + (p.2 - q.2).powi(2))
                    .sqrt()
                    .max(1.0);
                if d <= params.tau_max {
                    entries.push(((start + a) as u32, (start + b) as u32, d));
                }
            }
        }
    }

    // Deduplicate (keep the smallest distance per pair — closest contact).
    entries.sort_by(|x, y| {
        (x.0, x.1)
            .cmp(&(y.0, y.1))
            .then(x.2.partial_cmp(&y.2).unwrap())
    });
    entries.dedup_by_key(|e| (e.0, e.1));

    SparseDistances { n, entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::MetricData;
    use crate::homology::{compute_ph, EngineOptions};

    fn small_params() -> HiCParams {
        HiCParams {
            n_bins: 3000,
            chroms: 3,
            window: 16,
            n_loops: 40,
            n_domains: 6,
            ..Default::default()
        }
    }

    #[test]
    fn sparse_output_well_formed() {
        let p = small_params();
        let sd = generate(&p, Condition::Control);
        assert_eq!(sd.n, p.n_bins);
        for &(u, v, d) in &sd.entries {
            assert!(u < v, "ordered endpoints");
            assert!((v as usize) < sd.n);
            assert!(d > 0.0 && d <= p.tau_max);
        }
        // No duplicate pairs.
        let mut pairs: Vec<_> = sd.entries.iter().map(|e| (e.0, e.1)).collect();
        pairs.sort_unstable();
        let len = pairs.len();
        pairs.dedup();
        assert_eq!(pairs.len(), len);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = small_params();
        let a = generate(&p, Condition::Control);
        let b = generate(&p, Condition::Control);
        assert_eq!(a.entries.len(), b.entries.len());
        assert_eq!(a.entries[..50], b.entries[..50]);
    }

    #[test]
    fn auxin_is_sparser_than_control() {
        let p = small_params();
        let ctrl = generate(&p, Condition::Control);
        let aux = generate(&p, Condition::Auxin);
        assert!(
            aux.entries.len() < ctrl.entries.len(),
            "{} !< {}",
            aux.entries.len(),
            ctrl.entries.len()
        );
    }

    #[test]
    fn auxin_collapses_loops_and_voids() {
        let p = small_params();
        let opts = EngineOptions {
            max_dim: 2,
            ..Default::default()
        };
        let ctrl = compute_ph(
            &MetricData::Sparse(generate(&p, Condition::Control)),
            p.tau_max,
            &opts,
        );
        let aux = compute_ph(
            &MetricData::Sparse(generate(&p, Condition::Auxin)),
            p.tau_max,
            &opts,
        );
        // Fig 21's qualitative claim: loops and voids drop sharply.
        let (b1c, b1a) = (
            ctrl.diagram.significant(1, 60.0).len(),
            aux.diagram.significant(1, 60.0).len(),
        );
        assert!(
            (b1a as f64) < 0.55 * b1c as f64,
            "loops: control {b1c} vs auxin {b1a}"
        );
        let (b2c, b2a) = (
            ctrl.diagram.significant(2, 30.0).len(),
            aux.diagram.significant(2, 30.0).len(),
        );
        assert!(b2c >= 3, "control should show voids, got {b2c}");
        assert!(
            (b2a as f64) < 0.6 * b2c as f64,
            "voids: control {b2c} vs auxin {b2a}"
        );
    }
}
