//! Benchmark dataset generators (paper §5, Table 1).
//!
//! `o3` and `torus4` are generated exactly per the paper's description.
//! `dragon` (a Stanford scan we cannot ship) is substituted by a trefoil
//! tube surface sample of the same size and role; `fractal` (a
//! self-similar network) by a Sierpiński-triangle graph metric — see
//! DESIGN.md §4 for the substitution rationale. The Hi-C substrate lives
//! in [`crate::hic`]. Small fixtures (circle, figure-eight, sphere,
//! torus) back the known-topology tests.

use crate::geometry::{DenseDistances, MetricData, PointCloud};
use crate::util::rng::Pcg32;

/// Named dataset with the paper's benchmark parameters attached.
pub struct Dataset {
    pub name: String,
    pub data: MetricData,
    /// τ_m used in the paper's Table 1 (scaled variants adjust it).
    pub tau: f64,
    /// Homology dimension the benchmarks compute up to.
    pub max_dim: usize,
}

/// Noisy circle in R² — the classic one-loop fixture.
pub fn circle(n: usize, radius: f64, noise: f64, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 2);
    for i in 0..n {
        let t = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
        let r = radius + noise * rng.normal();
        coords.push(r * t.cos());
        coords.push(r * t.sin());
    }
    MetricData::Points(PointCloud::new(2, coords))
}

/// Two tangent circles — β1 = 2.
pub fn figure_eight(n: usize, radius: f64, noise: f64, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 2);
    for i in 0..n {
        let t = 2.0 * std::f64::consts::PI * i as f64 / (n / 2) as f64;
        let r = radius + noise * rng.normal();
        let (cx, s) = if i < n / 2 {
            (-radius, 1.0)
        } else {
            (radius, -1.0)
        };
        coords.push(cx + s * r * t.cos());
        coords.push(r * t.sin());
    }
    MetricData::Points(PointCloud::new(2, coords))
}

/// Fibonacci-lattice sphere sample in R³ — β2 = 1.
pub fn sphere(n: usize, radius: f64, noise: f64, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let phi = std::f64::consts::PI * (3.0 - 5f64.sqrt());
    let mut coords = Vec::with_capacity(n * 3);
    for i in 0..n {
        let y = 1.0 - 2.0 * (i as f64 + 0.5) / n as f64;
        let r = (1.0 - y * y).sqrt();
        let t = phi * i as f64;
        let s = radius + noise * rng.normal();
        coords.push(s * r * t.cos());
        coords.push(s * y);
        coords.push(s * r * t.sin());
    }
    MetricData::Points(PointCloud::new(3, coords))
}

/// Torus of revolution in R³ (β1 = 2, β2 = 1) — grid + jitter sample.
pub fn torus3(n: usize, big_r: f64, small_r: f64, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 3);
    for _ in 0..n {
        let u = 2.0 * std::f64::consts::PI * rng.next_f64();
        let v = 2.0 * std::f64::consts::PI * rng.next_f64();
        coords.push((big_r + small_r * v.cos()) * u.cos());
        coords.push((big_r + small_r * v.cos()) * u.sin());
        coords.push(small_r * v.sin());
    }
    MetricData::Points(PointCloud::new(3, coords))
}

/// Clifford torus S¹×S¹ ⊂ R⁴ — the paper's `torus4` (Table 1: n=50000,
/// τ_m=0.15, from the Ripser repository).
pub fn torus4(n: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 4);
    let s = 1.0 / 2f64.sqrt();
    for _ in 0..n {
        let u = 2.0 * std::f64::consts::PI * rng.next_f64();
        let v = 2.0 * std::f64::consts::PI * rng.next_f64();
        coords.push(s * u.cos());
        coords.push(s * u.sin());
        coords.push(s * v.cos());
        coords.push(s * v.sin());
    }
    MetricData::Points(PointCloud::new(4, coords))
}

/// `o3`: random orthogonal 3×3 matrices as points in R⁹ (Table 1:
/// n=8192, τ_m=1, d=2). Gram–Schmidt on a random Gaussian matrix.
pub fn o3(n: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 9);
    for _ in 0..n {
        let m = random_orthogonal3(&mut rng);
        coords.extend_from_slice(&m);
    }
    MetricData::Points(PointCloud::new(9, coords))
}

fn random_orthogonal3(rng: &mut Pcg32) -> [f64; 9] {
    loop {
        let mut v: [[f64; 3]; 3] = [[0.0; 3]; 3];
        for row in v.iter_mut() {
            for x in row.iter_mut() {
                *x = rng.normal();
            }
        }
        // Gram–Schmidt.
        let mut ok = true;
        for i in 0..3 {
            for j in 0..i {
                let dot: f64 = (0..3).map(|k| v[i][k] * v[j][k]).sum();
                for k in 0..3 {
                    v[i][k] -= dot * v[j][k];
                }
            }
            let norm: f64 = (0..3).map(|k| v[i][k] * v[i][k]).sum::<f64>().sqrt();
            if norm < 1e-8 {
                ok = false;
                break;
            }
            for k in 0..3 {
                v[i][k] /= norm;
            }
        }
        if ok {
            let mut out = [0.0; 9];
            for i in 0..3 {
                for k in 0..3 {
                    out[i * 3 + k] = v[i][k];
                }
            }
            return out;
        }
    }
}

/// "dragon" substitute: surface sample of a trefoil-knot tube in R³ —
/// a curved 3-D scan-like cloud with non-trivial H1 (the knotted core
/// circle), matching the benchmark's role (n=2000, τ_m=∞, d=1).
pub fn dragon_like(n: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let tube_r = 0.35;
    let mut coords = Vec::with_capacity(n * 3);
    for _ in 0..n {
        let t = 2.0 * std::f64::consts::PI * rng.next_f64();
        // Trefoil center curve.
        let cx = (t.sin() + 2.0 * (2.0 * t).sin()) * 1.0;
        let cy = (t.cos() - 2.0 * (2.0 * t).cos()) * 1.0;
        let cz = -(3.0 * t).sin();
        // Random offset in the normal disc (approximate frame).
        let phi = 2.0 * std::f64::consts::PI * rng.next_f64();
        let eps = 1e-4;
        let (dx, dy, dz) = (
            (t + eps).sin() + 2.0 * (2.0 * (t + eps)).sin() - cx,
            (t + eps).cos() - 2.0 * (2.0 * (t + eps)).cos() - cy,
            -(3.0 * (t + eps)).sin() - cz,
        );
        let tn = (dx * dx + dy * dy + dz * dz).sqrt();
        let (tx, ty, tz) = (dx / tn, dy / tn, dz / tn);
        // Any unit vector not parallel to T:
        let (ux, uy, uz) = if tx.abs() < 0.9 {
            (1.0, 0.0, 0.0)
        } else {
            (0.0, 1.0, 0.0)
        };
        // N = normalize(U - (U·T)T), B = T×N.
        let d = ux * tx + uy * ty + uz * tz;
        let (mut nx, mut ny, mut nz) = (ux - d * tx, uy - d * ty, uz - d * tz);
        let nn = (nx * nx + ny * ny + nz * nz).sqrt();
        nx /= nn;
        ny /= nn;
        nz /= nn;
        let (bx, by, bz) = (
            ty * nz - tz * ny,
            tz * nx - tx * nz,
            tx * ny - ty * nx,
        );
        coords.push(cx + tube_r * (phi.cos() * nx + phi.sin() * bx));
        coords.push(cy + tube_r * (phi.cos() * ny + phi.sin() * by));
        coords.push(cz + tube_r * (phi.cos() * nz + phi.sin() * bz));
    }
    MetricData::Points(PointCloud::new(3, coords))
}

/// "fractal" substitute: Sierpiński-triangle graph metric. `levels`
/// recursions give `(3^(levels+1) + 3) / 2` nodes; distances are
/// shortest-path lengths in the recursive graph — a dense, non-geometric,
/// self-similar metric (the paper's fractal network role; 512-ish nodes
/// at levels=5 -> 366, levels=6 -> 1095; we pick the closest size).
pub fn fractal_network(levels: usize) -> MetricData {
    // Build the Sierpiński gasket graph by recursive subdivision.
    let mut points: Vec<(f64, f64)> = vec![(0.0, 0.0), (1.0, 0.0), (0.5, 0.75f64.sqrt())];
    let mut tris: Vec<[usize; 3]> = vec![[0, 1, 2]];
    let mut index: std::collections::HashMap<(i64, i64), usize> = std::collections::HashMap::new();
    for (i, p) in points.iter().enumerate() {
        index.insert(quant(*p), i);
    }
    for _ in 0..levels {
        let mut next = Vec::with_capacity(tris.len() * 3);
        for &[a, b, c] in &tris {
            let mut m = |i: usize, j: usize, points: &mut Vec<(f64, f64)>| {
                let p = (
                    (points[i].0 + points[j].0) / 2.0,
                    (points[i].1 + points[j].1) / 2.0,
                );
                *index.entry(quant(p)).or_insert_with(|| {
                    points.push(p);
                    points.len() - 1
                })
            };
            let ab = m(a, b, &mut points);
            let bc = m(b, c, &mut points);
            let ca = m(c, a, &mut points);
            next.push([a, ab, ca]);
            next.push([ab, b, bc]);
            next.push([ca, bc, c]);
        }
        tris = next;
    }
    // Edges of the final subdivision; BFS all-pairs shortest paths.
    let n = points.len();
    let mut adj = vec![Vec::new(); n];
    let mut seen = std::collections::HashSet::new();
    for &[a, b, c] in &tris {
        for (u, v) in [(a, b), (b, c), (c, a)] {
            let key = (u.min(v), u.max(v));
            if seen.insert(key) {
                adj[u].push(v);
                adj[v].push(u);
            }
        }
    }
    let mut full = vec![0.0f64; n * n];
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    for s in 0..n {
        dist.iter_mut().for_each(|d| *d = u32::MAX);
        dist[s] = 0;
        queue.clear();
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                if dist[v] == u32::MAX {
                    dist[v] = dist[u] + 1;
                    queue.push_back(v);
                }
            }
        }
        for t in 0..n {
            full[s * n + t] = dist[t] as f64;
        }
    }
    MetricData::Dense(DenseDistances::from_full(n, &full))
}

fn quant(p: (f64, f64)) -> (i64, i64) {
    ((p.0 * 1e9).round() as i64, (p.1 * 1e9).round() as i64)
}

/// Uniform random cloud in the unit cube of `dim` dimensions.
pub fn random_cloud(n: usize, dim: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    MetricData::Points(PointCloud::new(
        dim,
        (0..n * dim).map(|_| rng.next_f64()).collect(),
    ))
}

/// The Figure-1 style demo: two small loops plus one large annulus.
pub fn multi_scale_demo(n: usize, seed: u64) -> MetricData {
    let mut rng = Pcg32::new(seed);
    let mut coords = Vec::with_capacity(n * 2);
    for i in 0..n {
        match i % 3 {
            0 => {
                // Large annulus.
                let t = 2.0 * std::f64::consts::PI * rng.next_f64();
                let r = 10.0 + 0.3 * rng.normal();
                coords.push(r * t.cos());
                coords.push(r * t.sin());
            }
            1 => {
                let t = 2.0 * std::f64::consts::PI * rng.next_f64();
                let r = 2.5 + 0.1 * rng.normal();
                coords.push(4.0 + r * t.cos());
                coords.push(1.0 + r * t.sin());
            }
            _ => {
                let t = 2.0 * std::f64::consts::PI * rng.next_f64();
                let r = 2.5 + 0.1 * rng.normal();
                coords.push(-4.0 + r * t.cos());
                coords.push(-1.0 + r * t.sin());
            }
        }
    }
    MetricData::Points(PointCloud::new(2, coords))
}

/// The paper's benchmark suite at a configurable scale factor.
/// `scale = 1.0` approaches Table 1 sizes; the default bench scale keeps
/// CI runtimes sane while preserving the comparisons' shape.
pub fn benchmark_suite(scale: f64, seed: u64) -> Vec<Dataset> {
    let s = |n: usize| ((n as f64 * scale).round() as usize).max(64);
    vec![
        Dataset {
            name: "dragon".into(),
            data: dragon_like(s(2000), seed),
            tau: f64::INFINITY,
            max_dim: 1,
        },
        Dataset {
            name: "fractal".into(),
            data: fractal_network(if scale >= 0.5 { 5 } else { 4 }),
            tau: f64::INFINITY,
            max_dim: 2,
        },
        Dataset {
            name: "o3".into(),
            data: o3(s(8192), seed + 1),
            tau: 1.0,
            max_dim: 2,
        },
        Dataset {
            name: "torus4(1)".into(),
            data: torus4(s(50_000), seed + 2),
            tau: 0.15 / scale.sqrt().min(1.0),
            max_dim: 1,
        },
        Dataset {
            name: "torus4(2)".into(),
            data: torus4(s(50_000), seed + 2),
            tau: 0.15 / scale.sqrt().min(1.0),
            max_dim: 2,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::homology::{compute_ph, EngineOptions};

    #[test]
    fn o3_points_are_orthogonal_matrices() {
        let data = o3(16, 1);
        let pc = match &data {
            MetricData::Points(p) => p,
            _ => unreachable!(),
        };
        assert_eq!(pc.dim, 9);
        for i in 0..pc.n() {
            let m = pc.point(i);
            // Rows orthonormal.
            for r in 0..3 {
                for q in 0..3 {
                    let dot: f64 = (0..3).map(|k| m[r * 3 + k] * m[q * 3 + k]).sum();
                    let want = if r == q { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-9, "i={i} r={r} q={q} dot={dot}");
                }
            }
        }
    }

    #[test]
    fn torus4_points_on_clifford_torus() {
        let data = torus4(32, 2);
        let pc = match &data {
            MetricData::Points(p) => p,
            _ => unreachable!(),
        };
        for i in 0..pc.n() {
            let p = pc.point(i);
            let n1 = p[0] * p[0] + p[1] * p[1];
            let n2 = p[2] * p[2] + p[3] * p[3];
            assert!((n1 - 0.5).abs() < 1e-12 && (n2 - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn figure_eight_has_two_loops() {
        let data = figure_eight(60, 1.0, 0.0, 3);
        let r = compute_ph(
            &data,
            1.2,
            &EngineOptions {
                max_dim: 1,
                ..Default::default()
            },
        );
        let sig = r.diagram.significant(1, 0.4);
        assert_eq!(sig.len(), 2, "{:?}", r.diagram.points(1));
    }

    #[test]
    fn torus3_betti_numbers() {
        let data = torus3(700, 2.0, 0.7, 7);
        let r = compute_ph(&data, 1.4, &EngineOptions::default());
        assert_eq!(r.diagram.essential_count(0), 1);
        let h1 = r.diagram.significant(1, 0.7);
        assert_eq!(h1.len(), 2, "torus has two independent loops: {h1:?}");
    }

    #[test]
    fn fractal_metric_axioms() {
        let data = fractal_network(3);
        let dd = match &data {
            MetricData::Dense(d) => d,
            _ => unreachable!(),
        };
        let n = dd.n;
        assert!(n > 30);
        for i in 0..n.min(12) {
            for j in 0..n.min(12) {
                if i == j {
                    continue;
                }
                assert!(dd.get(i, j) >= 1.0);
                assert_eq!(dd.get(i, j), dd.get(j, i));
                for k in 0..n.min(12) {
                    if k != i && k != j {
                        assert!(dd.get(i, j) <= dd.get(i, k) + dd.get(k, j) + 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn dragon_like_is_connected_at_modest_tau() {
        let data = dragon_like(400, 9);
        let r = compute_ph(
            &data,
            1.0,
            &EngineOptions {
                max_dim: 0,
                ..Default::default()
            },
        );
        assert_eq!(r.diagram.essential_count(0), 1, "tube sample is connected");
    }

    #[test]
    fn multi_scale_demo_three_loops() {
        let data = multi_scale_demo(450, 11);
        let r = compute_ph(
            &data,
            8.0,
            &EngineOptions {
                max_dim: 1,
                ..Default::default()
            },
        );
        // Multi-scale data genuinely carries multi-scale features
        // (composite loops between the blobs are real, transient
        // topology — the paper's Figure 1 point). Assert the three
        // *designed* features: two small circles dying around 2.5·√3,
        // and the essential annulus.
        let small: Vec<_> = r
            .diagram
            .significant(1, 1.8)
            .into_iter()
            .filter(|p| !p.is_essential() && p.death > 3.0 && p.death < 6.0 && p.birth < 1.5)
            .collect();
        assert_eq!(small.len(), 2, "two small circles: {small:?}");
        assert_eq!(r.diagram.essential_count(1), 1, "annulus still open at τ=8");
    }
}
