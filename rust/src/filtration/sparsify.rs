//! SimBa-style filtration sparsification (paper §7 / Dey et al. 2019)
//! and the greedy-net cover-graph front-end.
//!
//! "SimBa reduces the number of simplices in the filtration by
//! approximating it to a sparse filtration such that the PDs … are
//! within a theoretical error of margin" — the Discussion notes Dory can
//! serve as SimBa's exact backend. This module provides two ingredients:
//!
//! 1. Farthest-point (greedy permutation) subsampling, whose VR
//!    filtration on the ε-net is a classic 2·ε-interleaving of the full
//!    one — so `bottleneck(PD_full, PD_net) ≤ 2ε` per stability. The
//!    tests assert exactly that bound via [`crate::homology::analysis`].
//! 2. A cover-graph edge kernel ([`net_graph_edges`]): partition the
//!    cloud into net cells, then scan member pairs only for cell pairs
//!    whose centers are within `τ + 2ε` — by the triangle inequality no
//!    pair at distance ≤ τ can live in a farther cell pair, so the
//!    uncapped kernel recovers the *exact* thresholded edge set without
//!    materializing all n(n−1)/2 candidates. An optional per-point
//!    k-nearest-neighbor cap (`knn_k`) sparsifies further (approximate;
//!    union-symmetrized so each point keeps its k nearest).

use std::sync::Mutex;

use crate::geometry::{MetricData, PointCloud, SparseDistances};
use crate::reduction::pool::ThreadPool;
use crate::util::rng::Pcg32;

/// Result of a greedy permutation: selected indices, their exact cover
/// radius (the ε of the ε-net), and each point's assigned cell.
pub struct GreedyNet {
    pub indices: Vec<u32>,
    /// Exact post-selection cover radius: `max_i min_c d(i, c)` over
    /// the *final* center set. Recomputed from the maintained
    /// nearest-center distances after the loop exits, so the `2ε`
    /// stability gates downstream can rely on it regardless of whether
    /// selection stopped on `k` or on `min_radius`.
    pub radius: f64,
    /// `assign[i]` = index into `indices` of the nearest selected
    /// center (ties broken by selection order: the earliest center at
    /// the minimal distance wins).
    pub assign: Vec<u32>,
}

/// Farthest-point subsample of `k` points (or until radius ≤ `min_r`).
/// At least one point is always selected.
pub fn farthest_point_sample(
    pc: &PointCloud,
    k: usize,
    min_radius: f64,
    seed: u64,
) -> GreedyNet {
    let n = pc.n();
    assert!(n > 0);
    let k = k.clamp(1, n);
    let mut rng = Pcg32::new(seed);
    let first = rng.gen_range(n as u32) as usize;
    let mut dist = vec![f64::INFINITY; n];
    let mut assign = vec![0u32; n];
    let mut chosen: Vec<u32> = Vec::with_capacity(k);
    let mut cur = first;
    let mut radius = f64::INFINITY;
    while chosen.len() < k && radius > min_radius {
        let cell = chosen.len() as u32;
        chosen.push(cur as u32);
        let mut far = 0usize;
        let mut fard = -1.0;
        for i in 0..n {
            let d = pc.dist(cur, i);
            if d < dist[i] {
                dist[i] = d;
                assign[i] = cell;
            }
            if dist[i] > fard {
                fard = dist[i];
                far = i;
            }
        }
        radius = fard;
        cur = far;
    }
    // Pin the reported ε to the final center set structurally: the loop
    // above already folds the last selection into `dist`, but the gate
    // tests depend on this being the exact cover radius, so recompute
    // it from `dist` rather than trusting loop-exit bookkeeping.
    let radius = dist.iter().cloned().fold(0.0f64, f64::max);
    GreedyNet {
        indices: chosen,
        radius,
        assign,
    }
}

/// Restrict a point cloud to the net's points.
pub fn subsample_cloud(pc: &PointCloud, net: &GreedyNet) -> MetricData {
    let mut coords = Vec::with_capacity(net.indices.len() * pc.dim);
    for &i in &net.indices {
        coords.extend_from_slice(pc.point(i as usize));
    }
    MetricData::Points(PointCloud::new(pc.dim, coords))
}

/// A greedy ε-net plus the CSR of its cells: `members(c)` lists the
/// points whose nearest center is `indices[c]`, in ascending point
/// order. Cells partition the cloud, which is what makes the
/// cover-graph kernel below visit each unordered point pair exactly
/// once.
pub struct NetCover {
    pub net: GreedyNet,
    cell_start: Vec<u32>,
    members: Vec<u32>,
}

impl NetCover {
    pub fn build(pc: &PointCloud, k: usize, min_radius: f64, seed: u64) -> Self {
        let net = farthest_point_sample(pc, k, min_radius, seed);
        let n = pc.n();
        let nc = net.indices.len();
        // Counting scatter: stable, so members stay in ascending order.
        let mut counts = vec![0u32; nc + 1];
        for &c in &net.assign {
            counts[c as usize + 1] += 1;
        }
        for c in 0..nc {
            counts[c + 1] += counts[c];
        }
        let cell_start = counts.clone();
        let mut cursor = counts;
        let mut members = vec![0u32; n];
        for (i, &c) in net.assign.iter().enumerate() {
            members[cursor[c as usize] as usize] = i as u32;
            cursor[c as usize] += 1;
        }
        Self {
            net,
            cell_start,
            members,
        }
    }

    pub fn n_cells(&self) -> usize {
        self.net.indices.len()
    }

    pub fn members(&self, c: usize) -> &[u32] {
        &self.members[self.cell_start[c] as usize..self.cell_start[c + 1] as usize]
    }
}

/// Build the sparse edge set of the full cloud from the cover graph:
/// only cell pairs whose centers are within `τ + 2ε` are scanned, and
/// within a scanned pair only edges with `d ≤ τ` are kept.
///
/// With `knn_k == 0` this is **exact**: the triangle inequality puts
/// any pair at distance ≤ τ inside a scanned cell pair
/// (`d(c_u, c_v) ≤ d(c_u,u) + d(u,v) + d(v,c_v) ≤ 2ε + τ`), so the
/// result is the full thresholded edge set and downstream diagrams are
/// bit-identical to the dense pass at the same finite τ.
///
/// With `knn_k > 0` each point keeps at most its `knn_k` nearest kept
/// neighbors, union-symmetrized (an edge survives if *either* endpoint
/// ranks it); this is an approximation with no blanket stability bound
/// — use the ε-net subsample when a certified `2ε` bound is needed.
pub fn net_graph_edges(
    pc: &PointCloud,
    cover: &NetCover,
    tau: f64,
    knn_k: usize,
    pool: Option<&ThreadPool>,
) -> SparseDistances {
    let n = pc.n();
    let nc = cover.n_cells();
    let eps = cover.net.radius;
    let reach = tau + 2.0 * eps; // +∞ stays +∞: scan everything
    let mut cell_pairs: Vec<(u32, u32)> = Vec::new();
    for ci in 0..nc {
        let a = cover.net.indices[ci] as usize;
        for cj in ci..nc {
            let b = cover.net.indices[cj] as usize;
            if ci == cj || pc.dist(a, b) <= reach {
                cell_pairs.push((ci as u32, cj as u32));
            }
        }
    }

    let scan_pair = |ci: usize, cj: usize, out: &mut Vec<(u32, u32, f64)>| {
        let ms = cover.members(ci);
        if ci == cj {
            for (x, &u) in ms.iter().enumerate() {
                for &v in &ms[x + 1..] {
                    let d = pc.dist(u as usize, v as usize);
                    if d <= tau {
                        out.push((u.min(v), u.max(v), d));
                    }
                }
            }
        } else {
            for &u in ms {
                for &v in cover.members(cj) {
                    let d = pc.dist(u as usize, v as usize);
                    if d <= tau {
                        out.push((u.min(v), u.max(v), d));
                    }
                }
            }
        }
    };

    let entries: Vec<(u32, u32, f64)> = match pool {
        Some(pool) if cell_pairs.len() >= 2 => {
            // Chunked fan-out with in-order splice, same shape as the
            // sparse distance kernel: deterministic output order for
            // every schedule.
            let nchunks = cell_pairs
                .len()
                .div_ceil((pool.threads() * 8).max(1))
                .max(1);
            let chunk = cell_pairs.len().div_ceil(nchunks);
            let nchunks = cell_pairs.len().div_ceil(chunk);
            let slots: Vec<Mutex<Vec<(u32, u32, f64)>>> =
                (0..nchunks).map(|_| Mutex::new(Vec::new())).collect();
            pool.run_stealing(nchunks, 1, |_tid, range| {
                for c in range {
                    let lo = c * chunk;
                    let hi = (lo + chunk).min(cell_pairs.len());
                    let mut local = Vec::new();
                    for &(ci, cj) in &cell_pairs[lo..hi] {
                        scan_pair(ci as usize, cj as usize, &mut local);
                    }
                    *slots[c].lock().unwrap() = local;
                }
            });
            let mut all = Vec::new();
            for s in slots {
                all.append(&mut s.into_inner().unwrap());
            }
            all
        }
        _ => {
            let mut all = Vec::new();
            for &(ci, cj) in &cell_pairs {
                scan_pair(ci as usize, cj as usize, &mut all);
            }
            all
        }
    };

    let entries = if knn_k == 0 {
        entries
    } else {
        knn_cap(n, entries, knn_k)
    };
    SparseDistances { n, entries }
}

/// Keep, per vertex, its `k` nearest incident entries (ties broken by
/// the neighbor index), union-symmetrized across endpoints. Entry order
/// is preserved, so the result is deterministic.
fn knn_cap(n: usize, entries: Vec<(u32, u32, f64)>, k: usize) -> Vec<(u32, u32, f64)> {
    use super::f64_order_key;
    let mut adj: Vec<Vec<(u64, u32, u32)>> = vec![Vec::new(); n];
    for (idx, &(u, v, d)) in entries.iter().enumerate() {
        let key = f64_order_key(d);
        adj[u as usize].push((key, v, idx as u32));
        adj[v as usize].push((key, u, idx as u32));
    }
    let mut keep = vec![false; entries.len()];
    for list in &mut adj {
        list.sort_unstable();
        for &(_, _, idx) in list.iter().take(k) {
            keep[idx as usize] = true;
        }
    }
    entries
        .into_iter()
        .zip(keep)
        .filter_map(|(e, kept)| kept.then_some(e))
        .collect()
}

/// Upper bound on the enclosing radius from the net:
/// `min_{c ∈ centers} max_j d(c, j)`. Since centers are a subset of the
/// vertices this is ≥ `r_enc = min_i max_j d(i, j)`, and the cone
/// argument holds for *any* cut at or above `r_enc` — the center
/// achieving the bound cones off the whole complex at that value — so
/// truncating an infinite-τ build here preserves every diagram while
/// costing O(|net|·n) distances instead of O(n²).
pub fn net_enclosing_bound(pc: &PointCloud, cover: &NetCover) -> f64 {
    let n = pc.n();
    let mut best = f64::INFINITY;
    for &c in &cover.net.indices {
        let mut rowmax = f64::NEG_INFINITY;
        for j in 0..n {
            let d = pc.dist(c as usize, j);
            if d > rowmax {
                rowmax = d;
            }
        }
        if rowmax < best {
            best = rowmax;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::filtration::EdgeFiltration;
    use crate::homology::analysis::bottleneck_distance;
    use crate::homology::{compute_ph, EngineOptions};

    fn cloud(data: &MetricData) -> PointCloud {
        match data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        }
    }

    #[test]
    fn net_is_a_cover() {
        let data = datasets::circle(200, 1.0, 0.02, 3);
        let pc = cloud(&data);
        let net = farthest_point_sample(&pc, 50, 0.0, 1);
        assert_eq!(net.indices.len(), 50);
        // Every point is within `radius` of some net point.
        for i in 0..pc.n() {
            let d = net
                .indices
                .iter()
                .map(|&j| pc.dist(i, j as usize))
                .fold(f64::INFINITY, f64::min);
            assert!(d <= net.radius + 1e-12, "point {i}: {d} > {}", net.radius);
        }
        // Distinct indices.
        let set: std::collections::HashSet<_> = net.indices.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn reported_radius_and_assignment_are_exact() {
        // The reported ε must equal the brute-force cover radius of the
        // final center set bit-for-bit (f64 min/max over the same
        // distances is order-independent), and each point's assigned
        // center must achieve its nearest-center distance.
        let data = datasets::torus3(150, 2.0, 0.7, 11);
        let pc = cloud(&data);
        for k in [1usize, 7, 40, 150] {
            let net = farthest_point_sample(&pc, k, 0.0, 5);
            let mut brute = 0.0f64;
            for i in 0..pc.n() {
                let nearest = net
                    .indices
                    .iter()
                    .map(|&c| pc.dist(i, c as usize))
                    .fold(f64::INFINITY, f64::min);
                brute = brute.max(nearest);
                let assigned = pc.dist(i, net.indices[net.assign[i] as usize] as usize);
                assert_eq!(assigned, nearest, "k={k} point {i}");
            }
            assert_eq!(net.radius, brute, "k={k}");
        }
    }

    #[test]
    fn radius_decreases_with_k() {
        let data = datasets::torus3(300, 2.0, 0.7, 4);
        let pc = cloud(&data);
        let r20 = farthest_point_sample(&pc, 20, 0.0, 1).radius;
        let r100 = farthest_point_sample(&pc, 100, 0.0, 1).radius;
        assert!(r100 < r20);
    }

    #[test]
    fn cells_partition_the_cloud() {
        let data = datasets::circle(160, 1.0, 0.01, 2);
        let pc = cloud(&data);
        let cover = NetCover::build(&pc, 24, 0.0, 3);
        let mut seen = vec![false; pc.n()];
        for c in 0..cover.n_cells() {
            for &m in cover.members(c) {
                assert!(!seen[m as usize], "point {m} in two cells");
                seen[m as usize] = true;
                assert_eq!(cover.net.assign[m as usize] as usize, c);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn net_graph_kernel_is_exact_uncapped() {
        // Uncapped cover-graph scan == dense thresholded edge set,
        // byte-for-byte after the front-end sort.
        let data = datasets::circle(150, 1.0, 0.02, 9);
        let pc = cloud(&data);
        let tau = 0.6;
        let dense = EdgeFiltration::build(&data, tau);
        for k in [5usize, 20, 60] {
            let cover = NetCover::build(&pc, k, 0.0, 4);
            let sd = net_graph_edges(&pc, &cover, tau, 0, None);
            assert_eq!(sd.entries.len(), dense.n_edges(), "k={k}");
            let f = EdgeFiltration::build(&MetricData::Sparse(sd), tau);
            assert_eq!(f.edges, dense.edges, "k={k}");
            assert_eq!(
                f.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dense.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "k={k}"
            );
        }
    }

    #[test]
    fn net_graph_kernel_pooled_matches_serial() {
        let data = datasets::torus3(120, 2.0, 0.7, 6);
        let pc = cloud(&data);
        let cover = NetCover::build(&pc, 30, 0.0, 8);
        let serial = net_graph_edges(&pc, &cover, 1.5, 0, None);
        let pool = ThreadPool::new(4);
        let pooled = net_graph_edges(&pc, &cover, 1.5, 0, Some(&pool));
        assert_eq!(serial.entries, pooled.entries);
        let capped_s = net_graph_edges(&pc, &cover, 1.5, 6, None);
        let capped_p = net_graph_edges(&pc, &cover, 1.5, 6, Some(&pool));
        assert_eq!(capped_s.entries, capped_p.entries);
    }

    #[test]
    fn sparsified_pd_within_stability_bound() {
        // PD of the ε-net is within 2ε bottleneck distance of the full PD
        // (interleaving + stability). This validates the whole pipeline:
        // sparsifier, engine, and the bottleneck implementation together.
        let data = datasets::circle(240, 1.0, 0.0, 7);
        let pc = cloud(&data);
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let full = compute_ph(&data, 3.0, &opts).diagram;
        let net = farthest_point_sample(&pc, 80, 0.0, 2);
        let sub = compute_ph(&subsample_cloud(&pc, &net), 3.0, &opts).diagram;
        let d = bottleneck_distance(&full, &sub, 1);
        assert!(
            d <= 2.0 * net.radius + 1e-9,
            "bottleneck {d} > 2ε = {}",
            2.0 * net.radius
        );
        // And the loop survives sparsification.
        assert_eq!(sub.significant(1, 0.5).len(), 1);
    }

    #[test]
    fn net_graph_bottleneck_sweep() {
        // Sweep net sizes: route the subsample's edge set through the
        // cover-graph kernel (a coarser net over the net) and assert the
        // 2ε stability gate at every scale. Exercises the kernel as the
        // actual front-end of the bounded-error pipeline.
        let data = datasets::circle(240, 1.0, 0.0, 7);
        let pc = cloud(&data);
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let tau = 3.0;
        let full = compute_ph(&data, tau, &opts).diagram;
        for k in [40usize, 80, 140] {
            let net = farthest_point_sample(&pc, k, 0.0, 2);
            let sub_pc = cloud(&subsample_cloud(&pc, &net));
            let inner = NetCover::build(&sub_pc, (k / 4).max(1), 0.0, 3);
            let sd = net_graph_edges(&sub_pc, &inner, tau, 0, None);
            let sub = compute_ph(&MetricData::Sparse(sd), tau, &opts).diagram;
            let d = bottleneck_distance(&full, &sub, 1);
            assert!(
                d <= 2.0 * net.radius + 1e-9,
                "k={k}: bottleneck {d} > 2ε = {}",
                2.0 * net.radius
            );
        }
    }

    #[test]
    fn knn_cap_keeps_nearest_neighbors_and_loop() {
        let data = datasets::circle(100, 1.0, 0.0, 5);
        let pc = cloud(&data);
        let cover = NetCover::build(&pc, 20, 0.0, 3);
        let uncapped = net_graph_edges(&pc, &cover, 3.0, 0, None);
        let capped = net_graph_edges(&pc, &cover, 3.0, 4, None);
        assert!(capped.entries.len() < uncapped.entries.len());
        // Union symmetrization: every point keeps its ring neighbors,
        // so the H1 loop survives the cap.
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let pd = compute_ph(&MetricData::Sparse(capped), 3.0, &opts).diagram;
        assert!(!pd.significant(1, 0.5).is_empty());
    }

    #[test]
    fn net_enclosing_bound_dominates_r_enc() {
        let data = datasets::circle(120, 1.0, 0.02, 8);
        let pc = cloud(&data);
        let cover = NetCover::build(&pc, 30, 0.0, 2);
        let bound = net_enclosing_bound(&pc, &cover);
        // Brute-force r_enc.
        let mut r_enc = f64::INFINITY;
        for i in 0..pc.n() {
            let rm = (0..pc.n())
                .map(|j| pc.dist(i, j))
                .fold(f64::NEG_INFINITY, f64::max);
            r_enc = r_enc.min(rm);
        }
        assert!(bound >= r_enc);
        assert!(bound.is_finite());
        // Truncating at the bound preserves the diagram (cone argument).
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let full = compute_ph(&data, f64::INFINITY, &opts).diagram;
        let cut = compute_ph(&data, bound, &opts).diagram;
        let d = bottleneck_distance(&full, &cut, 1);
        assert!(d <= 1e-12, "cut at net bound changed H1: {d}");
    }

    #[test]
    fn min_radius_stopping() {
        let data = datasets::circle(100, 1.0, 0.0, 5);
        let pc = cloud(&data);
        let net = farthest_point_sample(&pc, 100, 0.5, 1);
        assert!(net.indices.len() < 100, "should stop early");
        assert!(net.radius <= 0.5 + 1e-9 || net.indices.len() == 100);
    }
}
