//! SimBa-style filtration sparsification (paper §7 / Dey et al. 2019).
//!
//! "SimBa reduces the number of simplices in the filtration by
//! approximating it to a sparse filtration such that the PDs … are
//! within a theoretical error of margin" — the Discussion notes Dory can
//! serve as SimBa's exact backend. This module provides the complementary
//! ingredient: farthest-point (greedy permutation) subsampling, whose
//! VR filtration on the ε-net is a classic 2·ε-interleaving of the full
//! one — so `bottleneck(PD_full, PD_net) ≤ 2ε` per stability. The bench
//! tests assert exactly that bound via [`crate::homology::analysis`].

use crate::geometry::{MetricData, PointCloud};
use crate::util::rng::Pcg32;

/// Result of a greedy permutation: selected indices and their cover
/// radius (the ε of the ε-net).
pub struct GreedyNet {
    pub indices: Vec<u32>,
    pub radius: f64,
}

/// Farthest-point subsample of `k` points (or until radius ≤ `min_r`).
pub fn farthest_point_sample(
    pc: &PointCloud,
    k: usize,
    min_radius: f64,
    seed: u64,
) -> GreedyNet {
    let n = pc.n();
    assert!(n > 0);
    let k = k.min(n);
    let mut rng = Pcg32::new(seed);
    let first = rng.gen_range(n as u32) as usize;
    let mut dist = vec![f64::INFINITY; n];
    let mut chosen = Vec::with_capacity(k);
    let mut cur = first;
    let mut radius = f64::INFINITY;
    while chosen.len() < k && radius > min_radius {
        chosen.push(cur as u32);
        let mut far = 0usize;
        let mut fard = -1.0;
        for i in 0..n {
            let d = pc.dist(cur, i);
            if d < dist[i] {
                dist[i] = d;
            }
            if dist[i] > fard {
                fard = dist[i];
                far = i;
            }
        }
        radius = fard;
        cur = far;
    }
    GreedyNet {
        indices: chosen,
        radius: radius.max(0.0),
    }
}

/// Restrict a point cloud to the net's points.
pub fn subsample_cloud(pc: &PointCloud, net: &GreedyNet) -> MetricData {
    let mut coords = Vec::with_capacity(net.indices.len() * pc.dim);
    for &i in &net.indices {
        coords.extend_from_slice(pc.point(i as usize));
    }
    MetricData::Points(PointCloud::new(pc.dim, coords))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::homology::analysis::bottleneck_distance;
    use crate::homology::{compute_ph, EngineOptions};

    #[test]
    fn net_is_a_cover() {
        let data = datasets::circle(200, 1.0, 0.02, 3);
        let pc = match &data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let net = farthest_point_sample(&pc, 50, 0.0, 1);
        assert_eq!(net.indices.len(), 50);
        // Every point is within `radius` of some net point.
        for i in 0..pc.n() {
            let d = net
                .indices
                .iter()
                .map(|&j| pc.dist(i, j as usize))
                .fold(f64::INFINITY, f64::min);
            assert!(d <= net.radius + 1e-12, "point {i}: {d} > {}", net.radius);
        }
        // Distinct indices.
        let set: std::collections::HashSet<_> = net.indices.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn radius_decreases_with_k() {
        let data = datasets::torus3(300, 2.0, 0.7, 4);
        let pc = match &data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let r20 = farthest_point_sample(&pc, 20, 0.0, 1).radius;
        let r100 = farthest_point_sample(&pc, 100, 0.0, 1).radius;
        assert!(r100 < r20);
    }

    #[test]
    fn sparsified_pd_within_stability_bound() {
        // PD of the ε-net is within 2ε bottleneck distance of the full PD
        // (interleaving + stability). This validates the whole pipeline:
        // sparsifier, engine, and the bottleneck implementation together.
        let data = datasets::circle(240, 1.0, 0.0, 7);
        let pc = match &data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        let full = compute_ph(&data, 3.0, &opts).diagram;
        let net = farthest_point_sample(&pc, 80, 0.0, 2);
        let sub = compute_ph(&subsample_cloud(&pc, &net), 3.0, &opts).diagram;
        let d = bottleneck_distance(&full, &sub, 1);
        assert!(
            d <= 2.0 * net.radius + 1e-9,
            "bottleneck {d} > 2ε = {}",
            2.0 * net.radius
        );
        // And the loop survives sparsification.
        assert_eq!(sub.significant(1, 0.5).len(), 1);
    }

    #[test]
    fn min_radius_stopping() {
        let data = datasets::circle(100, 1.0, 0.0, 5);
        let pc = match &data {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let net = farthest_point_sample(&pc, 100, 0.5, 1);
        assert!(net.indices.len() < 100, "should stop early");
        assert!(net.radius <= 0.5 + 1e-9 || net.indices.len() == 100);
    }
}
