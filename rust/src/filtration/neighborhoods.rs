//! Vertex- and edge-neighborhoods (paper §4.2, Figure 6).
//!
//! For every vertex `a` we store the list of its neighbors twice, in CSR
//! layout sharing one offset array:
//!
//! * the **vertex-neighborhood** `N^a`: `(neighbor, edge-order)` pairs
//!   sorted by neighbor id — drives Case 1 of coboundary enumeration;
//! * the **edge-neighborhood** `E^a`: `(edge-order, neighbor)` pairs
//!   sorted by edge order — drives Case 2.
//!
//! `edge_order(a, b)` — "what is the filtration order of edge {a,b}?" — is
//! the hot query of the whole system (§4.6). The sparse answer is a binary
//! search in the smaller vertex-neighborhood; the non-sparse variant
//! (DoryNS, `-D COMBIDX` in the paper) trades `O(n^2)` memory for an O(1)
//! packed-triangular table lookup.

use super::EdgeFiltration;

#[derive(Clone, Debug)]
pub struct Neighborhoods {
    pub n: u32,
    off: Vec<u32>,
    // Vertex-neighborhood arrays (sorted by neighbor id within a vertex).
    vn_vtx: Vec<u32>,
    vn_ord: Vec<u32>,
    // Edge-neighborhood arrays (sorted by edge order within a vertex).
    en_ord: Vec<u32>,
    en_vtx: Vec<u32>,
    /// DoryNS: packed strict-lower-triangular `n(n-1)/2` table of edge
    /// orders (`u32::MAX` = edge absent from the filtration).
    dense: Option<Vec<u32>>,
}

pub const NO_EDGE: u32 = u32::MAX;

impl Neighborhoods {
    /// Build from F1. `dense_lookup = true` selects the DoryNS layout.
    pub fn build(f: &EdgeFiltration, dense_lookup: bool) -> Self {
        let n = f.n as usize;
        let ne = f.n_edges();
        let mut off = vec![0u32; n + 1];
        for &(a, b) in &f.edges {
            off[a as usize + 1] += 1;
            off[b as usize + 1] += 1;
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let total = off[n] as usize;
        debug_assert_eq!(total, 2 * ne);

        // Fill the edge-neighborhood by walking edges in filtration order:
        // per-vertex runs come out already sorted by edge order.
        let mut cursor = off.clone();
        let mut en_ord = vec![0u32; total];
        let mut en_vtx = vec![0u32; total];
        for (o, &(a, b)) in f.edges.iter().enumerate() {
            let (o, a, b) = (o as u32, a as usize, b as usize);
            let ca = cursor[a] as usize;
            en_ord[ca] = o;
            en_vtx[ca] = f.edges[o as usize].1;
            cursor[a] += 1;
            let cb = cursor[b] as usize;
            en_ord[cb] = o;
            en_vtx[cb] = f.edges[o as usize].0;
            cursor[b] += 1;
        }

        // Vertex-neighborhood: same pairs re-sorted by neighbor id.
        let mut vn_vtx = vec![0u32; total];
        let mut vn_ord = vec![0u32; total];
        let mut scratch: Vec<(u32, u32)> = Vec::new();
        for a in 0..n {
            let (s, e) = (off[a] as usize, off[a + 1] as usize);
            scratch.clear();
            scratch.extend(en_vtx[s..e].iter().zip(&en_ord[s..e]).map(|(&v, &o)| (v, o)));
            scratch.sort_unstable();
            for (k, &(v, o)) in scratch.iter().enumerate() {
                vn_vtx[s + k] = v;
                vn_ord[s + k] = o;
            }
        }

        let dense = if dense_lookup {
            let mut tbl = vec![NO_EDGE; n * (n - 1) / 2];
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                let (hi, lo) = (b as usize, a as usize);
                tbl[hi * (hi - 1) / 2 + lo] = o as u32;
            }
            Some(tbl)
        } else {
            None
        };

        Self {
            n: f.n,
            off,
            vn_vtx,
            vn_ord,
            en_ord,
            en_vtx,
            dense,
        }
    }

    #[inline]
    pub fn degree(&self, a: u32) -> u32 {
        self.off[a as usize + 1] - self.off[a as usize]
    }

    /// `N^a` as `(neighbor ids, edge orders)`, sorted by neighbor id.
    #[inline]
    pub fn vn(&self, a: u32) -> (&[u32], &[u32]) {
        let (s, e) = (self.off[a as usize] as usize, self.off[a as usize + 1] as usize);
        (&self.vn_vtx[s..e], &self.vn_ord[s..e])
    }

    /// `E^a` as `(edge orders, neighbor ids)`, sorted by edge order.
    #[inline]
    pub fn en(&self, a: u32) -> (&[u32], &[u32]) {
        let (s, e) = (self.off[a as usize] as usize, self.off[a as usize + 1] as usize);
        (&self.en_ord[s..e], &self.en_vtx[s..e])
    }

    /// Order of edge `{a, b}` if present. The §4.6 hot path: O(1) with the
    /// dense table, binary search in the smaller neighborhood otherwise.
    #[inline]
    pub fn edge_order(&self, a: u32, b: u32) -> Option<u32> {
        debug_assert_ne!(a, b);
        if let Some(tbl) = &self.dense {
            let (hi, lo) = if a > b { (a as usize, b as usize) } else { (b as usize, a as usize) };
            let o = tbl[hi * (hi - 1) / 2 + lo];
            return if o == NO_EDGE { None } else { Some(o) };
        }
        let (qa, qb) = if self.degree(a) <= self.degree(b) {
            (a, b)
        } else {
            (b, a)
        };
        let (vtx, ord) = self.vn(qa);
        match vtx.binary_search(&qb) {
            Ok(i) => Some(ord[i]),
            Err(_) => None,
        }
    }

    /// First index in `N^a` whose neighbor id is >= `v`.
    #[inline]
    pub fn vn_lower_bound(&self, a: u32, v: u32) -> u32 {
        let (vtx, _) = self.vn(a);
        vtx.partition_point(|&x| x < v) as u32
    }

    /// First index in `E^a` whose edge order is >= `o`.
    #[inline]
    pub fn en_lower_bound(&self, a: u32, o: u32) -> u32 {
        let (ord, _) = self.en(a);
        ord.partition_point(|&x| x < o) as u32
    }

    pub fn is_dense(&self) -> bool {
        self.dense.is_some()
    }

    /// Measured heap bytes of the structure (paper App. E base memory).
    pub fn memory_bytes(&self) -> usize {
        4 * (self.off.len()
            + self.vn_vtx.len()
            + self.vn_ord.len()
            + self.en_ord.len()
            + self.en_vtx.len()
            + self.dense.as_ref().map_or(0, |d| d.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{MetricData, PointCloud};

    fn fixture() -> EdgeFiltration {
        // 5 points on a line with distinct gaps -> unique edge lengths.
        let pc = PointCloud::new(1, vec![0.0, 1.0, 2.3, 3.9, 5.8]);
        EdgeFiltration::build(&MetricData::Points(pc), 10.0)
    }

    #[test]
    fn en_sorted_by_order_vn_by_vertex() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for a in 0..f.n {
                let (ord, _) = nb.en(a);
                assert!(ord.windows(2).all(|w| w[0] < w[1]), "E^{a} sorted");
                let (vtx, _) = nb.vn(a);
                assert!(vtx.windows(2).all(|w| w[0] < w[1]), "N^{a} sorted");
            }
        }
    }

    #[test]
    fn edge_order_roundtrip_sparse_and_dense() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                assert_eq!(nb.edge_order(a, b), Some(o as u32));
                assert_eq!(nb.edge_order(b, a), Some(o as u32));
            }
        }
    }

    #[test]
    fn absent_edge_is_none() {
        let pc = PointCloud::new(1, vec![0.0, 1.0, 10.0]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 2.0);
        assert_eq!(f.n_edges(), 1);
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            assert_eq!(nb.edge_order(0, 1), Some(0));
            assert_eq!(nb.edge_order(0, 2), None);
            assert_eq!(nb.edge_order(1, 2), None);
        }
    }

    #[test]
    fn lower_bounds() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let (vtx, _) = nb.vn(0);
        let lb = nb.vn_lower_bound(0, 2);
        assert!(vtx[..lb as usize].iter().all(|&v| v < 2));
        assert!(vtx[lb as usize..].iter().all(|&v| v >= 2));
        let (ord, _) = nb.en(0);
        let lb = nb.en_lower_bound(0, 3);
        assert!(ord[..lb as usize].iter().all(|&o| o < 3));
        assert!(ord[lb as usize..].iter().all(|&o| o >= 3));
    }

    #[test]
    fn degrees_sum_to_twice_edges() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let total: u32 = (0..f.n).map(|a| nb.degree(a)).sum();
        assert_eq!(total as usize, 2 * f.n_edges());
    }
}
