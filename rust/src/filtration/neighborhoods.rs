//! Vertex- and edge-neighborhoods (paper §4.2, Figure 6).
//!
//! For every vertex `a` we store the list of its neighbors twice, in CSR
//! layout sharing one offset array:
//!
//! * the **vertex-neighborhood** `N^a`: `(neighbor, edge-order)` pairs
//!   sorted by neighbor id — drives Case 1 of coboundary enumeration;
//! * the **edge-neighborhood** `E^a`: `(edge-order, neighbor)` pairs
//!   sorted by edge order — drives Case 2.
//!
//! `edge_order(a, b)` — "what is the filtration order of edge {a,b}?" — is
//! the hot query of the whole system (§4.6). The sparse answer is a binary
//! search in the smaller vertex-neighborhood; the non-sparse variant
//! (DoryNS, `-D COMBIDX` in the paper) trades `O(n^2)` memory for an O(1)
//! packed-triangular table lookup.

//! With a pool ([`Neighborhoods::build_pooled`]) the CSR fill runs as
//! two-pass counting + scatter over edge chunks on the workers,
//! producing arrays byte-identical to the serial build: chunk counts
//! turn into deterministic per-chunk write cursors, so every vertex run
//! still comes out sorted by edge order regardless of steal schedule.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::{EdgeFiltration, FiltrationStats};
use crate::error::DoryError;
use crate::reduction::pool::{SharedSlice, ThreadPool};

/// The CSR arrays are `Arc`-shared so a [`Neighborhoods::truncated`]
/// view — the session layer's sub-τ query path — costs O(n) (per-vertex
/// `E^a` cut positions) instead of an array rebuild. A view hides every
/// edge with order `>= cap` behind the same accessors: `edge_order`
/// filters, `en` returns the per-vertex prefix (runs are sorted by
/// order), and `vn` stays full because its consumers re-check orders
/// against the column's own order, which is below any cap.
#[derive(Clone, Debug)]
pub struct Neighborhoods {
    pub n: u32,
    off: Arc<Vec<u32>>,
    // Vertex-neighborhood arrays (sorted by neighbor id within a vertex).
    vn_vtx: Arc<Vec<u32>>,
    vn_ord: Arc<Vec<u32>>,
    // Edge-neighborhood arrays (sorted by edge order within a vertex).
    en_ord: Arc<Vec<u32>>,
    en_vtx: Arc<Vec<u32>>,
    /// DoryNS: packed strict-lower-triangular `n(n-1)/2` table of edge
    /// orders (`u32::MAX` = edge absent from the filtration).
    dense: Option<Arc<Vec<u32>>>,
    /// Edge orders `>= cap` are treated as absent (truncated views);
    /// `NO_EDGE` = no cap. Real orders never reach `u32::MAX`.
    cap: u32,
    /// Per-vertex `E^a` run lengths under `cap` (`None` = full runs).
    en_len: Option<Arc<Vec<u32>>>,
}

pub const NO_EDGE: u32 = u32::MAX;

/// Slot count of the DoryNS packed strict-lower-triangular table,
/// refusing — before any allocation — sizes whose index arithmetic or
/// allocation would overflow. The cap also guarantees `hi * (hi - 1)`
/// in [`Neighborhoods::edge_order`] can never wrap: it is bounded by
/// `2 * slots`.
fn dense_table_slots(n: usize) -> Result<usize, DoryError> {
    match n.checked_mul(n.saturating_sub(1)).map(|x| x / 2) {
        Some(slots) if slots <= (isize::MAX as usize) / 8 => Ok(slots),
        _ => Err(DoryError::Overflow(format!(
            "Neighborhoods: the DoryNS dense edge-order table for n = {n} needs \
             n(n-1)/2 packed-triangular entries, which overflows the index space \
             or the allocation limit on this platform; use the sparse lookup \
             (dense_lookup = false / drop --ns)"
        ))),
    }
}

/// [`dense_table_slots`] after the build-entry guard already passed.
fn dense_slots_guarded(n: usize) -> usize {
    dense_table_slots(n).expect("guarded at build entry")
}

impl Neighborhoods {
    /// Build from F1. `dense_lookup = true` selects the DoryNS layout.
    /// Serial reference path; see [`Self::build_pooled`] for the
    /// front-end that runs on the engine's worker pool.
    pub fn build(f: &EdgeFiltration, dense_lookup: bool) -> Self {
        Self::build_pooled(f, dense_lookup, None, &mut FiltrationStats::default())
    }

    /// Build from F1, running the counting/scatter passes as pool work
    /// when a pool is given. Output arrays are byte-identical to
    /// [`Self::build`] for every pool size, chunk plan and steal
    /// schedule; `stats` records the CSR phase time and chunk count.
    /// Panicking compatibility wrapper over [`Self::try_build_pooled`]
    /// (the session layer takes the typed-error path instead).
    pub fn build_pooled(
        f: &EdgeFiltration,
        dense_lookup: bool,
        pool: Option<&ThreadPool>,
        stats: &mut FiltrationStats,
    ) -> Self {
        match Self::try_build_pooled(f, dense_lookup, pool, stats) {
            Ok(nb) => nb,
            Err(e) => panic!("{e}"),
        }
    }

    /// [`Self::build_pooled`] with the infeasible-size guard surfaced
    /// as a typed [`DoryError::Overflow`] instead of a panic.
    pub fn try_build_pooled(
        f: &EdgeFiltration,
        dense_lookup: bool,
        pool: Option<&ThreadPool>,
        stats: &mut FiltrationStats,
    ) -> Result<Self, DoryError> {
        if dense_lookup {
            // Refuse infeasible DoryNS sizes before any allocation.
            dense_table_slots(f.n as usize)?;
        }
        stats.nb_builds += 1;
        let t0 = Instant::now();
        let out = match pool {
            Some(pool) if pool.threads() > 1 && f.n_edges() > 0 => {
                Self::build_on_pool(f, dense_lookup, pool, stats)
            }
            _ => Self::build_serial(f, dense_lookup),
        };
        stats.nb_ns += t0.elapsed().as_nanos() as u64;
        Ok(out)
    }

    /// A view of this structure restricted to edge orders `< cap` — the
    /// neighborhoods of the prefix sub-filtration, without rebuilding
    /// any CSR array (the arrays are `Arc`-shared; only the per-vertex
    /// `E^a` cut positions are computed, O(n log deg)). Every accessor
    /// of the view behaves exactly as if built from
    /// [`EdgeFiltration::prefix`]`(cap)`: capped orders are absent from
    /// `edge_order` and `en`, and `vn` consumers re-check orders.
    pub fn truncated(&self, cap: u32) -> Neighborhoods {
        let cap = cap.min(self.cap);
        let n = self.n as usize;
        let mut en_len = Vec::with_capacity(n);
        for a in 0..n {
            let (s, e) = (self.off[a] as usize, self.off[a + 1] as usize);
            en_len.push(self.en_ord[s..e].partition_point(|&o| o < cap) as u32);
        }
        Neighborhoods {
            n: self.n,
            off: Arc::clone(&self.off),
            vn_vtx: Arc::clone(&self.vn_vtx),
            vn_ord: Arc::clone(&self.vn_ord),
            en_ord: Arc::clone(&self.en_ord),
            en_vtx: Arc::clone(&self.en_vtx),
            dense: self.dense.clone(),
            cap,
            en_len: Some(Arc::new(en_len)),
        }
    }

    fn build_serial(f: &EdgeFiltration, dense_lookup: bool) -> Self {
        let n = f.n as usize;
        let ne = f.n_edges();
        let mut off = vec![0u32; n + 1];
        for &(a, b) in &f.edges {
            off[a as usize + 1] += 1;
            off[b as usize + 1] += 1;
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let total = off[n] as usize;
        debug_assert_eq!(total, 2 * ne);

        // Fill the edge-neighborhood by walking edges in filtration order:
        // per-vertex runs come out already sorted by edge order.
        let mut cursor = off.clone();
        let mut en_ord = vec![0u32; total];
        let mut en_vtx = vec![0u32; total];
        for (o, &(a, b)) in f.edges.iter().enumerate() {
            let (o, a, b) = (o as u32, a as usize, b as usize);
            let ca = cursor[a] as usize;
            en_ord[ca] = o;
            en_vtx[ca] = f.edges[o as usize].1;
            cursor[a] += 1;
            let cb = cursor[b] as usize;
            en_ord[cb] = o;
            en_vtx[cb] = f.edges[o as usize].0;
            cursor[b] += 1;
        }

        // Vertex-neighborhood: same pairs re-sorted by neighbor id.
        let mut vn_vtx = vec![0u32; total];
        let mut vn_ord = vec![0u32; total];
        let mut scratch: Vec<(u32, u32)> = Vec::new();
        for a in 0..n {
            let (s, e) = (off[a] as usize, off[a + 1] as usize);
            scratch.clear();
            scratch.extend(en_vtx[s..e].iter().zip(&en_ord[s..e]).map(|(&v, &o)| (v, o)));
            scratch.sort_unstable();
            for (k, &(v, o)) in scratch.iter().enumerate() {
                vn_vtx[s + k] = v;
                vn_ord[s + k] = o;
            }
        }

        let dense = if dense_lookup {
            let mut tbl = vec![NO_EDGE; dense_slots_guarded(n)];
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                let (hi, lo) = (b as usize, a as usize);
                tbl[hi * (hi - 1) / 2 + lo] = o as u32;
            }
            Some(Arc::new(tbl))
        } else {
            None
        };

        Self {
            n: f.n,
            off: Arc::new(off),
            vn_vtx: Arc::new(vn_vtx),
            vn_ord: Arc::new(vn_ord),
            en_ord: Arc::new(en_ord),
            en_vtx: Arc::new(en_vtx),
            dense,
            cap: NO_EDGE,
            en_len: None,
        }
    }

    /// The pooled CSR build: (1) per-chunk incidence counts, (2) a
    /// serial prefix pass turning counts into per-chunk write cursors,
    /// (3) the edge-neighborhood scatter, (4) per-vertex re-sorts for
    /// the vertex-neighborhood, (5) the DoryNS table scatter. Within a
    /// chunk edges ascend and chunk cursor bases ascend with the chunk
    /// index, so every vertex run comes out sorted by edge order — the
    /// exact bytes of the serial fill.
    fn build_on_pool(
        f: &EdgeFiltration,
        dense_lookup: bool,
        pool: &ThreadPool,
        stats: &mut FiltrationStats,
    ) -> Self {
        let n = f.n as usize;
        let ne = f.n_edges();
        let threads = pool.threads();
        let n_chunks = (threads * 2).min(ne).max(1);
        let cb: Vec<usize> = (0..=n_chunks).map(|k| k * ne / n_chunks).collect();

        // Pass 1: count each chunk's incidences per vertex. The slots
        // stay in place through the prefix pass and are *taken* (not
        // cloned) by the scatter pass — one O(chunks × n) array set for
        // the whole build.
        let count_slots: Vec<Mutex<Vec<u32>>> =
            (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
        pool.run_stealing(n_chunks, 1, |_tid, range| {
            for c in range {
                let mut cnt = vec![0u32; n];
                for &(a, b) in &f.edges[cb[c]..cb[c + 1]] {
                    cnt[a as usize] += 1;
                    cnt[b as usize] += 1;
                }
                *count_slots[c].lock().unwrap() = cnt;
            }
        });

        // Serial prefix: `off` plus per-chunk base cursors (slot c at
        // vertex v becomes chunk c's first write position into vertex
        // v's run).
        let mut off = vec![0u32; n + 1];
        {
            let mut guards: Vec<_> = count_slots
                .iter()
                .map(|m| m.lock().unwrap())
                .collect();
            for v in 0..n {
                let mut acc = off[v];
                for g in guards.iter_mut() {
                    let t = g[v];
                    g[v] = acc;
                    acc += t;
                }
                off[v + 1] = acc;
            }
        }
        let total = off[n] as usize;
        debug_assert_eq!(total, 2 * ne);

        // Pass 2: scatter the edge-neighborhood at the precomputed
        // cursors (disjoint windows per chunk per vertex). Each chunk
        // takes ownership of its cursor array — exactly one worker ever
        // touches slot c.
        let mut en_ord = vec![0u32; total];
        let mut en_vtx = vec![0u32; total];
        {
            let so = SharedSlice::new(&mut en_ord);
            let sv = SharedSlice::new(&mut en_vtx);
            let count_slots = &count_slots;
            pool.run_stealing(n_chunks, 1, |_tid, range| {
                for c in range {
                    let mut cur = std::mem::take(&mut *count_slots[c].lock().unwrap());
                    for (k, &(a, b)) in f.edges[cb[c]..cb[c + 1]].iter().enumerate() {
                        let o = (cb[c] + k) as u32;
                        let ca = cur[a as usize] as usize;
                        cur[a as usize] += 1;
                        let cbx = cur[b as usize] as usize;
                        cur[b as usize] += 1;
                        // SAFETY: cursor windows of distinct chunks are
                        // disjoint by the prefix construction above.
                        unsafe {
                            so.write(ca, o);
                            sv.write(ca, b);
                            so.write(cbx, o);
                            sv.write(cbx, a);
                        }
                    }
                }
            });
        }
        drop(count_slots);

        // Vertex-neighborhood: per-vertex re-sort by neighbor id, tiled
        // over vertex ranges (each vertex writes its own run).
        let mut vn_vtx = vec![0u32; total];
        let mut vn_ord = vec![0u32; total];
        {
            let sx = SharedSlice::new(&mut vn_vtx);
            let so = SharedSlice::new(&mut vn_ord);
            let (en_vtx, en_ord, off) = (&en_vtx, &en_ord, &off);
            let grain = n.div_ceil(threads * 8).max(1);
            pool.run_stealing(n, grain, |_tid, vr| {
                let mut scratch: Vec<(u32, u32)> = Vec::new();
                for a in vr {
                    let (s, e) = (off[a] as usize, off[a + 1] as usize);
                    scratch.clear();
                    scratch.extend(
                        en_vtx[s..e].iter().zip(&en_ord[s..e]).map(|(&v, &o)| (v, o)),
                    );
                    scratch.sort_unstable();
                    for (k, &(v, o)) in scratch.iter().enumerate() {
                        // SAFETY: vertex runs are disjoint slices of the
                        // shared arrays.
                        unsafe {
                            sx.write(s + k, v);
                            so.write(s + k, o);
                        }
                    }
                }
            });
        }

        // DoryNS table: one unique slot per edge, scattered in chunks.
        let dense = if dense_lookup {
            let mut tbl = vec![NO_EDGE; dense_slots_guarded(n)];
            {
                let st = SharedSlice::new(&mut tbl);
                let grain = ne.div_ceil(threads * 8).max(1);
                pool.run_stealing(ne, grain, |_tid, er| {
                    for o in er {
                        let (a, b) = f.edges[o];
                        let (hi, lo) = (b as usize, a as usize);
                        // SAFETY: every edge owns a distinct table slot.
                        unsafe { st.write(hi * (hi - 1) / 2 + lo, o as u32) };
                    }
                });
            }
            Some(Arc::new(tbl))
        } else {
            None
        };

        stats.nb_chunks += n_chunks as u64;
        Self {
            n: f.n,
            off: Arc::new(off),
            vn_vtx: Arc::new(vn_vtx),
            vn_ord: Arc::new(vn_ord),
            en_ord: Arc::new(en_ord),
            en_vtx: Arc::new(en_vtx),
            dense,
            cap: NO_EDGE,
            en_len: None,
        }
    }

    #[inline]
    pub fn degree(&self, a: u32) -> u32 {
        self.off[a as usize + 1] - self.off[a as usize]
    }

    /// `N^a` as `(neighbor ids, edge orders)`, sorted by neighbor id.
    #[inline]
    pub fn vn(&self, a: u32) -> (&[u32], &[u32]) {
        let (s, e) = (self.off[a as usize] as usize, self.off[a as usize + 1] as usize);
        (&self.vn_vtx[s..e], &self.vn_ord[s..e])
    }

    /// `E^a` as `(edge orders, neighbor ids)`, sorted by edge order.
    /// Truncated views return the per-vertex prefix below the cap (runs
    /// are sorted by order, so the cut is a precomputed prefix length).
    #[inline]
    pub fn en(&self, a: u32) -> (&[u32], &[u32]) {
        let s = self.off[a as usize] as usize;
        let e = match &self.en_len {
            Some(len) => s + len[a as usize] as usize,
            None => self.off[a as usize + 1] as usize,
        };
        (&self.en_ord[s..e], &self.en_vtx[s..e])
    }

    /// Order of edge `{a, b}` if present. The §4.6 hot path: O(1) with the
    /// dense table, binary search in the smaller neighborhood otherwise.
    /// Truncated views report capped orders as absent.
    #[inline]
    pub fn edge_order(&self, a: u32, b: u32) -> Option<u32> {
        debug_assert_ne!(a, b);
        if let Some(tbl) = &self.dense {
            let (hi, lo) = if a > b { (a as usize, b as usize) } else { (b as usize, a as usize) };
            let o = tbl[hi * (hi - 1) / 2 + lo];
            // `NO_EDGE >= cap` always, so one compare covers both the
            // absent sentinel and truncated-view filtering.
            return if o >= self.cap { None } else { Some(o) };
        }
        let (qa, qb) = if self.degree(a) <= self.degree(b) {
            (a, b)
        } else {
            (b, a)
        };
        let (vtx, ord) = self.vn(qa);
        match vtx.binary_search(&qb) {
            Ok(i) if ord[i] < self.cap => Some(ord[i]),
            _ => None,
        }
    }

    /// First index in `N^a` whose neighbor id is >= `v`.
    #[inline]
    pub fn vn_lower_bound(&self, a: u32, v: u32) -> u32 {
        let (vtx, _) = self.vn(a);
        vtx.partition_point(|&x| x < v) as u32
    }

    /// First index in `E^a` whose edge order is >= `o`.
    #[inline]
    pub fn en_lower_bound(&self, a: u32, o: u32) -> u32 {
        let (ord, _) = self.en(a);
        ord.partition_point(|&x| x < o) as u32
    }

    pub fn is_dense(&self) -> bool {
        self.dense.is_some()
    }

    /// Measured heap bytes of the structure (paper App. E base memory).
    /// Truncated views share the backing arrays with their parent, so
    /// they report the full arrays plus their own O(n) cut table.
    pub fn memory_bytes(&self) -> usize {
        4 * (self.off.len()
            + self.vn_vtx.len()
            + self.vn_ord.len()
            + self.en_ord.len()
            + self.en_vtx.len()
            + self.dense.as_ref().map_or(0, |d| d.len())
            + self.en_len.as_ref().map_or(0, |l| l.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{MetricData, PointCloud};

    fn fixture() -> EdgeFiltration {
        // 5 points on a line with distinct gaps -> unique edge lengths.
        let pc = PointCloud::new(1, vec![0.0, 1.0, 2.3, 3.9, 5.8]);
        EdgeFiltration::build(&MetricData::Points(pc), 10.0)
    }

    #[test]
    fn en_sorted_by_order_vn_by_vertex() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for a in 0..f.n {
                let (ord, _) = nb.en(a);
                assert!(ord.windows(2).all(|w| w[0] < w[1]), "E^{a} sorted");
                let (vtx, _) = nb.vn(a);
                assert!(vtx.windows(2).all(|w| w[0] < w[1]), "N^{a} sorted");
            }
        }
    }

    #[test]
    fn edge_order_roundtrip_sparse_and_dense() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                assert_eq!(nb.edge_order(a, b), Some(o as u32));
                assert_eq!(nb.edge_order(b, a), Some(o as u32));
            }
        }
    }

    #[test]
    fn absent_edge_is_none() {
        let pc = PointCloud::new(1, vec![0.0, 1.0, 10.0]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 2.0);
        assert_eq!(f.n_edges(), 1);
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            assert_eq!(nb.edge_order(0, 1), Some(0));
            assert_eq!(nb.edge_order(0, 2), None);
            assert_eq!(nb.edge_order(1, 2), None);
        }
    }

    #[test]
    fn lower_bounds() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let (vtx, _) = nb.vn(0);
        let lb = nb.vn_lower_bound(0, 2);
        assert!(vtx[..lb as usize].iter().all(|&v| v < 2));
        assert!(vtx[lb as usize..].iter().all(|&v| v >= 2));
        let (ord, _) = nb.en(0);
        let lb = nb.en_lower_bound(0, 3);
        assert!(ord[..lb as usize].iter().all(|&o| o < 3));
        assert!(ord[lb as usize..].iter().all(|&o| o >= 3));
    }

    #[test]
    fn degrees_sum_to_twice_edges() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let total: u32 = (0..f.n).map(|a| nb.degree(a)).sum();
        assert_eq!(total as usize, 2 * f.n_edges());
    }

    #[test]
    fn pooled_build_matches_serial_arrays() {
        use crate::geometry::MetricData;
        use crate::util::rng::Pcg32;
        let pool = ThreadPool::new(4);
        for seed in 0..6u64 {
            let mut rng = Pcg32::new(0xC5A + seed);
            let n = 10 + rng.gen_range(30) as usize;
            let pc = PointCloud::new(3, (0..n * 3).map(|_| rng.next_f64()).collect());
            let f = EdgeFiltration::build(&MetricData::Points(pc), rng.uniform(0.4, 1.0));
            for dense in [false, true] {
                let want = Neighborhoods::build(&f, dense);
                let mut stats = FiltrationStats::default();
                let got = Neighborhoods::build_pooled(&f, dense, Some(&pool), &mut stats);
                assert_eq!(got.off, want.off, "seed={seed} dense={dense}");
                assert_eq!(got.en_ord, want.en_ord, "seed={seed} dense={dense}");
                assert_eq!(got.en_vtx, want.en_vtx, "seed={seed} dense={dense}");
                assert_eq!(got.vn_vtx, want.vn_vtx, "seed={seed} dense={dense}");
                assert_eq!(got.vn_ord, want.vn_ord, "seed={seed} dense={dense}");
                assert_eq!(got.dense, want.dense, "seed={seed} dense={dense}");
                assert_eq!(got.memory_bytes(), want.memory_bytes());
                if f.n_edges() > 0 {
                    assert!(stats.nb_chunks > 0, "CSR fill must run on the pool");
                    assert!(stats.nb_ns > 0);
                }
            }
        }
    }

    #[test]
    fn truncated_view_equals_rebuilt_prefix() {
        use crate::geometry::MetricData;
        use crate::util::rng::Pcg32;
        for seed in 0..4u64 {
            let mut rng = Pcg32::new(0xBEEF + seed);
            let n = 12 + rng.gen_range(20) as usize;
            let pc = PointCloud::new(3, (0..n * 3).map(|_| rng.next_f64()).collect());
            let md = MetricData::Points(pc);
            let f = EdgeFiltration::build(&md, 1.1);
            for dense in [false, true] {
                let full = Neighborhoods::build(&f, dense);
                for cap_frac in [0usize, 1, 2, 3] {
                    let m = f.n_edges() * cap_frac / 3;
                    let view = full.truncated(m as u32);
                    let fp = f.prefix(m, f.values.get(m.wrapping_sub(1)).copied().unwrap_or(0.0));
                    let want = Neighborhoods::build(&fp, dense);
                    // edge_order agrees with the rebuilt prefix on every
                    // vertex pair (capped orders absent).
                    for a in 0..f.n {
                        for b in (a + 1)..f.n {
                            assert_eq!(
                                view.edge_order(a, b),
                                want.edge_order(a, b),
                                "seed={seed} dense={dense} m={m} ({a},{b})"
                            );
                        }
                        // E^a runs agree element-wise.
                        let (vo, vv) = view.en(a);
                        let (wo, wv) = want.en(a);
                        assert_eq!(vo, wo, "seed={seed} dense={dense} m={m} E^{a} orders");
                        assert_eq!(vv, wv, "seed={seed} dense={dense} m={m} E^{a} vertices");
                        // en_lower_bound probes agree for every in-range order.
                        for probe in [0u32, (m as u32) / 2, m as u32] {
                            assert_eq!(
                                view.en_lower_bound(a, probe),
                                want.en_lower_bound(a, probe),
                                "seed={seed} m={m} probe={probe}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn truncated_view_is_cheap_and_idempotent() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let v2 = nb.truncated(2);
        // Re-truncating a view tightens, never widens.
        let v1 = v2.truncated(3);
        for a in 0..f.n {
            let (ord, _) = v1.en(a);
            assert!(ord.iter().all(|&o| o < 2), "cap must not widen");
        }
        assert!(v2.memory_bytes() >= nb.memory_bytes(), "view adds its cut table");
    }

    #[test]
    fn try_build_reports_overflow_as_typed_error() {
        let f = EdgeFiltration {
            n: u32::MAX - 2,
            edges: Vec::new(),
            values: Vec::new(),
            tau_max: 1.0,
        };
        let e = Neighborhoods::try_build_pooled(
            &f,
            true,
            None,
            &mut FiltrationStats::default(),
        )
        .unwrap_err();
        assert!(matches!(e, crate::error::DoryError::Overflow(_)), "{e}");
        assert!(e.to_string().contains("DoryNS dense edge-order table"));
    }

    #[test]
    #[should_panic(expected = "DoryNS dense edge-order table")]
    fn dense_mode_refuses_packed_index_overflow() {
        // A fake filtration with a huge vertex count and no edges: the
        // guard must fire before any table (or even `off`) allocation.
        let f = EdgeFiltration {
            n: u32::MAX - 2,
            edges: Vec::new(),
            values: Vec::new(),
            tau_max: 1.0,
        };
        let _ = Neighborhoods::build(&f, true);
    }
}
