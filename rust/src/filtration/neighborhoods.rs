//! Vertex- and edge-neighborhoods (paper §4.2, Figure 6).
//!
//! For every vertex `a` we store the list of its neighbors twice, in CSR
//! layout sharing one offset array:
//!
//! * the **vertex-neighborhood** `N^a`: `(neighbor, edge-order)` pairs
//!   sorted by neighbor id — drives Case 1 of coboundary enumeration;
//! * the **edge-neighborhood** `E^a`: `(edge-order, neighbor)` pairs
//!   sorted by edge order — drives Case 2.
//!
//! `edge_order(a, b)` — "what is the filtration order of edge {a,b}?" — is
//! the hot query of the whole system (§4.6). The sparse answer is a binary
//! search in the smaller vertex-neighborhood; the non-sparse variant
//! (DoryNS, `-D COMBIDX` in the paper) trades `O(n^2)` memory for an O(1)
//! packed-triangular table lookup.

//! With a pool ([`Neighborhoods::build_pooled`]) the CSR fill runs as
//! two-pass counting + scatter over edge chunks on the workers,
//! producing arrays byte-identical to the serial build: chunk counts
//! turn into deterministic per-chunk write cursors, so every vertex run
//! still comes out sorted by edge order regardless of steal schedule.

use std::sync::Mutex;
use std::time::Instant;

use super::{EdgeFiltration, FiltrationStats};
use crate::reduction::pool::{SharedSlice, ThreadPool};

#[derive(Clone, Debug)]
pub struct Neighborhoods {
    pub n: u32,
    off: Vec<u32>,
    // Vertex-neighborhood arrays (sorted by neighbor id within a vertex).
    vn_vtx: Vec<u32>,
    vn_ord: Vec<u32>,
    // Edge-neighborhood arrays (sorted by edge order within a vertex).
    en_ord: Vec<u32>,
    en_vtx: Vec<u32>,
    /// DoryNS: packed strict-lower-triangular `n(n-1)/2` table of edge
    /// orders (`u32::MAX` = edge absent from the filtration).
    dense: Option<Vec<u32>>,
}

pub const NO_EDGE: u32 = u32::MAX;

/// Slot count of the DoryNS packed strict-lower-triangular table,
/// refusing — before any allocation — sizes whose index arithmetic or
/// allocation would overflow. The cap also guarantees `hi * (hi - 1)`
/// in [`Neighborhoods::edge_order`] can never wrap: it is bounded by
/// `2 * slots`.
fn dense_table_slots(n: usize) -> usize {
    match n.checked_mul(n.saturating_sub(1)).map(|x| x / 2) {
        Some(slots) if slots <= (isize::MAX as usize) / 8 => slots,
        _ => panic!(
            "Neighborhoods: the DoryNS dense edge-order table for n = {n} needs \
             n(n-1)/2 packed-triangular entries, which overflows the index space \
             or the allocation limit on this platform; use the sparse lookup \
             (dense_lookup = false / drop --ns)"
        ),
    }
}

impl Neighborhoods {
    /// Build from F1. `dense_lookup = true` selects the DoryNS layout.
    /// Serial reference path; see [`Self::build_pooled`] for the
    /// front-end that runs on the engine's worker pool.
    pub fn build(f: &EdgeFiltration, dense_lookup: bool) -> Self {
        Self::build_pooled(f, dense_lookup, None, &mut FiltrationStats::default())
    }

    /// Build from F1, running the counting/scatter passes as pool work
    /// when a pool is given. Output arrays are byte-identical to
    /// [`Self::build`] for every pool size, chunk plan and steal
    /// schedule; `stats` records the CSR phase time and chunk count.
    pub fn build_pooled(
        f: &EdgeFiltration,
        dense_lookup: bool,
        pool: Option<&ThreadPool>,
        stats: &mut FiltrationStats,
    ) -> Self {
        if dense_lookup {
            // Refuse infeasible DoryNS sizes before any allocation.
            dense_table_slots(f.n as usize);
        }
        let t0 = Instant::now();
        let out = match pool {
            Some(pool) if pool.threads() > 1 && f.n_edges() > 0 => {
                Self::build_on_pool(f, dense_lookup, pool, stats)
            }
            _ => Self::build_serial(f, dense_lookup),
        };
        stats.nb_ns += t0.elapsed().as_nanos() as u64;
        out
    }

    fn build_serial(f: &EdgeFiltration, dense_lookup: bool) -> Self {
        let n = f.n as usize;
        let ne = f.n_edges();
        let mut off = vec![0u32; n + 1];
        for &(a, b) in &f.edges {
            off[a as usize + 1] += 1;
            off[b as usize + 1] += 1;
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let total = off[n] as usize;
        debug_assert_eq!(total, 2 * ne);

        // Fill the edge-neighborhood by walking edges in filtration order:
        // per-vertex runs come out already sorted by edge order.
        let mut cursor = off.clone();
        let mut en_ord = vec![0u32; total];
        let mut en_vtx = vec![0u32; total];
        for (o, &(a, b)) in f.edges.iter().enumerate() {
            let (o, a, b) = (o as u32, a as usize, b as usize);
            let ca = cursor[a] as usize;
            en_ord[ca] = o;
            en_vtx[ca] = f.edges[o as usize].1;
            cursor[a] += 1;
            let cb = cursor[b] as usize;
            en_ord[cb] = o;
            en_vtx[cb] = f.edges[o as usize].0;
            cursor[b] += 1;
        }

        // Vertex-neighborhood: same pairs re-sorted by neighbor id.
        let mut vn_vtx = vec![0u32; total];
        let mut vn_ord = vec![0u32; total];
        let mut scratch: Vec<(u32, u32)> = Vec::new();
        for a in 0..n {
            let (s, e) = (off[a] as usize, off[a + 1] as usize);
            scratch.clear();
            scratch.extend(en_vtx[s..e].iter().zip(&en_ord[s..e]).map(|(&v, &o)| (v, o)));
            scratch.sort_unstable();
            for (k, &(v, o)) in scratch.iter().enumerate() {
                vn_vtx[s + k] = v;
                vn_ord[s + k] = o;
            }
        }

        let dense = if dense_lookup {
            let mut tbl = vec![NO_EDGE; dense_table_slots(n)];
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                let (hi, lo) = (b as usize, a as usize);
                tbl[hi * (hi - 1) / 2 + lo] = o as u32;
            }
            Some(tbl)
        } else {
            None
        };

        Self {
            n: f.n,
            off,
            vn_vtx,
            vn_ord,
            en_ord,
            en_vtx,
            dense,
        }
    }

    /// The pooled CSR build: (1) per-chunk incidence counts, (2) a
    /// serial prefix pass turning counts into per-chunk write cursors,
    /// (3) the edge-neighborhood scatter, (4) per-vertex re-sorts for
    /// the vertex-neighborhood, (5) the DoryNS table scatter. Within a
    /// chunk edges ascend and chunk cursor bases ascend with the chunk
    /// index, so every vertex run comes out sorted by edge order — the
    /// exact bytes of the serial fill.
    fn build_on_pool(
        f: &EdgeFiltration,
        dense_lookup: bool,
        pool: &ThreadPool,
        stats: &mut FiltrationStats,
    ) -> Self {
        let n = f.n as usize;
        let ne = f.n_edges();
        let threads = pool.threads();
        let n_chunks = (threads * 2).min(ne).max(1);
        let cb: Vec<usize> = (0..=n_chunks).map(|k| k * ne / n_chunks).collect();

        // Pass 1: count each chunk's incidences per vertex. The slots
        // stay in place through the prefix pass and are *taken* (not
        // cloned) by the scatter pass — one O(chunks × n) array set for
        // the whole build.
        let count_slots: Vec<Mutex<Vec<u32>>> =
            (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
        pool.run_stealing(n_chunks, 1, |_tid, range| {
            for c in range {
                let mut cnt = vec![0u32; n];
                for &(a, b) in &f.edges[cb[c]..cb[c + 1]] {
                    cnt[a as usize] += 1;
                    cnt[b as usize] += 1;
                }
                *count_slots[c].lock().unwrap() = cnt;
            }
        });

        // Serial prefix: `off` plus per-chunk base cursors (slot c at
        // vertex v becomes chunk c's first write position into vertex
        // v's run).
        let mut off = vec![0u32; n + 1];
        {
            let mut guards: Vec<_> = count_slots
                .iter()
                .map(|m| m.lock().unwrap())
                .collect();
            for v in 0..n {
                let mut acc = off[v];
                for g in guards.iter_mut() {
                    let t = g[v];
                    g[v] = acc;
                    acc += t;
                }
                off[v + 1] = acc;
            }
        }
        let total = off[n] as usize;
        debug_assert_eq!(total, 2 * ne);

        // Pass 2: scatter the edge-neighborhood at the precomputed
        // cursors (disjoint windows per chunk per vertex). Each chunk
        // takes ownership of its cursor array — exactly one worker ever
        // touches slot c.
        let mut en_ord = vec![0u32; total];
        let mut en_vtx = vec![0u32; total];
        {
            let so = SharedSlice::new(&mut en_ord);
            let sv = SharedSlice::new(&mut en_vtx);
            let count_slots = &count_slots;
            pool.run_stealing(n_chunks, 1, |_tid, range| {
                for c in range {
                    let mut cur = std::mem::take(&mut *count_slots[c].lock().unwrap());
                    for (k, &(a, b)) in f.edges[cb[c]..cb[c + 1]].iter().enumerate() {
                        let o = (cb[c] + k) as u32;
                        let ca = cur[a as usize] as usize;
                        cur[a as usize] += 1;
                        let cbx = cur[b as usize] as usize;
                        cur[b as usize] += 1;
                        // SAFETY: cursor windows of distinct chunks are
                        // disjoint by the prefix construction above.
                        unsafe {
                            so.write(ca, o);
                            sv.write(ca, b);
                            so.write(cbx, o);
                            sv.write(cbx, a);
                        }
                    }
                }
            });
        }
        drop(count_slots);

        // Vertex-neighborhood: per-vertex re-sort by neighbor id, tiled
        // over vertex ranges (each vertex writes its own run).
        let mut vn_vtx = vec![0u32; total];
        let mut vn_ord = vec![0u32; total];
        {
            let sx = SharedSlice::new(&mut vn_vtx);
            let so = SharedSlice::new(&mut vn_ord);
            let (en_vtx, en_ord, off) = (&en_vtx, &en_ord, &off);
            let grain = n.div_ceil(threads * 8).max(1);
            pool.run_stealing(n, grain, |_tid, vr| {
                let mut scratch: Vec<(u32, u32)> = Vec::new();
                for a in vr {
                    let (s, e) = (off[a] as usize, off[a + 1] as usize);
                    scratch.clear();
                    scratch.extend(
                        en_vtx[s..e].iter().zip(&en_ord[s..e]).map(|(&v, &o)| (v, o)),
                    );
                    scratch.sort_unstable();
                    for (k, &(v, o)) in scratch.iter().enumerate() {
                        // SAFETY: vertex runs are disjoint slices of the
                        // shared arrays.
                        unsafe {
                            sx.write(s + k, v);
                            so.write(s + k, o);
                        }
                    }
                }
            });
        }

        // DoryNS table: one unique slot per edge, scattered in chunks.
        let dense = if dense_lookup {
            let mut tbl = vec![NO_EDGE; dense_table_slots(n)];
            {
                let st = SharedSlice::new(&mut tbl);
                let grain = ne.div_ceil(threads * 8).max(1);
                pool.run_stealing(ne, grain, |_tid, er| {
                    for o in er {
                        let (a, b) = f.edges[o];
                        let (hi, lo) = (b as usize, a as usize);
                        // SAFETY: every edge owns a distinct table slot.
                        unsafe { st.write(hi * (hi - 1) / 2 + lo, o as u32) };
                    }
                });
            }
            Some(tbl)
        } else {
            None
        };

        stats.nb_chunks += n_chunks as u64;
        Self {
            n: f.n,
            off,
            vn_vtx,
            vn_ord,
            en_ord,
            en_vtx,
            dense,
        }
    }

    #[inline]
    pub fn degree(&self, a: u32) -> u32 {
        self.off[a as usize + 1] - self.off[a as usize]
    }

    /// `N^a` as `(neighbor ids, edge orders)`, sorted by neighbor id.
    #[inline]
    pub fn vn(&self, a: u32) -> (&[u32], &[u32]) {
        let (s, e) = (self.off[a as usize] as usize, self.off[a as usize + 1] as usize);
        (&self.vn_vtx[s..e], &self.vn_ord[s..e])
    }

    /// `E^a` as `(edge orders, neighbor ids)`, sorted by edge order.
    #[inline]
    pub fn en(&self, a: u32) -> (&[u32], &[u32]) {
        let (s, e) = (self.off[a as usize] as usize, self.off[a as usize + 1] as usize);
        (&self.en_ord[s..e], &self.en_vtx[s..e])
    }

    /// Order of edge `{a, b}` if present. The §4.6 hot path: O(1) with the
    /// dense table, binary search in the smaller neighborhood otherwise.
    #[inline]
    pub fn edge_order(&self, a: u32, b: u32) -> Option<u32> {
        debug_assert_ne!(a, b);
        if let Some(tbl) = &self.dense {
            let (hi, lo) = if a > b { (a as usize, b as usize) } else { (b as usize, a as usize) };
            let o = tbl[hi * (hi - 1) / 2 + lo];
            return if o == NO_EDGE { None } else { Some(o) };
        }
        let (qa, qb) = if self.degree(a) <= self.degree(b) {
            (a, b)
        } else {
            (b, a)
        };
        let (vtx, ord) = self.vn(qa);
        match vtx.binary_search(&qb) {
            Ok(i) => Some(ord[i]),
            Err(_) => None,
        }
    }

    /// First index in `N^a` whose neighbor id is >= `v`.
    #[inline]
    pub fn vn_lower_bound(&self, a: u32, v: u32) -> u32 {
        let (vtx, _) = self.vn(a);
        vtx.partition_point(|&x| x < v) as u32
    }

    /// First index in `E^a` whose edge order is >= `o`.
    #[inline]
    pub fn en_lower_bound(&self, a: u32, o: u32) -> u32 {
        let (ord, _) = self.en(a);
        ord.partition_point(|&x| x < o) as u32
    }

    pub fn is_dense(&self) -> bool {
        self.dense.is_some()
    }

    /// Measured heap bytes of the structure (paper App. E base memory).
    pub fn memory_bytes(&self) -> usize {
        4 * (self.off.len()
            + self.vn_vtx.len()
            + self.vn_ord.len()
            + self.en_ord.len()
            + self.en_vtx.len()
            + self.dense.as_ref().map_or(0, |d| d.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{MetricData, PointCloud};

    fn fixture() -> EdgeFiltration {
        // 5 points on a line with distinct gaps -> unique edge lengths.
        let pc = PointCloud::new(1, vec![0.0, 1.0, 2.3, 3.9, 5.8]);
        EdgeFiltration::build(&MetricData::Points(pc), 10.0)
    }

    #[test]
    fn en_sorted_by_order_vn_by_vertex() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for a in 0..f.n {
                let (ord, _) = nb.en(a);
                assert!(ord.windows(2).all(|w| w[0] < w[1]), "E^{a} sorted");
                let (vtx, _) = nb.vn(a);
                assert!(vtx.windows(2).all(|w| w[0] < w[1]), "N^{a} sorted");
            }
        }
    }

    #[test]
    fn edge_order_roundtrip_sparse_and_dense() {
        let f = fixture();
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            for (o, &(a, b)) in f.edges.iter().enumerate() {
                assert_eq!(nb.edge_order(a, b), Some(o as u32));
                assert_eq!(nb.edge_order(b, a), Some(o as u32));
            }
        }
    }

    #[test]
    fn absent_edge_is_none() {
        let pc = PointCloud::new(1, vec![0.0, 1.0, 10.0]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 2.0);
        assert_eq!(f.n_edges(), 1);
        for dense in [false, true] {
            let nb = Neighborhoods::build(&f, dense);
            assert_eq!(nb.edge_order(0, 1), Some(0));
            assert_eq!(nb.edge_order(0, 2), None);
            assert_eq!(nb.edge_order(1, 2), None);
        }
    }

    #[test]
    fn lower_bounds() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let (vtx, _) = nb.vn(0);
        let lb = nb.vn_lower_bound(0, 2);
        assert!(vtx[..lb as usize].iter().all(|&v| v < 2));
        assert!(vtx[lb as usize..].iter().all(|&v| v >= 2));
        let (ord, _) = nb.en(0);
        let lb = nb.en_lower_bound(0, 3);
        assert!(ord[..lb as usize].iter().all(|&o| o < 3));
        assert!(ord[lb as usize..].iter().all(|&o| o >= 3));
    }

    #[test]
    fn degrees_sum_to_twice_edges() {
        let f = fixture();
        let nb = Neighborhoods::build(&f, false);
        let total: u32 = (0..f.n).map(|a| nb.degree(a)).sum();
        assert_eq!(total as usize, 2 * f.n_edges());
    }

    #[test]
    fn pooled_build_matches_serial_arrays() {
        use crate::geometry::MetricData;
        use crate::util::rng::Pcg32;
        let pool = ThreadPool::new(4);
        for seed in 0..6u64 {
            let mut rng = Pcg32::new(0xC5A + seed);
            let n = 10 + rng.gen_range(30) as usize;
            let pc = PointCloud::new(3, (0..n * 3).map(|_| rng.next_f64()).collect());
            let f = EdgeFiltration::build(&MetricData::Points(pc), rng.uniform(0.4, 1.0));
            for dense in [false, true] {
                let want = Neighborhoods::build(&f, dense);
                let mut stats = FiltrationStats::default();
                let got = Neighborhoods::build_pooled(&f, dense, Some(&pool), &mut stats);
                assert_eq!(got.off, want.off, "seed={seed} dense={dense}");
                assert_eq!(got.en_ord, want.en_ord, "seed={seed} dense={dense}");
                assert_eq!(got.en_vtx, want.en_vtx, "seed={seed} dense={dense}");
                assert_eq!(got.vn_vtx, want.vn_vtx, "seed={seed} dense={dense}");
                assert_eq!(got.vn_ord, want.vn_ord, "seed={seed} dense={dense}");
                assert_eq!(got.dense, want.dense, "seed={seed} dense={dense}");
                assert_eq!(got.memory_bytes(), want.memory_bytes());
                if f.n_edges() > 0 {
                    assert!(stats.nb_chunks > 0, "CSR fill must run on the pool");
                    assert!(stats.nb_ns > 0);
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "DoryNS dense edge-order table")]
    fn dense_mode_refuses_packed_index_overflow() {
        // A fake filtration with a huge vertex count and no edges: the
        // guard must fire before any table (or even `off`) allocation.
        let f = EdgeFiltration {
            n: u32::MAX - 2,
            edges: Vec::new(),
            values: Vec::new(),
            tau_max: 1.0,
        };
        let _ = Neighborhoods::build(&f, true);
    }
}
