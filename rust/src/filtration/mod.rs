//! VR edge filtration (`F1`), vertex-/edge-neighborhoods, paired indexing.
//!
//! Paper §4: the filtration for 1-simplices is the list of edges sorted by
//! length; 2-/3-simplices are *never* materialized — they are identified by
//! paired keys `⟨primary, secondary⟩` (§4.1) and enumerated on the fly from
//! the neighborhoods (§4.2).

pub mod neighborhoods;
pub mod sparsify;
pub mod paired;

pub use neighborhoods::Neighborhoods;
pub use paired::Key;

use crate::geometry::MetricData;

/// The 1-skeleton filtration: edges sorted ascending by (length, a, b).
///
/// Edge *order* (its index in `edges`) is the unit every higher-dimensional
/// key is built from; `values[o]` recovers the filtration parameter.
#[derive(Clone, Debug)]
pub struct EdgeFiltration {
    pub n: u32,
    /// `edges[o] = (a, b)` with `a < b`, sorted ascending by value.
    pub edges: Vec<(u32, u32)>,
    /// `values[o]` = length of edge `o`; non-decreasing.
    pub values: Vec<f64>,
    /// Max permissible filtration parameter used to build this filtration.
    pub tau_max: f64,
}

impl EdgeFiltration {
    /// Build F1 from any metric input, keeping edges with `d <= tau_max`.
    pub fn build(data: &MetricData, tau_max: f64) -> Self {
        let n = data.n();
        assert!(n < u32::MAX as usize, "vertex count must fit u32");
        let mut raw: Vec<(f64, u32, u32)> = Vec::new();
        match data {
            MetricData::Points(pc) => {
                for i in 0..n {
                    let pi = pc.point(i);
                    for j in (i + 1)..n {
                        let pj = pc.point(j);
                        let mut s = 0.0;
                        for k in 0..pc.dim {
                            let d = pi[k] - pj[k];
                            s += d * d;
                        }
                        let d = s.sqrt();
                        if d <= tau_max {
                            raw.push((d, i as u32, j as u32));
                        }
                    }
                }
            }
            MetricData::Dense(dd) => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        let d = dd.get(i, j);
                        if d <= tau_max {
                            raw.push((d, i as u32, j as u32));
                        }
                    }
                }
            }
            MetricData::Sparse(sd) => {
                for &(u, v, d) in &sd.entries {
                    debug_assert!(u < v);
                    if d <= tau_max {
                        raw.push((d, u, v));
                    }
                }
            }
        }
        Self::from_weighted_edges(n as u32, raw, tau_max)
    }

    /// Build from an explicit weighted edge list (deduplicated by caller).
    pub fn from_weighted_edges(n: u32, mut raw: Vec<(f64, u32, u32)>, tau_max: f64) -> Self {
        // Deterministic total order: by length, ties by (a, b).
        raw.sort_unstable_by(|x, y| {
            x.0.partial_cmp(&y.0)
                .unwrap()
                .then(x.1.cmp(&y.1))
                .then(x.2.cmp(&y.2))
        });
        let mut edges = Vec::with_capacity(raw.len());
        let mut values = Vec::with_capacity(raw.len());
        for (d, a, b) in raw {
            edges.push((a, b));
            values.push(d);
        }
        Self {
            n,
            edges,
            values,
            tau_max,
        }
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Filtration value of a triangle/tetrahedron key = value of its diameter.
    #[inline]
    pub fn key_value(&self, key: Key) -> f64 {
        self.values[key.p as usize]
    }

    /// Base memory model from paper App. E: `(3n + 12 n_e) * 4` bytes.
    pub fn base_memory_model_bytes(&self) -> usize {
        (3 * self.n as usize + 12 * self.n_edges()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{DenseDistances, PointCloud, SparseDistances};

    fn square_cloud() -> MetricData {
        // Unit square: 4 edges of length 1, 2 diagonals of length sqrt(2).
        MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0],
        ))
    }

    #[test]
    fn sorted_and_thresholded() {
        let f = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f.n_edges(), 6);
        for w in f.values.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!((f.values[3] - 1.0).abs() < 1e-12);
        assert!((f.values[4] - 2f64.sqrt()).abs() < 1e-12);

        let f = EdgeFiltration::build(&square_cloud(), 1.1);
        assert_eq!(f.n_edges(), 4, "diagonals filtered");
    }

    #[test]
    fn ties_broken_deterministically() {
        let f1 = EdgeFiltration::build(&square_cloud(), 2.0);
        let f2 = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f1.edges, f2.edges);
        // Ties: (0,1),(0,3),(1,2),(2,3) all length 1, ordered lexicographically.
        assert_eq!(f1.edges[0], (0, 1));
        assert_eq!(f1.edges[1], (0, 3));
    }

    #[test]
    fn dense_and_sparse_agree_with_points() {
        let md = square_cloud();
        let pc = match &md {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let dd = MetricData::Dense(DenseDistances::from_points(&pc));
        let mut entries = Vec::new();
        for i in 0..4u32 {
            for j in (i + 1)..4 {
                entries.push((i, j, pc.dist(i as usize, j as usize)));
            }
        }
        let sd = MetricData::Sparse(SparseDistances { n: 4, entries });
        let f_p = EdgeFiltration::build(&md, 2.0);
        let f_d = EdgeFiltration::build(&dd, 2.0);
        let f_s = EdgeFiltration::build(&sd, 2.0);
        assert_eq!(f_p.edges, f_d.edges);
        assert_eq!(f_p.edges, f_s.edges);
    }

    #[test]
    fn base_memory_model() {
        let f = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f.base_memory_model_bytes(), (3 * 4 + 12 * 6) * 4);
    }
}
