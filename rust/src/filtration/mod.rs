//! VR edge filtration (`F1`), vertex-/edge-neighborhoods, paired indexing.
//!
//! Paper §4: the filtration for 1-simplices is the list of edges sorted by
//! length; 2-/3-simplices are *never* materialized — they are identified by
//! paired keys `⟨primary, secondary⟩` (§4.1) and enumerated on the fly from
//! the neighborhoods (§4.2).
//!
//! ## The parallel front-end
//!
//! Building F1 used to be the last fully serial stretch of the pipeline:
//! an O(n²) distance loop, a comparator sort, and a serial CSR fill all
//! ran before a single pool worker woke up. [`EdgeFiltration::build_pooled`]
//! runs the whole front-end on the engine's persistent work-stealing pool
//! while keeping the output **byte-identical** to the serial build:
//!
//! * **tiled distance kernel** — the upper-triangular (i, j) index space
//!   is cut into row-band tiles dispatched through the pool; each tile
//!   filters by `τ` into a local buffer and tiles are spliced back in
//!   canonical order. Inside a tile the squared distances run through an
//!   explicit-SIMD kernel (AVX2/NEON, see [`simd`]) over a cache-aligned
//!   SoA copy of the points, bit-identical to the scalar loop;
//! * **total-order key sort** — every kept edge is packed into a `u128`
//!   whose unsigned order equals the filtration's total order (monotone
//!   f64→u64 bits, tie-broken by the packed `(a, b)`), then sorted by a
//!   chunk-sort-then-merge pass on the pool. No `partial_cmp().unwrap()`
//!   in the hot loop, and the fully sorted order is schedule-independent
//!   because keys are strictly unique;
//! * **enclosing-radius truncation** — when no finite `τ` was requested,
//!   nothing outlives `r_enc = min_i max_j d(i, j)` (beyond it the VR
//!   complex is a cone over the argmin vertex, so every diagram point is
//!   unchanged), and the kernel filters by `r_enc` instead of `+∞`;
//! * **parallel CSR fill** — see [`Neighborhoods::build_pooled`].
//!
//! [`FiltrationStats`] carries the per-stage times and the
//! considered/kept/pruned edge counters up through `EngineStats`, the run
//! summary JSON and the benches.

pub mod neighborhoods;
pub mod simd;
pub mod sparsify;
pub mod paired;

pub use neighborhoods::Neighborhoods;
pub use paired::Key;
pub use simd::SimdMode;

use std::sync::Mutex;
use std::time::Instant;

use crate::error::DoryError;
use crate::geometry::MetricData;
use crate::reduction::pool::{SharedSlice, ThreadPool};

/// Knobs for the pooled filtration front-end.
#[derive(Clone, Copy, Debug)]
pub struct FrontendOptions {
    /// Point rows per distance tile (`f1_tile`); 0 = auto (~8 tiles per
    /// worker so stealing levels the triangular row costs).
    pub tile: usize,
    /// Enclosing-radius truncation when `tau_max` is exactly `+inf`:
    /// cut the edge set at `r_enc = min_i max_j d(i, j)` — diagrams are
    /// unchanged (the complex is a cone beyond `r_enc`), the edge list
    /// shrinks. Inapplicable to pre-thresholded sparse inputs.
    pub enclosing: bool,
    /// Distance kernel selection (`simd` knob): `auto` resolves to the
    /// widest kernel the host supports at runtime, forced modes degrade
    /// to `scalar` when unavailable. Output bits are identical for
    /// every setting; [`FiltrationStats::dist_kernel`] records what ran.
    pub simd: SimdMode,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self {
            tile: 0,
            enclosing: true,
            simd: SimdMode::Auto,
        }
    }
}

/// Counters and stage times of one front-end run (distance kernel, key
/// sort, CSR fill). All-zero except `enclosing_radius` (+∞) until a
/// build fills them; pooled stages leave their tile/chunk counters
/// nonzero, serial fallbacks leave them 0.
#[derive(Clone, Copy, Debug)]
pub struct FiltrationStats {
    /// Wall time of the distance pass (tile kernel; the enclosing-
    /// radius row maxima ride along in the same sweep).
    pub dist_ns: u64,
    /// Wall time of the edge key sort (chunk sorts + merge).
    pub sort_ns: u64,
    /// Wall time of the `Neighborhoods` CSR build.
    pub nb_ns: u64,
    /// Distance/row-max tiles dispatched to pool workers (0 = serial).
    pub tiles: u64,
    /// Sorted chunks merged by the pooled key sort (0 = serial sort).
    pub sort_chunks: u64,
    /// CSR counting/scatter chunks dispatched to pool workers (0 =
    /// serial).
    pub nb_chunks: u64,
    /// Candidate pairs examined by the distance kernel.
    pub edges_considered: u64,
    /// Edges kept in the filtration.
    pub edges_kept: u64,
    /// Edges dropped by the enclosing-radius truncation. Edges above a
    /// caller-supplied finite `τ` are *filtered*, not pruned, and are
    /// not counted here.
    pub edges_pruned: u64,
    /// `r_enc = min_i max_j d(i, j)` when the truncation ran; +∞ when it
    /// was off or inapplicable.
    pub enclosing_radius: f64,
    /// Full F1 builds recorded into this stats object (distance pass +
    /// key sort). The session layer's "ingest once" guarantee is pinned
    /// on this counter: a batch of N queries over one
    /// [`crate::homology::FiltrationHandle`] leaves it at 1.
    pub f1_builds: u64,
    /// `Neighborhoods` CSR builds recorded into this stats object; the
    /// session counterpart of `f1_builds`.
    pub nb_builds: u64,
    /// Distance kernel that ran (`"avx2"`, `"neon"`, `"scalar"`); empty
    /// until a dense distance pass runs (sparse/weighted inputs never
    /// run one).
    pub dist_kernel: &'static str,
    /// Sorted key runs spilled to disk by the *dense* streamed front-end
    /// (`stream_dense_build`); 0 for in-memory builds.
    pub dense_spilled_runs: u64,
    /// Bytes written to spill files by the dense streamed front-end.
    pub dense_spilled_bytes: u64,
    /// Peak resident staging (spill buffer + tile scratch) of the dense
    /// streamed front-end, in bytes.
    pub dense_staging_peak_bytes: u64,
}

impl Default for FiltrationStats {
    fn default() -> Self {
        Self {
            dist_ns: 0,
            sort_ns: 0,
            nb_ns: 0,
            tiles: 0,
            sort_chunks: 0,
            nb_chunks: 0,
            edges_considered: 0,
            edges_kept: 0,
            edges_pruned: 0,
            enclosing_radius: f64::INFINITY,
            f1_builds: 0,
            nb_builds: 0,
            dist_kernel: "",
            dense_spilled_runs: 0,
            dense_spilled_bytes: 0,
            dense_staging_peak_bytes: 0,
        }
    }
}

impl FiltrationStats {
    /// Machine-readable form for run summaries and bench dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .field("dist_s", self.dist_ns as f64 * 1e-9)
            .field("sort_s", self.sort_ns as f64 * 1e-9)
            .field("nb_s", self.nb_ns as f64 * 1e-9)
            .field("tiles", self.tiles as f64)
            .field("sort_chunks", self.sort_chunks as f64)
            .field("nb_chunks", self.nb_chunks as f64)
            .field("edges_considered", self.edges_considered as f64)
            .field("edges_kept", self.edges_kept as f64)
            .field("edges_pruned", self.edges_pruned as f64)
            .field("enclosing_radius", self.enclosing_radius)
            .field("f1_builds", self.f1_builds as f64)
            .field("nb_builds", self.nb_builds as f64)
            .field("dist_kernel", self.dist_kernel)
            .field("dense_spilled_runs", self.dense_spilled_runs as f64)
            .field("dense_spilled_bytes", self.dense_spilled_bytes as f64)
            .field(
                "dense_staging_peak_bytes",
                self.dense_staging_peak_bytes as f64,
            )
    }
}

/// Order-preserving map from a (non-NaN) f64 to u64: sorting the keys
/// as unsigned integers sorts the floats. `-0.0` is normalized to
/// `+0.0` first — the comparator this replaces treated the two as equal
/// ties, so the normalization is order-neutral.
#[inline]
pub fn f64_order_key(d: f64) -> u64 {
    debug_assert!(!d.is_nan());
    // IEEE: x + 0.0 == x bit-for-bit except -0.0, which becomes +0.0.
    let b = (d + 0.0).to_bits();
    if b >> 63 == 0 {
        b | (1u64 << 63)
    } else {
        !b
    }
}

/// Inverse of [`f64_order_key`].
#[inline]
pub fn f64_from_order_key(k: u64) -> f64 {
    f64::from_bits(if k >> 63 == 1 { k & !(1u64 << 63) } else { !k })
}

/// One weighted edge packed into its 128-bit sort key: unsigned u128
/// order == the filtration total order (length, ties by `(a, b)`). Keys
/// are strictly unique because `(a, b)` pairs are. `pub(crate)` so the
/// streaming reader (`io::stream`) packs per-chunk keys in exactly the
/// front-end's order — the spill-merge output is then byte-identical to
/// the in-memory sort.
#[inline]
pub(crate) fn edge_key(d: f64, a: u32, b: u32) -> u128 {
    ((f64_order_key(d) as u128) << 64) | ((a as u128) << 32) | b as u128
}

#[inline]
pub(crate) fn unpack_edge_key(k: u128) -> (f64, u32, u32) {
    (f64_from_order_key((k >> 64) as u64), (k >> 32) as u32, k as u32)
}

/// Pooled sort for externally staged key runs (the `io::stream` spill
/// store): the front-end's chunk-sort + pairwise-merge pass without the
/// stats plumbing. Byte-identical to `sort_unstable` for unique keys.
pub(crate) fn sort_run_u128(keys: Vec<u128>, pool: Option<&ThreadPool>) -> Vec<u128> {
    sort_keys(keys, pool, &mut FiltrationStats::default())
}

/// Rows per distance tile: the `f1_tile` knob, or ~8 tiles per worker,
/// at least 16 rows each, when 0. `pub(crate)` so the dense streamed
/// front-end (`io::stream`) cuts identical row bands.
pub(crate) fn effective_tile(n: usize, knob: usize, threads: usize) -> usize {
    let n = n.max(1);
    if knob > 0 {
        return knob.min(n);
    }
    n.div_ceil(threads.max(1) * 8).max(16).min(n)
}

/// The 1-skeleton filtration: edges sorted ascending by (length, a, b).
///
/// Edge *order* (its index in `edges`) is the unit every higher-dimensional
/// key is built from; `values[o]` recovers the filtration parameter.
#[derive(Clone, Debug)]
pub struct EdgeFiltration {
    pub n: u32,
    /// `edges[o] = (a, b)` with `a < b`, sorted ascending by value.
    pub edges: Vec<(u32, u32)>,
    /// `values[o]` = length of edge `o`; non-decreasing.
    pub values: Vec<f64>,
    /// Max permissible filtration parameter used to build this filtration
    /// (the enclosing radius when the truncation fired).
    pub tau_max: f64,
}

impl EdgeFiltration {
    /// Build F1 from any metric input, keeping edges with `d <= tau_max`.
    /// Serial reference path: no pool, no enclosing-radius truncation,
    /// scalar distance kernel — the differential oracle every pooled and
    /// vectorised configuration is pinned against.
    pub fn build(data: &MetricData, tau_max: f64) -> Self {
        let fe = FrontendOptions {
            tile: 0,
            enclosing: false,
            simd: SimdMode::Scalar,
        };
        Self::build_pooled(data, tau_max, None, &fe, &mut FiltrationStats::default())
    }

    /// Build F1 with the pooled front-end. Byte-identical to
    /// [`Self::build`] for every pool size and tile plan when
    /// `fe.enclosing` is off (or `tau_max` is finite); with the
    /// truncation on and `tau_max` infinite, the edge set is cut at the
    /// enclosing radius and every persistence diagram is still
    /// unchanged.
    pub fn build_pooled(
        data: &MetricData,
        tau_max: f64,
        pool: Option<&ThreadPool>,
        fe: &FrontendOptions,
        stats: &mut FiltrationStats,
    ) -> Self {
        let n = data.n();
        assert!(n < u32::MAX as usize, "vertex count must fit u32");
        stats.f1_builds += 1;
        let t0 = Instant::now();
        // Enclosing-radius truncation: with no cap requested (tau must
        // be exactly +inf — a caller asking for tau = -inf wants an
        // empty filtration and gets one), nothing outlives
        // r_enc = min_i max_j d(i, j): at r_enc the argmin vertex
        // neighbors every other vertex, so the flag complex is a cone
        // (contractible above dim 0) from there on. Sparse inputs are
        // already thresholded (absent pairs are unknown, not infinite),
        // so the radius cannot be derived there. Row maxima ride along
        // in the same fused tile pass that emits the keys (each pair's
        // distance is evaluated exactly once — see
        // `fused_enclosing_keys`), and the key list is truncated before
        // the sort ever sees it.
        let applicable = !matches!(data, MetricData::Sparse(_)) && n >= 2;
        let (keys, r_enc) = if fe.enclosing && tau_max == f64::INFINITY && applicable {
            fused_enclosing_keys(data, tau_max, pool, fe, stats)
        } else {
            (distance_keys(data, tau_max, pool, fe, stats), f64::INFINITY)
        };
        stats.enclosing_radius = r_enc;
        stats.dist_ns += t0.elapsed().as_nanos() as u64;

        let t1 = Instant::now();
        let keys = sort_keys(keys, pool, stats);
        let f = Self::from_sorted_keys(
            n as u32,
            &keys,
            if r_enc.is_finite() { r_enc } else { tau_max },
            pool,
        );
        stats.sort_ns += t1.elapsed().as_nanos() as u64;
        stats.edges_kept += f.n_edges() as u64;
        if r_enc.is_finite() {
            // With τ infinite every dropped candidate was dropped by the
            // truncation (NaN distances aside, which the serial filter
            // also drops — see `MetricData::validate`).
            stats.edges_pruned += stats.edges_considered - stats.edges_kept;
        }
        f
    }

    /// Build from an explicit weighted edge list (thresholded by the
    /// caller). Panicking wrapper over [`Self::try_from_weighted_edges`]
    /// for legacy callers; new code (the serve layer, anything taking
    /// untrusted input) should use the `try_` variant and surface the
    /// typed error.
    pub fn from_weighted_edges(n: u32, raw: Vec<(f64, u32, u32)>, tau_max: f64) -> Self {
        Self::from_weighted_edges_pooled(n, raw, tau_max, None, &mut FiltrationStats::default())
    }

    /// Panicking wrapper over [`Self::try_from_weighted_edges_pooled`].
    pub fn from_weighted_edges_pooled(
        n: u32,
        raw: Vec<(f64, u32, u32)>,
        tau_max: f64,
        pool: Option<&ThreadPool>,
        stats: &mut FiltrationStats,
    ) -> Self {
        match Self::try_from_weighted_edges_pooled(n, raw, tau_max, pool, stats) {
            Ok(f) => f,
            Err(e) => panic!("EdgeFiltration: {e}"),
        }
    }

    /// Validating variant of [`Self::from_weighted_edges`].
    pub fn try_from_weighted_edges(
        n: u32,
        raw: Vec<(f64, u32, u32)>,
        tau_max: f64,
    ) -> Result<Self, DoryError> {
        Self::try_from_weighted_edges_pooled(n, raw, tau_max, None, &mut FiltrationStats::default())
    }

    /// Build from an explicit weighted edge list with the key sort
    /// running on the pool (chunk-sort + merge); byte-identical output
    /// for every pool size. This is the PJRT/Pallas kernel path: the
    /// accelerator hands back the thresholded pair list, the pool
    /// orders it.
    ///
    /// The list is validated on the way in — a malformed pair list
    /// would otherwise corrupt the CSR degree counts and break the
    /// strict-unique-key assumption of the pooled sort. Rejected with a
    /// typed [`DoryError::InvalidInput`] naming the offending edge:
    /// NaN distances, endpoints outside `0..n`, self-loops (`a == b`),
    /// and duplicate pairs (in either orientation — endpoint order is
    /// normalized to `a < b` first, so `(a, b)` and `(b, a)` collide).
    pub fn try_from_weighted_edges_pooled(
        n: u32,
        raw: Vec<(f64, u32, u32)>,
        tau_max: f64,
        pool: Option<&ThreadPool>,
        stats: &mut FiltrationStats,
    ) -> Result<Self, DoryError> {
        let mut keys: Vec<u128> = Vec::with_capacity(raw.len());
        let mut pairs: Vec<u64> = Vec::with_capacity(raw.len());
        for &(d, a, b) in &raw {
            if d.is_nan() {
                return Err(DoryError::InvalidInput(format!(
                    "NaN distance on edge ({a}, {b}); reject NaN inputs at ingestion \
                     (MetricData::validate)"
                )));
            }
            if a == b {
                return Err(DoryError::InvalidInput(format!(
                    "self-loop edge ({a}, {b}); Rips edges join distinct vertices"
                )));
            }
            let (a, b) = if a < b { (a, b) } else { (b, a) };
            if b >= n {
                return Err(DoryError::InvalidInput(format!(
                    "edge ({a}, {b}) references vertex {b} outside 0..{n}"
                )));
            }
            keys.push(edge_key(d, a, b));
            pairs.push(((a as u64) << 32) | b as u64);
        }
        // Duplicate detection on the normalized pairs. The value-sorted
        // keys don't make pair-duplicates adjacent (two weights for one
        // pair sort far apart), so sort the pairs themselves.
        pairs.sort_unstable();
        if let Some(w) = pairs.windows(2).find(|w| w[0] == w[1]) {
            let (a, b) = ((w[0] >> 32) as u32, w[0] as u32);
            return Err(DoryError::InvalidInput(format!(
                "duplicate edge ({a}, {b}) in weighted input; pairs must be unique up to \
                 orientation"
            )));
        }
        drop(pairs);
        stats.f1_builds += 1;
        let t0 = Instant::now();
        stats.edges_considered += raw.len() as u64;
        drop(raw);
        let keys = sort_keys(keys, pool, stats);
        let f = Self::from_sorted_keys(n, &keys, tau_max, pool);
        stats.sort_ns += t0.elapsed().as_nanos() as u64;
        stats.edges_kept += f.n_edges() as u64;
        Ok(f)
    }

    /// Unpack sorted keys into the `edges`/`values` arrays (tiled over
    /// the pool when one is given; writes are index-disjoint).
    fn from_sorted_keys(
        n: u32,
        keys: &[u128],
        tau_max: f64,
        pool: Option<&ThreadPool>,
    ) -> Self {
        let m = keys.len();
        let mut edges = vec![(0u32, 0u32); m];
        let mut values = vec![0f64; m];
        match pool {
            Some(pool) if pool.threads() > 1 && m >= 4096 => {
                let se = SharedSlice::new(&mut edges);
                let sv = SharedSlice::new(&mut values);
                let grain = m.div_ceil(pool.threads() * 4).max(1024);
                pool.run_stealing(m, grain, |_tid, r| {
                    for i in r {
                        let (d, a, b) = unpack_edge_key(keys[i]);
                        // SAFETY: stealing hands out each index once.
                        unsafe {
                            se.write(i, (a, b));
                            sv.write(i, d);
                        }
                    }
                });
            }
            _ => {
                for (i, &k) in keys.iter().enumerate() {
                    let (d, a, b) = unpack_edge_key(k);
                    edges[i] = (a, b);
                    values[i] = d;
                }
            }
        }
        Self {
            n,
            edges,
            values,
            tau_max,
        }
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Number of edges with value `<= tau` — the length of the sorted
    /// prefix a sub-τ query is served from. Edges are sorted ascending
    /// by (value, a, b), so the kept set of any `tau <= tau_max` is
    /// exactly a prefix of this filtration.
    pub fn prefix_len(&self, tau: f64) -> usize {
        self.values.partition_point(|&v| v <= tau)
    }

    /// The sub-filtration of the first `m` edges (those with value
    /// `<= tau_eff`), as an owned copy of the prefix. No distance is
    /// recomputed and nothing is re-sorted, so the arrays are bit-equal
    /// to a fresh `build(data, tau_eff)` of the same input — the
    /// session layer's sub-τ query path. The copy is a deliberate
    /// tradeoff: O(m) memcpy per query (the reduction reads
    /// `edges`/`values` as plain arrays throughout the engine) against
    /// the O(n² + m log m) rebuild it replaces; `Arc`-backed prefix
    /// views, as `Neighborhoods::truncated` already does for the CSR,
    /// are the follow-up if the copy ever shows up in service profiles.
    pub fn prefix(&self, m: usize, tau_eff: f64) -> EdgeFiltration {
        debug_assert!(m <= self.n_edges());
        EdgeFiltration {
            n: self.n,
            edges: self.edges[..m].to_vec(),
            values: self.values[..m].to_vec(),
            tau_max: tau_eff,
        }
    }

    /// Filtration value of a triangle/tetrahedron key = value of its diameter.
    #[inline]
    pub fn key_value(&self, key: Key) -> f64 {
        self.values[key.p as usize]
    }

    /// Base memory model from paper App. E: `(3n + 12 n_e) * 4` bytes.
    pub fn base_memory_model_bytes(&self) -> usize {
        (3 * self.n as usize + 12 * self.n_edges()) * 4
    }

    /// Measured heap bytes of the built filtration arrays (the edge list
    /// plus the value array — what the front-end actually materializes).
    pub fn memory_bytes(&self) -> usize {
        self.edges.len() * std::mem::size_of::<(u32, u32)>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

/// Collapse a row-max array to `r_enc = min_i row_max[i]`. When the
/// maxima were folded in squared space (vector kernels), each row takes
/// one `sqrt` here — correctly-rounded `sqrt` is monotone, so
/// `fl(sqrt(max_j s_ij)) == max_j fl(sqrt(s_ij))` and the result is
/// bit-equal to the distance-space fold. `-inf` rows (all-NaN, only
/// possible with infinite coordinates) pass through unrooted so they
/// poison the min into the non-finite fallback exactly as before.
fn rowmax_to_radius(row_max: Vec<f64>, squared: bool) -> f64 {
    row_max
        .into_iter()
        .map(|m| {
            if squared && m != f64::NEG_INFINITY {
                m.sqrt()
            } else {
                m
            }
        })
        .fold(f64::INFINITY, f64::min)
}

/// `r_enc = min_i max_j d(i, j)` by a triangular sweep that stores no
/// keys — O(n) memory. Pooled runs keep one partial row-max array per
/// *worker* (a stolen tile accumulates into the thief's array; `tid`
/// names the executing worker, which runs its tasks sequentially, so
/// the slot is uncontended); the element-wise max-merge is
/// schedule-independent because every pair contributes to the same two
/// rows exactly once and `f64::max` over a fixed multiset is
/// associative and commutative (NaN contributions are ignored).
/// `pub(crate)` for the dense streamed front-end, which needs the
/// radius *before* it can start thresholding tiles into the spill
/// store; the in-memory build uses the fused single pass instead.
pub(crate) fn enclosing_radius_rowmax(
    data: &MetricData,
    pool: Option<&ThreadPool>,
    fe: &FrontendOptions,
    stats: &mut FiltrationStats,
) -> f64 {
    let n = data.n();
    debug_assert!(n >= 2);
    let dist = simd::Dist::new(data, fe.simd);
    let squared = dist.rowmax_is_squared();
    let row_max = match pool {
        Some(pool) if pool.threads() > 1 => {
            let tile = effective_tile(n, fe.tile, pool.threads());
            let n_tiles = n.div_ceil(tile);
            let maxes: Vec<Mutex<Vec<f64>>> =
                (0..pool.threads()).map(|_| Mutex::new(Vec::new())).collect();
            let dist = &dist;
            pool.run_stealing(n_tiles, 1, |tid, range| {
                let mut mx = maxes[tid].lock().unwrap();
                if mx.is_empty() {
                    mx.resize(n, f64::NEG_INFINITY);
                }
                let mut scratch = vec![0f64; n];
                for t in range {
                    for i in t * tile..((t + 1) * tile).min(n) {
                        dist.fold_row_max(i, n, &mut mx[..], &mut scratch);
                    }
                }
            });
            stats.tiles += n_tiles as u64;
            let mut row_max = vec![f64::NEG_INFINITY; n];
            for m in maxes {
                let m = m.into_inner().unwrap();
                for (r, &v) in row_max.iter_mut().zip(&m) {
                    *r = r.max(v);
                }
            }
            row_max
        }
        _ => {
            let mut row_max = vec![f64::NEG_INFINITY; n];
            let mut scratch = vec![0f64; n];
            for i in 0..n {
                dist.fold_row_max(i, n, &mut row_max, &mut scratch);
            }
            row_max
        }
    };
    rowmax_to_radius(row_max, squared)
}

/// Sample rows used to seed the provisional truncation bound of the
/// fused enclosing pass. Any row's max is an upper bound on
/// `r_enc = min_i max_j d(i, j)`; the min over a handful of rows is
/// generically tight.
const ENCLOSING_SAMPLE_ROWS: usize = 16;

/// Fused τ=∞ front-end pass: a single sweep over the upper triangle
/// evaluates each pair's distance exactly once, folding the
/// enclosing-radius row maxima *and* emitting sort keys thresholded at a
/// provisional bound `τ_p ≥ r_enc` (the min of a few sampled row
/// maxima). Once the sweep finishes the exact `r_enc` is known and the
/// provisional key list is filtered down to it by key prefix —
/// bit-identical to the old two-pass build (same distances, same order
/// keys) at half the distance work. Peak memory tracks the kept set at
/// `τ_p`, which coincides with the kept set at `r_enc` whenever some
/// sampled row max sits near the min; a pathological sample costs only
/// memory, never bits. Degenerate geometry (a non-finite radius —
/// infinite coordinates) falls back to the untruncated kernel exactly
/// as the two-pass build did.
fn fused_enclosing_keys(
    data: &MetricData,
    tau_max: f64,
    pool: Option<&ThreadPool>,
    fe: &FrontendOptions,
    stats: &mut FiltrationStats,
) -> (Vec<u128>, f64) {
    let n = data.n();
    debug_assert!(n >= 2);
    let dist = simd::Dist::new(data, fe.simd);
    stats.dist_kernel = dist.kernel_name();
    let squared = dist.rowmax_is_squared();
    let mut scratch = vec![0f64; n];
    let mut tau_p = f64::INFINITY;
    for i in 0..n.min(ENCLOSING_SAMPLE_ROWS) {
        tau_p = tau_p.min(dist.full_row_max(i, n, &mut scratch));
    }
    let bound = simd::sq_prefilter_bound(tau_p);
    let (keys, row_max, n_tiles) = match pool {
        Some(pool) if pool.threads() > 1 => {
            let tile = effective_tile(n, fe.tile, pool.threads());
            let n_tiles = n.div_ceil(tile);
            let slots: Vec<Mutex<Vec<u128>>> =
                (0..n_tiles).map(|_| Mutex::new(Vec::new())).collect();
            let maxes: Vec<Mutex<Vec<f64>>> =
                (0..pool.threads()).map(|_| Mutex::new(Vec::new())).collect();
            let dist = &dist;
            pool.run_stealing(n_tiles, 1, |tid, range| {
                let mut mx = maxes[tid].lock().unwrap();
                if mx.is_empty() {
                    mx.resize(n, f64::NEG_INFINITY);
                }
                let mut scratch = vec![0f64; n];
                for t in range {
                    let mut buf = Vec::new();
                    for i in t * tile..((t + 1) * tile).min(n) {
                        dist.fused_row(i, n, tau_p, bound, &mut buf, &mut mx[..], &mut scratch);
                    }
                    *slots[t].lock().unwrap() = buf;
                }
            });
            let mut row_max = vec![f64::NEG_INFINITY; n];
            for m in maxes {
                let m = m.into_inner().unwrap();
                for (r, &v) in row_max.iter_mut().zip(&m) {
                    *r = r.max(v);
                }
            }
            (splice(slots), row_max, n_tiles as u64)
        }
        _ => {
            let mut keys = Vec::new();
            let mut row_max = vec![f64::NEG_INFINITY; n];
            for i in 0..n {
                dist.fused_row(i, n, tau_p, bound, &mut keys, &mut row_max, &mut scratch);
            }
            (keys, row_max, 0)
        }
    };
    let r_enc = rowmax_to_radius(row_max, squared);
    if !r_enc.is_finite() {
        // Truncation inapplicable; discard the provisional keys and
        // rebuild untruncated (the fallback records its own counters).
        return (distance_keys(data, tau_max, pool, fe, stats), r_enc);
    }
    stats.tiles += n_tiles;
    stats.edges_considered += (n * (n - 1) / 2) as u64;
    let mut keys = keys;
    if r_enc < tau_p {
        let cut = f64_order_key(r_enc);
        keys.retain(|&k| (k >> 64) as u64 <= cut);
    }
    (keys, r_enc)
}

/// The one row-max sweep behind every query/kernel-side enclosing
/// radius: `min_i max_j d(i, j)` over a complete unordered pair list.
/// `f64::max`/`min` over a fixed multiset are order-independent, so the
/// result is bit-equal to the build-time tiled sweep regardless of the
/// pair order the caller iterates in. NaN entries are ignored.
fn enclosing_radius_from_pairs(
    n: usize,
    pairs: impl Iterator<Item = (f64, u32, u32)>,
) -> f64 {
    let mut row_max = vec![f64::NEG_INFINITY; n];
    for (d, a, b) in pairs {
        row_max[a as usize] = row_max[a as usize].max(d);
        row_max[b as usize] = row_max[b as usize].max(d);
    }
    row_max.into_iter().fold(f64::INFINITY, f64::min)
}

/// `min_i max_j d(i, j)` from a **complete** weighted pair list (every
/// unordered pair present exactly once) — the shape the PJRT distance
/// kernel returns at `τ = +∞`. The coordinator uses this to apply the
/// enclosing-radius truncation to accelerator-produced edge lists
/// before they are key-sorted.
pub fn enclosing_radius_of_edges(n: usize, edges: &[(f64, u32, u32)]) -> f64 {
    debug_assert_eq!(edges.len(), n * (n.saturating_sub(1)) / 2);
    enclosing_radius_from_pairs(n, edges.iter().copied())
}

/// `min_i max_j d(i, j)` over a **complete** built filtration (every
/// unordered pair kept, i.e. built at `τ = +∞` without the enclosing
/// truncation), so a session can apply the truncation at *query* time
/// to a handle that ingested the full filtration — bit-equal to the
/// build-time sweep (see [`enclosing_radius_from_pairs`]). Returns +∞
/// when the edge list is not the complete pair list.
pub fn enclosing_radius_of_filtration(f: &EdgeFiltration) -> f64 {
    let n = f.n as usize;
    if n < 2 || f.n_edges() != n * (n - 1) / 2 {
        return f64::INFINITY;
    }
    enclosing_radius_from_pairs(
        n,
        f.edges
            .iter()
            .zip(&f.values)
            .map(|(&(a, b), &d)| (d, a, b)),
    )
}

/// The thresholded distance pass: every candidate pair with `d <= tau`
/// becomes a packed sort key. Pooled runs tile the upper-triangular
/// index space by point rows (sparse inputs: by entry chunks) and
/// splice the tile buffers back in canonical order; the serial path
/// walks the same loops inline. The produced key *set* is identical
/// either way, and the subsequent sort makes the order canonical.
fn distance_keys(
    data: &MetricData,
    tau: f64,
    pool: Option<&ThreadPool>,
    fe: &FrontendOptions,
    stats: &mut FiltrationStats,
) -> Vec<u128> {
    let n = data.n();
    match (data, pool) {
        (MetricData::Sparse(sd), Some(pool)) if pool.threads() > 1 && !sd.entries.is_empty() => {
            let len = sd.entries.len();
            let chunk = len.div_ceil(pool.threads() * 8).max(1);
            let n_chunks = len.div_ceil(chunk);
            let slots: Vec<Mutex<Vec<u128>>> =
                (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
            pool.run_stealing(n_chunks, 1, |_tid, range| {
                for c in range {
                    let mut buf = Vec::new();
                    for &(u, v, d) in &sd.entries[c * chunk..((c + 1) * chunk).min(len)] {
                        debug_assert!(u < v);
                        if d <= tau {
                            buf.push(edge_key(d, u, v));
                        }
                    }
                    *slots[c].lock().unwrap() = buf;
                }
            });
            stats.tiles += n_chunks as u64;
            stats.edges_considered += len as u64;
            splice(slots)
        }
        (MetricData::Sparse(sd), _) => {
            let mut keys = Vec::new();
            for &(u, v, d) in &sd.entries {
                debug_assert!(u < v);
                if d <= tau {
                    keys.push(edge_key(d, u, v));
                }
            }
            stats.edges_considered += sd.entries.len() as u64;
            keys
        }
        (_, Some(pool)) if pool.threads() > 1 && n >= 2 => {
            let dist = simd::Dist::new(data, fe.simd);
            stats.dist_kernel = dist.kernel_name();
            let bound = simd::sq_prefilter_bound(tau);
            let tile = effective_tile(n, fe.tile, pool.threads());
            let n_tiles = n.div_ceil(tile);
            let slots: Vec<Mutex<Vec<u128>>> =
                (0..n_tiles).map(|_| Mutex::new(Vec::new())).collect();
            {
                let dist = &dist;
                pool.run_stealing(n_tiles, 1, |_tid, range| {
                    let mut scratch = vec![0f64; n];
                    for t in range {
                        let mut buf = Vec::new();
                        for i in t * tile..((t + 1) * tile).min(n) {
                            dist.fill_row(i, n, tau, bound, &mut buf, &mut scratch);
                        }
                        *slots[t].lock().unwrap() = buf;
                    }
                });
            }
            stats.tiles += n_tiles as u64;
            stats.edges_considered += (n * (n - 1) / 2) as u64;
            splice(slots)
        }
        _ => {
            let mut keys = Vec::new();
            if n >= 2 {
                let dist = simd::Dist::new(data, fe.simd);
                stats.dist_kernel = dist.kernel_name();
                let bound = simd::sq_prefilter_bound(tau);
                let mut scratch = vec![0f64; n];
                for i in 0..n {
                    dist.fill_row(i, n, tau, bound, &mut keys, &mut scratch);
                }
                stats.edges_considered += (n * (n - 1) / 2) as u64;
            }
            keys
        }
    }
}

/// Concatenate per-tile buffers in tile order.
fn splice(slots: Vec<Mutex<Vec<u128>>>) -> Vec<u128> {
    let mut bufs: Vec<Vec<u128>> = slots
        .into_iter()
        .map(|s| s.into_inner().unwrap())
        .collect();
    let total: usize = bufs.iter().map(Vec::len).sum();
    let mut keys = Vec::with_capacity(total);
    for b in &mut bufs {
        keys.append(b);
    }
    keys
}

/// Sort packed edge keys: chunk-sort on the pool followed by pooled
/// pairwise merge rounds, or a plain `sort_unstable` serially. Keys
/// are strictly unique, so both paths produce the same byte sequence
/// for any chunk plan or steal schedule.
fn sort_keys(
    mut keys: Vec<u128>,
    pool: Option<&ThreadPool>,
    stats: &mut FiltrationStats,
) -> Vec<u128> {
    match pool {
        Some(pool) if pool.threads() > 1 && keys.len() > 1 => {
            let c = pool.threads().min(keys.len());
            let bounds: Vec<usize> = (0..=c).map(|k| k * keys.len() / c).collect();
            {
                let shared = SharedSlice::new(&mut keys);
                let bounds = &bounds;
                pool.run_stealing(c, 1, |_tid, range| {
                    for ci in range {
                        // SAFETY: chunk ranges are pairwise disjoint.
                        let s = unsafe { shared.slice_mut(bounds[ci]..bounds[ci + 1]) };
                        s.sort_unstable();
                    }
                });
            }
            stats.sort_chunks += c as u64;
            merge_sorted_runs_pooled(pool, keys, bounds)
        }
        _ => {
            keys.sort_unstable();
            keys
        }
    }
}

/// Merge the sorted runs `keys[bounds[i]..bounds[i+1]]` by pairwise
/// merge rounds executed on the pool (⌈log₂ runs⌉ generations, each
/// round merging adjacent run pairs into disjoint regions of a
/// ping-pong buffer), so the merge is not a serial critical path that
/// grows with the pool width. Keys are strictly unique, so the fully
/// merged sequence is the same bytes for any round structure.
fn merge_sorted_runs_pooled(
    pool: &ThreadPool,
    keys: Vec<u128>,
    mut bounds: Vec<usize>,
) -> Vec<u128> {
    let mut src = keys;
    let mut dst = vec![0u128; src.len()];
    while bounds.len() > 2 {
        let r = bounds.len() - 1;
        let tasks = r.div_ceil(2);
        {
            let shared = SharedSlice::new(&mut dst);
            let (bounds, src) = (&bounds, &src);
            pool.run_stealing(tasks, 1, |_tid, range| {
                for k in range {
                    let s = bounds[2 * k];
                    if 2 * k + 2 <= r {
                        let (mid, e) = (bounds[2 * k + 1], bounds[2 * k + 2]);
                        // SAFETY: output regions of distinct tasks are
                        // disjoint (adjacent run pairs).
                        let out = unsafe { shared.slice_mut(s..e) };
                        merge_two(&src[s..mid], &src[mid..e], out);
                    } else {
                        // Odd run count: the last run rides over as-is.
                        let e = bounds[2 * k + 1];
                        let out = unsafe { shared.slice_mut(s..e) };
                        out.copy_from_slice(&src[s..e]);
                    }
                }
            });
        }
        std::mem::swap(&mut src, &mut dst);
        let last = *bounds.last().unwrap();
        let mut nb: Vec<usize> = bounds.iter().copied().step_by(2).collect();
        if *nb.last().unwrap() != last {
            nb.push(last);
        }
        bounds = nb;
    }
    src
}

/// Standard two-way merge of sorted slices into `out`
/// (`out.len() == a.len() + b.len()`).
fn merge_two(a: &[u128], b: &[u128], out: &mut [u128]) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in out.iter_mut() {
        let take_a = match (a.get(i), b.get(j)) {
            (Some(&x), Some(&y)) => x <= y,
            (Some(_), None) => true,
            _ => false,
        };
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{DenseDistances, PointCloud, SparseDistances};

    fn square_cloud() -> MetricData {
        // Unit square: 4 edges of length 1, 2 diagonals of length sqrt(2).
        MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0],
        ))
    }

    #[test]
    fn sorted_and_thresholded() {
        let f = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f.n_edges(), 6);
        for w in f.values.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!((f.values[3] - 1.0).abs() < 1e-12);
        assert!((f.values[4] - 2f64.sqrt()).abs() < 1e-12);

        let f = EdgeFiltration::build(&square_cloud(), 1.1);
        assert_eq!(f.n_edges(), 4, "diagonals filtered");
    }

    #[test]
    fn ties_broken_deterministically() {
        let f1 = EdgeFiltration::build(&square_cloud(), 2.0);
        let f2 = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f1.edges, f2.edges);
        // Ties: (0,1),(0,3),(1,2),(2,3) all length 1, ordered lexicographically.
        assert_eq!(f1.edges[0], (0, 1));
        assert_eq!(f1.edges[1], (0, 3));
    }

    #[test]
    fn dense_and_sparse_agree_with_points() {
        let md = square_cloud();
        let pc = match &md {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let dd = MetricData::Dense(DenseDistances::from_points(&pc));
        let mut entries = Vec::new();
        for i in 0..4u32 {
            for j in (i + 1)..4 {
                entries.push((i, j, pc.dist(i as usize, j as usize)));
            }
        }
        let sd = MetricData::Sparse(SparseDistances { n: 4, entries });
        let f_p = EdgeFiltration::build(&md, 2.0);
        let f_d = EdgeFiltration::build(&dd, 2.0);
        let f_s = EdgeFiltration::build(&sd, 2.0);
        assert_eq!(f_p.edges, f_d.edges);
        assert_eq!(f_p.edges, f_s.edges);
    }

    #[test]
    fn base_memory_model() {
        let f = EdgeFiltration::build(&square_cloud(), 2.0);
        assert_eq!(f.base_memory_model_bytes(), (3 * 4 + 12 * 6) * 4);
        assert_eq!(f.memory_bytes(), 6 * 8 + 6 * 8);
    }

    #[test]
    fn f64_key_roundtrip_and_order() {
        let xs = [
            f64::NEG_INFINITY,
            -3.5,
            -1.0,
            -f64::MIN_POSITIVE,
            0.0,
            f64::MIN_POSITIVE,
            0.25,
            1.0,
            1.0000000000000002,
            1e300,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                f64_order_key(w[0]) < f64_order_key(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
        for &x in &xs {
            assert_eq!(f64_from_order_key(f64_order_key(x)).to_bits(), x.to_bits());
        }
        // -0.0 normalizes to +0.0 (the comparator treated them equal).
        assert_eq!(f64_order_key(-0.0), f64_order_key(0.0));
        assert_eq!(f64_from_order_key(f64_order_key(-0.0)).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn edge_key_orders_like_the_old_comparator() {
        let mut raw = vec![
            (1.5, 3u32, 7u32),
            (1.5, 3, 5),
            (0.5, 9, 10),
            (1.5, 2, 11),
            (0.5, 0, 1),
        ];
        let mut keys: Vec<u128> = raw.iter().map(|&(d, a, b)| edge_key(d, a, b)).collect();
        keys.sort_unstable();
        raw.sort_by(|x, y| {
            x.0.partial_cmp(&y.0)
                .unwrap()
                .then(x.1.cmp(&y.1))
                .then(x.2.cmp(&y.2))
        });
        let unpacked: Vec<(f64, u32, u32)> = keys.iter().map(|&k| unpack_edge_key(k)).collect();
        assert_eq!(unpacked, raw);
    }

    #[test]
    #[should_panic(expected = "NaN distance")]
    fn nan_weighted_edge_rejected_with_clear_error() {
        let _ = EdgeFiltration::from_weighted_edges(
            3,
            vec![(0.5, 0, 1), (f64::NAN, 0, 2)],
            1.0,
        );
    }

    #[test]
    fn malformed_weighted_edges_are_typed_errors() {
        use crate::error::DoryError;
        // Self-loop.
        let e = EdgeFiltration::try_from_weighted_edges(3, vec![(0.5, 1, 1)], 1.0).unwrap_err();
        assert!(matches!(&e, DoryError::InvalidInput(m) if m.contains("self-loop")), "{e}");
        // Out-of-range endpoint.
        let e = EdgeFiltration::try_from_weighted_edges(3, vec![(0.5, 0, 3)], 1.0).unwrap_err();
        assert!(matches!(&e, DoryError::InvalidInput(m) if m.contains("outside")), "{e}");
        // Duplicate pair, same orientation — different weights, so the
        // value-sorted keys are unique and only pair-level validation
        // catches it.
        let e = EdgeFiltration::try_from_weighted_edges(
            3,
            vec![(0.5, 0, 1), (0.9, 0, 1)],
            1.0,
        )
        .unwrap_err();
        assert!(matches!(&e, DoryError::InvalidInput(m) if m.contains("duplicate")), "{e}");
        // Duplicate pair across orientations.
        let e = EdgeFiltration::try_from_weighted_edges(
            3,
            vec![(0.5, 0, 1), (0.7, 1, 0)],
            1.0,
        )
        .unwrap_err();
        assert!(matches!(&e, DoryError::InvalidInput(m) if m.contains("duplicate")), "{e}");
        // NaN distance.
        let e =
            EdgeFiltration::try_from_weighted_edges(3, vec![(f64::NAN, 0, 1)], 1.0).unwrap_err();
        assert!(matches!(&e, DoryError::InvalidInput(m) if m.contains("NaN")), "{e}");
    }

    #[test]
    fn reversed_orientation_is_normalized() {
        // (b, a) input must come out as the canonical (a, b) edge with
        // identical bits to the already-normalized build.
        let fwd = EdgeFiltration::try_from_weighted_edges(
            3,
            vec![(0.5, 0, 1), (0.25, 1, 2)],
            1.0,
        )
        .unwrap();
        let rev = EdgeFiltration::try_from_weighted_edges(
            3,
            vec![(0.5, 1, 0), (0.25, 2, 1)],
            1.0,
        )
        .unwrap();
        assert_eq!(fwd.edges, rev.edges);
        assert_eq!(fwd.edges, vec![(1, 2), (0, 1)]);
        let fb: Vec<u64> = fwd.values.iter().map(|v| v.to_bits()).collect();
        let rb: Vec<u64> = rev.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, rb);
    }

    #[test]
    fn pooled_build_matches_serial_bits() {
        let pool = ThreadPool::new(4);
        for tau in [1.1, 2.0, f64::INFINITY] {
            let serial = EdgeFiltration::build(&square_cloud(), tau);
            let mut stats = FiltrationStats::default();
            let fe = FrontendOptions {
                tile: 1,
                enclosing: false,
                ..Default::default()
            };
            let pooled =
                EdgeFiltration::build_pooled(&square_cloud(), tau, Some(&pool), &fe, &mut stats);
            assert_eq!(serial.edges, pooled.edges, "tau={tau}");
            let sb: Vec<u64> = serial.values.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u64> = pooled.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "tau={tau}");
            assert!(stats.tiles > 0, "tau={tau}: tiles must run on the pool");
            assert_eq!(stats.edges_kept as usize, pooled.n_edges());
            assert_eq!(stats.edges_pruned, 0);
        }
    }

    #[test]
    fn enclosing_truncates_at_min_max_radius() {
        // Square + one far-away point: r_enc = max distance from the
        // far point's nearest-to-farthest... computed brute force below.
        let md = MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 5.0, 0.0],
        ));
        let pc = match &md {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let n = pc.n();
        let mut r_enc = f64::INFINITY;
        for i in 0..n {
            let mut m = f64::NEG_INFINITY;
            for j in 0..n {
                if j != i {
                    m = m.max(pc.dist(i, j));
                }
            }
            r_enc = r_enc.min(m);
        }
        for pool in [None, Some(ThreadPool::new(3))] {
            let mut stats = FiltrationStats::default();
            let fe = FrontendOptions {
                tile: 2,
                enclosing: true,
                ..Default::default()
            };
            let f = EdgeFiltration::build_pooled(
                &md,
                f64::INFINITY,
                pool.as_ref(),
                &fe,
                &mut stats,
            );
            assert_eq!(stats.enclosing_radius.to_bits(), r_enc.to_bits());
            assert!(f.values.iter().all(|&v| v <= r_enc));
            assert_eq!(f.tau_max.to_bits(), r_enc.to_bits());
            assert!(stats.edges_pruned > 0, "far edges must be pruned");
            assert_eq!(
                stats.edges_considered,
                stats.edges_kept + stats.edges_pruned
            );
            // The truncated set must equal the serial build at tau = r_enc.
            let want = EdgeFiltration::build(&md, r_enc);
            assert_eq!(f.edges, want.edges);
        }
    }

    #[test]
    fn enclosing_radius_of_edges_matches_metric_and_truncates_like_native() {
        // Simulates the PJRT flow: a complete pair list at tau = +inf,
        // radius derived from the list, list truncated, key-sorted —
        // must land on the same filtration as the native enclosing path.
        let md = MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 5.0, 0.0],
        ));
        let pc = match &md {
            MetricData::Points(p) => p.clone(),
            _ => unreachable!(),
        };
        let n = pc.n();
        let mut raw = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                raw.push((pc.dist(i, j), i as u32, j as u32));
            }
        }
        let r = enclosing_radius_of_edges(n, &raw);
        let mut stats = FiltrationStats::default();
        let native = EdgeFiltration::build_pooled(
            &md,
            f64::INFINITY,
            None,
            &FrontendOptions::default(),
            &mut stats,
        );
        assert_eq!(r.to_bits(), stats.enclosing_radius.to_bits());
        raw.retain(|&(d, _, _)| d <= r);
        let kernel_path = EdgeFiltration::from_weighted_edges(n as u32, raw, r);
        assert_eq!(kernel_path.edges, native.edges);
        let kb: Vec<u64> = kernel_path.values.iter().map(|v| v.to_bits()).collect();
        let nb: Vec<u64> = native.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(kb, nb);
    }

    #[test]
    fn negative_infinity_tau_yields_empty_filtration() {
        // tau = -inf asks for an empty filtration; the enclosing
        // truncation must NOT fire (it applies to +inf only).
        let fe = FrontendOptions::default();
        for pool in [None, Some(ThreadPool::new(2))] {
            let mut stats = FiltrationStats::default();
            let f = EdgeFiltration::build_pooled(
                &square_cloud(),
                f64::NEG_INFINITY,
                pool.as_ref(),
                &fe,
                &mut stats,
            );
            assert_eq!(f.n_edges(), 0);
            assert!(stats.enclosing_radius.is_infinite());
            assert_eq!(stats.edges_pruned, 0);
        }
    }

    #[test]
    fn prefix_is_bit_equal_to_fresh_build() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0xF00D);
        let pc = PointCloud::new(3, (0..30 * 3).map(|_| rng.next_f64()).collect());
        let md = MetricData::Points(pc);
        let full = EdgeFiltration::build(&md, 1.2);
        for tau in [0.0, 0.3, 0.55, 0.8, 1.2] {
            let m = full.prefix_len(tau);
            let p = full.prefix(m, tau);
            let fresh = EdgeFiltration::build(&md, tau);
            assert_eq!(p.edges, fresh.edges, "tau={tau}");
            let pb: Vec<u64> = p.values.iter().map(|v| v.to_bits()).collect();
            let fb: Vec<u64> = fresh.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, fb, "tau={tau}");
            assert_eq!(p.tau_max, tau);
        }
        assert_eq!(full.prefix_len(f64::NEG_INFINITY), 0);
        assert_eq!(full.prefix_len(f64::INFINITY), full.n_edges());
    }

    #[test]
    fn enclosing_radius_of_filtration_matches_build_time_sweep() {
        let md = MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 5.0, 0.0],
        ));
        // Build-time radius (the row-max sweep over the metric).
        let mut stats = FiltrationStats::default();
        let fe = FrontendOptions::default();
        let truncated = EdgeFiltration::build_pooled(&md, f64::INFINITY, None, &fe, &mut stats);
        // Query-time radius (derived from the complete built filtration).
        let full = EdgeFiltration::build(&md, f64::INFINITY);
        let r = enclosing_radius_of_filtration(&full);
        assert_eq!(r.to_bits(), stats.enclosing_radius.to_bits());
        // Prefix at r must equal the build-time-truncated edge set.
        let p = full.prefix(full.prefix_len(r), r);
        assert_eq!(p.edges, truncated.edges);
        // Not a complete pair list -> inapplicable.
        assert!(enclosing_radius_of_filtration(&p).is_infinite());
    }

    #[test]
    fn build_counters_count_builds() {
        let mut stats = FiltrationStats::default();
        let fe = FrontendOptions::default();
        let f = EdgeFiltration::build_pooled(&square_cloud(), 2.0, None, &fe, &mut stats);
        assert_eq!(stats.f1_builds, 1);
        assert_eq!(stats.nb_builds, 0);
        let _ = Neighborhoods::build_pooled(&f, false, None, &mut stats);
        assert_eq!(stats.nb_builds, 1);
        let _ = EdgeFiltration::build_pooled(&square_cloud(), 2.0, None, &fe, &mut stats);
        assert_eq!(stats.f1_builds, 2);
    }

    #[test]
    fn simd_modes_are_bit_identical_to_scalar() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0x51D);
        let pc = PointCloud::new(3, (0..37 * 3).map(|_| rng.next_f64()).collect());
        let md = MetricData::Points(pc);
        let pool = ThreadPool::new(4);
        for tau in [0.4, f64::INFINITY] {
            for enclosing in [false, true] {
                let mut base_stats = FiltrationStats::default();
                let base = EdgeFiltration::build_pooled(
                    &md,
                    tau,
                    Some(&pool),
                    &FrontendOptions {
                        enclosing,
                        simd: SimdMode::Scalar,
                        ..Default::default()
                    },
                    &mut base_stats,
                );
                assert_eq!(base_stats.dist_kernel, "scalar");
                for mode in [SimdMode::Auto, SimdMode::Avx2, SimdMode::Neon] {
                    let mut stats = FiltrationStats::default();
                    let f = EdgeFiltration::build_pooled(
                        &md,
                        tau,
                        Some(&pool),
                        &FrontendOptions {
                            enclosing,
                            simd: mode,
                            ..Default::default()
                        },
                        &mut stats,
                    );
                    assert_eq!(base.edges, f.edges, "mode {mode:?} tau {tau}");
                    let bb: Vec<u64> = base.values.iter().map(|v| v.to_bits()).collect();
                    let fb: Vec<u64> = f.values.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(bb, fb, "mode {mode:?} tau {tau}");
                    assert_eq!(
                        stats.enclosing_radius.to_bits(),
                        base_stats.enclosing_radius.to_bits()
                    );
                    assert!(!stats.dist_kernel.is_empty());
                }
            }
        }
    }

    #[test]
    fn non_finite_radius_falls_back_to_untruncated() {
        // An infinite coordinate makes every row max infinite, so the
        // enclosing radius is non-finite and the truncation must yield
        // the untruncated τ=∞ build (infinite edges and all).
        let md = MetricData::Points(PointCloud::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, f64::INFINITY, 0.0],
        ));
        let want = EdgeFiltration::build(&md, f64::INFINITY);
        for pool in [None, Some(ThreadPool::new(3))] {
            let mut stats = FiltrationStats::default();
            let f = EdgeFiltration::build_pooled(
                &md,
                f64::INFINITY,
                pool.as_ref(),
                &FrontendOptions::default(),
                &mut stats,
            );
            assert_eq!(f.edges, want.edges);
            assert!(!stats.enclosing_radius.is_finite());
            assert_eq!(stats.edges_pruned, 0);
            assert_eq!(f.n_edges(), 3, "infinite edges survive τ=∞");
        }
    }

    #[test]
    fn enclosing_noop_on_finite_tau_and_sparse() {
        let mut stats = FiltrationStats::default();
        let fe = FrontendOptions::default();
        let f = EdgeFiltration::build_pooled(&square_cloud(), 1.1, None, &fe, &mut stats);
        assert_eq!(f.n_edges(), 4);
        assert!(stats.enclosing_radius.is_infinite());
        assert_eq!(stats.edges_pruned, 0);
        let sd = MetricData::Sparse(SparseDistances {
            n: 3,
            entries: vec![(0, 1, 1.0), (1, 2, 2.0)],
        });
        let mut stats = FiltrationStats::default();
        let f = EdgeFiltration::build_pooled(&sd, f64::INFINITY, None, &fe, &mut stats);
        assert_eq!(f.n_edges(), 2, "sparse inputs are never truncated");
        assert!(stats.enclosing_radius.is_infinite());
    }
}
