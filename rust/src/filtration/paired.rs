//! Paired-indexing (paper §4.1).
//!
//! A triangle `{a,b,c}` with diameter edge `{a,b}` (order `kp`) is keyed
//! `⟨kp, c⟩`; a tetrahedron `{a,b,c,d}` with diameter `{a,b}` is keyed
//! `⟨kp, order({c,d})⟩`. Lexicographic order on `⟨primary, secondary⟩`
//! refines the VR filtration order, because a simplex with a larger
//! diameter appears later. 8 bytes regardless of `n`; keys bounded by
//! `O(n_e)` rather than `O(n^4)` — this is the memory contribution.

/// `⟨primary, secondary⟩`. Derived `Ord` is lexicographic, matching Eq. (1).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct Key {
    pub p: u32,
    pub s: u32,
}

impl Key {
    pub const NONE: Key = Key {
        p: u32::MAX,
        s: u32::MAX,
    };

    #[inline]
    pub fn new(p: u32, s: u32) -> Key {
        Key { p, s }
    }

    #[inline]
    pub fn is_none(self) -> bool {
        self == Key::NONE
    }

    /// Packed form for hashing / dense maps.
    #[inline]
    pub fn pack(self) -> u64 {
        ((self.p as u64) << 32) | self.s as u64
    }

    #[inline]
    pub fn unpack(x: u64) -> Key {
        Key {
            p: (x >> 32) as u32,
            s: x as u32,
        }
    }
}

impl std::fmt::Display for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "⟨{},{}⟩", self.p, self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_eq1() {
        // kp dominates; ks breaks ties (paper Eq. 1).
        assert!(Key::new(2, 0) > Key::new(1, 99));
        assert!(Key::new(1, 5) > Key::new(1, 4));
        assert!(Key::new(1, 4) == Key::new(1, 4));
    }

    #[test]
    fn pack_roundtrip_preserves_order() {
        let ks = [
            Key::new(0, 0),
            Key::new(0, 7),
            Key::new(3, 1),
            Key::new(3, 2),
            Key::new(9, 0),
        ];
        for w in ks.windows(2) {
            assert!(w[0] < w[1]);
            assert!(w[0].pack() < w[1].pack(), "packing must be monotone");
        }
        for k in ks {
            assert_eq!(Key::unpack(k.pack()), k);
        }
    }

    #[test]
    fn none_is_max() {
        assert!(Key::new(u32::MAX - 1, u32::MAX) < Key::NONE);
        assert!(Key::NONE.is_none());
    }
}
