//! Explicit-SIMD squared-distance kernels for the row-band front-end.
//!
//! The O(n²) distance pass is the dominant front-end cost for dense point
//! clouds (Otter et al. identify filtration construction as the practical
//! bottleneck at scale). This module vectorises it without changing a
//! single output bit:
//!
//! - **Lanes run across candidate points, not coordinates.** Each vector
//!   lane accumulates one point-pair's squared distance in the *same
//!   sequential axis order* as [`PointCloud::dist`], using separate
//!   multiply and add (never FMA — Rust never contracts, and neither do
//!   we). Every lane therefore performs the exact op sequence of the
//!   scalar loop and the per-pair sum `s` is bit-identical to the scalar
//!   sum, for every lane count, tile size, and remainder split.
//! - **`sqrt` never enters a vector lane.** Candidates are prefiltered in
//!   squared space against a conservatively widened `τ²` bound; only the
//!   survivors pay one scalar `sqrt`, and the emitted distance is
//!   `fl(sqrt(s))` — the very same bits `PointCloud::dist` returns. The
//!   prefilter only over-accepts (boundary candidates are re-checked
//!   exactly), so the kept edge set matches the scalar kernel exactly.
//!
//! Backends: AVX2 (x86_64, runtime-detected) and NEON (aarch64 baseline),
//! both stable-Rust `std::arch`; the scalar loop is the always-available
//! fallback and the differential oracle. A forced mode that the host
//! cannot run degrades to scalar rather than failing.

use crate::geometry::{DenseDistances, MetricData, PointCloud, SoaPoints};

use super::edge_key;

/// User-facing kernel knob: `auto` picks the widest kernel the host
/// supports at runtime; forced modes fall back to `scalar` when the
/// requested ISA is unavailable (wrong arch or missing CPU feature).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SimdMode {
    #[default]
    Auto,
    Scalar,
    Avx2,
    Neon,
}

impl SimdMode {
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s {
            "auto" => Some(SimdMode::Auto),
            "scalar" => Some(SimdMode::Scalar),
            "avx2" => Some(SimdMode::Avx2),
            "neon" => Some(SimdMode::Neon),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
        }
    }
}

/// The kernel actually selected for a build (post feature detection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Kernel {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    pub(crate) fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }
}

/// Resolve a [`SimdMode`] against the running host. NEON is part of the
/// aarch64 baseline so needs no runtime probe; AVX2 is checked with
/// `is_x86_feature_detected!`. Unsatisfiable requests degrade to scalar.
pub(crate) fn select(mode: SimdMode) -> Kernel {
    match mode {
        SimdMode::Scalar => Kernel::Scalar,
        SimdMode::Auto | SimdMode::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    return Kernel::Avx2;
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                if matches!(mode, SimdMode::Auto) {
                    return Kernel::Neon;
                }
            }
            Kernel::Scalar
        }
        SimdMode::Neon => {
            #[cfg(target_arch = "aarch64")]
            let k = Kernel::Neon;
            #[cfg(not(target_arch = "aarch64"))]
            let k = Kernel::Scalar;
            k
        }
    }
}

/// Conservative squared-space prefilter bound for threshold `tau`.
///
/// A pair is kept iff `fl(sqrt(s)) <= tau`; the vector path first tests
/// `s <= bound` and re-checks survivors exactly, so the bound only has to
/// *never reject a kept pair*. `fl(sqrt(s)) <= tau` implies
/// `s <= tau²·(1 + 5ε)` after unwinding the two roundings, and
/// `fl(tau·tau)` itself can sit one ulp below `tau²` — a relative margin
/// of `16ε` covers both with room to spare. Adding `MIN_POSITIVE` keeps
/// the margin meaningful when `tau²` is subnormal (where the relative
/// term underflows to zero); for any normal-range `tau` it is invisible.
/// Over-acceptance only costs a scalar re-check, never a wrong bit.
pub(crate) fn sq_prefilter_bound(tau: f64) -> f64 {
    if tau.is_infinite() {
        // +inf: everything passes; -inf: the exact re-check rejects all.
        return f64::INFINITY;
    }
    let t2 = tau * tau;
    t2 + t2 * (16.0 * f64::EPSILON) + f64::MIN_POSITIVE
}

/// Fill `out[t] = Σ_k (x[i,k] - x[j0+t,k])²` for `t in 0..out.len()`,
/// each sum accumulated in sequential axis order (scalar-bit-identical).
pub(crate) fn sq_row(kernel: Kernel, soa: &SoaPoints, i: usize, j0: usize, out: &mut [f64]) {
    debug_assert!(j0 + out.len() <= soa.n());
    match kernel {
        Kernel::Scalar => sq_row_scalar(soa, i, j0, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 variant is only constructed after
        // `is_x86_feature_detected!("avx2")` succeeded in `select`.
        Kernel::Avx2 => unsafe { sq_row_avx2(soa, i, j0, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is mandatory in the aarch64 baseline.
        Kernel::Neon => unsafe { sq_row_neon(soa, i, j0, out) },
    }
}

fn sq_row_scalar(soa: &SoaPoints, i: usize, j0: usize, out: &mut [f64]) {
    let dim = soa.dim();
    for (t, s) in out.iter_mut().enumerate() {
        let j = j0 + t;
        let mut acc = 0.0f64;
        for k in 0..dim {
            let d = soa.coord(i, k) - soa.coord(j, k);
            acc += d * d;
        }
        *s = acc;
    }
}

/// Candidate points are processed in blocks small enough that the block's
/// accumulator slice stays in L1 while the axis loop streams over it.
const SQ_BLOCK: usize = 512;

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sq_row_avx2(soa: &SoaPoints, i: usize, j0: usize, out: &mut [f64]) {
    use std::arch::x86_64::*;
    let m = out.len();
    let dim = soa.dim();
    let mut b0 = 0usize;
    while b0 < m {
        let b1 = (b0 + SQ_BLOCK).min(m);
        let vec_end = b0 + ((b1 - b0) / 4) * 4;
        out[b0..b1].fill(0.0);
        for k in 0..dim {
            let row = soa.coord_row(k);
            let pi = row[i];
            let c = _mm256_set1_pd(pi);
            let mut t = b0;
            while t < vec_end {
                let v = _mm256_loadu_pd(row.as_ptr().add(j0 + t));
                let d = _mm256_sub_pd(c, v);
                // mul + add, NOT fmadd: contraction would change the
                // rounding and break bit-equality with the scalar sum.
                let sq = _mm256_mul_pd(d, d);
                let acc = _mm256_add_pd(_mm256_loadu_pd(out.as_ptr().add(t)), sq);
                _mm256_storeu_pd(out.as_mut_ptr().add(t), acc);
                t += 4;
            }
            while t < b1 {
                let d = pi - row[j0 + t];
                out[t] += d * d;
                t += 1;
            }
        }
        b0 = b1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sq_row_neon(soa: &SoaPoints, i: usize, j0: usize, out: &mut [f64]) {
    use std::arch::aarch64::*;
    let m = out.len();
    let dim = soa.dim();
    let mut b0 = 0usize;
    while b0 < m {
        let b1 = (b0 + SQ_BLOCK).min(m);
        let vec_end = b0 + ((b1 - b0) / 2) * 2;
        out[b0..b1].fill(0.0);
        for k in 0..dim {
            let row = soa.coord_row(k);
            let pi = row[i];
            let c = vdupq_n_f64(pi);
            let mut t = b0;
            while t < vec_end {
                let v = vld1q_f64(row.as_ptr().add(j0 + t));
                let d = vsubq_f64(c, v);
                // vmulq + vaddq, NOT vfmaq: keep scalar rounding.
                let sq = vmulq_f64(d, d);
                let acc = vaddq_f64(vld1q_f64(out.as_ptr().add(t)), sq);
                vst1q_f64(out.as_mut_ptr().add(t), acc);
                t += 2;
            }
            while t < b1 {
                let d = pi - row[j0 + t];
                out[t] += d * d;
                t += 1;
            }
        }
        b0 = b1;
    }
}

/// Distance evaluator for one front-end build: the selected kernel plus
/// whatever derived layout it needs. Constructed once per build (the SoA
/// copy is O(n·dim), trivial next to the O(n²·dim) pass it accelerates)
/// and shared read-only across worker threads.
pub(crate) enum Dist<'a> {
    /// Scalar oracle path: distances via [`PointCloud::dist`], bitwise
    /// the historical front-end behaviour.
    Cloud(&'a PointCloud),
    /// Vector path over the SoA layout; emitted bits match `Cloud`.
    CloudSimd { soa: SoaPoints, kernel: Kernel },
    /// Precomputed distance table — already memory-bound, stays scalar.
    Table(&'a DenseDistances),
}

impl<'a> Dist<'a> {
    /// Panics on sparse inputs — those take the entry-chunk path and
    /// never reach the row-band kernels.
    pub(crate) fn new(data: &'a MetricData, mode: SimdMode) -> Dist<'a> {
        match data {
            MetricData::Points(pc) => {
                let kernel = select(mode);
                if kernel == Kernel::Scalar {
                    Dist::Cloud(pc)
                } else {
                    Dist::CloudSimd {
                        soa: SoaPoints::from_cloud(pc),
                        kernel,
                    }
                }
            }
            MetricData::Dense(dd) => Dist::Table(dd),
            MetricData::Sparse(_) => unreachable!("sparse inputs use the entry-chunk path"),
        }
    }

    pub(crate) fn kernel_name(&self) -> &'static str {
        match self {
            Dist::Cloud(_) | Dist::Table(_) => "scalar",
            Dist::CloudSimd { kernel, .. } => kernel.name(),
        }
    }

    /// Whether row-max folds through this evaluator live in squared
    /// space (vector path) rather than distance space (scalar paths).
    /// `sqrt` is monotone and correctly rounded, so folding squares and
    /// rooting once per row at the end yields the same bits as folding
    /// rooted distances — but the two spaces must not be mixed.
    pub(crate) fn rowmax_is_squared(&self) -> bool {
        matches!(self, Dist::CloudSimd { .. })
    }

    /// Emit thresholded keys for row `i` (pairs `(i, j)`, `j > i`).
    /// `bound` must be `sq_prefilter_bound(tau)`; `scratch` holds at
    /// least `n - i - 1` slots.
    pub(crate) fn fill_row(
        &self,
        i: usize,
        n: usize,
        tau: f64,
        bound: f64,
        out: &mut Vec<u128>,
        scratch: &mut [f64],
    ) {
        match self {
            Dist::Cloud(pc) => {
                for j in (i + 1)..n {
                    let d = pc.dist(i, j);
                    if d <= tau {
                        out.push(edge_key(d, i as u32, j as u32));
                    }
                }
            }
            Dist::CloudSimd { soa, kernel } => {
                let m = n - i - 1;
                let sq = &mut scratch[..m];
                sq_row(*kernel, soa, i, i + 1, sq);
                for (t, &s) in sq.iter().enumerate() {
                    if s <= bound {
                        let d = s.sqrt();
                        if d <= tau {
                            out.push(edge_key(d, i as u32, (i + 1 + t) as u32));
                        }
                    }
                }
            }
            Dist::Table(dd) => {
                for j in (i + 1)..n {
                    let d = dd.get(i, j);
                    if d <= tau {
                        out.push(edge_key(d, i as u32, j as u32));
                    }
                }
            }
        }
    }

    /// Fused τ=∞ row: emit keys thresholded at the provisional bound
    /// `tau_p` *and* fold row maxima — each pair's distance is evaluated
    /// exactly once. `row_max` is in the space reported by
    /// [`Dist::rowmax_is_squared`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fused_row(
        &self,
        i: usize,
        n: usize,
        tau_p: f64,
        bound: f64,
        out: &mut Vec<u128>,
        row_max: &mut [f64],
        scratch: &mut [f64],
    ) {
        match self {
            Dist::Cloud(pc) => {
                let mut mi = row_max[i];
                for j in (i + 1)..n {
                    let d = pc.dist(i, j);
                    mi = mi.max(d);
                    row_max[j] = row_max[j].max(d);
                    if d <= tau_p {
                        out.push(edge_key(d, i as u32, j as u32));
                    }
                }
                row_max[i] = mi;
            }
            Dist::CloudSimd { soa, kernel } => {
                let m = n - i - 1;
                let sq = &mut scratch[..m];
                sq_row(*kernel, soa, i, i + 1, sq);
                let mut mi = row_max[i];
                for (t, &s) in sq.iter().enumerate() {
                    mi = mi.max(s);
                    let rj = &mut row_max[i + 1 + t];
                    *rj = rj.max(s);
                    if s <= bound {
                        let d = s.sqrt();
                        if d <= tau_p {
                            out.push(edge_key(d, i as u32, (i + 1 + t) as u32));
                        }
                    }
                }
                row_max[i] = mi;
            }
            Dist::Table(dd) => {
                let mut mi = row_max[i];
                for j in (i + 1)..n {
                    let d = dd.get(i, j);
                    mi = mi.max(d);
                    row_max[j] = row_max[j].max(d);
                    if d <= tau_p {
                        out.push(edge_key(d, i as u32, j as u32));
                    }
                }
                row_max[i] = mi;
            }
        }
    }

    /// Fold row maxima only (no key emission) for rows `i` with `j > i`
    /// contributions — the streamed dense path's standalone `r_enc`
    /// sweep. Space convention as in [`Dist::fused_row`].
    pub(crate) fn fold_row_max(&self, i: usize, n: usize, row_max: &mut [f64], scratch: &mut [f64]) {
        match self {
            Dist::Cloud(pc) => {
                let mut mi = row_max[i];
                for j in (i + 1)..n {
                    let d = pc.dist(i, j);
                    mi = mi.max(d);
                    row_max[j] = row_max[j].max(d);
                }
                row_max[i] = mi;
            }
            Dist::CloudSimd { soa, kernel } => {
                let m = n - i - 1;
                let sq = &mut scratch[..m];
                sq_row(*kernel, soa, i, i + 1, sq);
                let mut mi = row_max[i];
                for (t, &s) in sq.iter().enumerate() {
                    mi = mi.max(s);
                    let rj = &mut row_max[i + 1 + t];
                    *rj = rj.max(s);
                }
                row_max[i] = mi;
            }
            Dist::Table(dd) => {
                let mut mi = row_max[i];
                for j in (i + 1)..n {
                    let d = dd.get(i, j);
                    mi = mi.max(d);
                    row_max[j] = row_max[j].max(d);
                }
                row_max[i] = mi;
            }
        }
    }

    /// Full max over `j != i` of `d(i, j)`, in distance space — used to
    /// seed the provisional truncation bound from a few sample rows.
    pub(crate) fn full_row_max(&self, i: usize, n: usize, scratch: &mut [f64]) -> f64 {
        match self {
            Dist::Cloud(pc) => {
                let mut m = f64::NEG_INFINITY;
                for j in 0..n {
                    if j != i {
                        m = m.max(pc.dist(i, j));
                    }
                }
                m
            }
            Dist::CloudSimd { soa, kernel } => {
                let sq = &mut scratch[..n];
                sq_row(*kernel, soa, i, 0, sq);
                let mut m = f64::NEG_INFINITY;
                for (j, &s) in sq.iter().enumerate() {
                    if j != i {
                        m = m.max(s);
                    }
                }
                // All-NaN rows leave the fold at -inf in both spaces;
                // rooting would turn that into NaN, so pass it through.
                if m == f64::NEG_INFINITY {
                    m
                } else {
                    m.sqrt()
                }
            }
            Dist::Table(dd) => {
                let mut m = f64::NEG_INFINITY;
                for j in 0..n {
                    if j != i {
                        m = m.max(dd.get(i, j));
                    }
                }
                m
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_cloud(n: usize, dim: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let coords: Vec<f64> = (0..n * dim)
            .map(|i| match i % 11 {
                0 => -0.0,
                1 => f64::MIN_POSITIVE / 8.0,
                2 => -f64::MIN_POSITIVE / 2.0,
                _ => rng.next_f64() * 2.0 - 1.0,
            })
            .collect();
        PointCloud::new(dim, coords)
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [SimdMode::Auto, SimdMode::Scalar, SimdMode::Avx2, SimdMode::Neon] {
            assert_eq!(SimdMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(SimdMode::parse("avx512"), None);
        assert_eq!(SimdMode::default(), SimdMode::Auto);
    }

    #[test]
    fn forced_modes_degrade_to_scalar_when_unavailable() {
        assert_eq!(select(SimdMode::Scalar), Kernel::Scalar);
        // The cross-arch request must never panic and must resolve to
        // *something* runnable.
        #[cfg(target_arch = "x86_64")]
        assert_eq!(select(SimdMode::Neon), Kernel::Scalar);
        #[cfg(target_arch = "aarch64")]
        {
            assert_eq!(select(SimdMode::Avx2), Kernel::Scalar);
            assert_eq!(select(SimdMode::Auto), Kernel::Neon);
        }
        let _ = select(SimdMode::Auto);
    }

    #[test]
    fn vector_sums_are_bit_identical_to_scalar() {
        let kernel = select(SimdMode::Auto);
        // Cover every lane-remainder class for both 4-lane and 2-lane
        // kernels, plus a block-boundary-ish width.
        for n in 8..=16usize {
            for &dim in &[1usize, 2, 3, 8, 20] {
                let pc = random_cloud(n, dim, (n * 31 + dim) as u64);
                let soa = SoaPoints::from_cloud(&pc);
                let mut got = vec![0.0f64; n];
                let mut want = vec![0.0f64; n];
                for i in 0..n.saturating_sub(1) {
                    let m = n - i - 1;
                    sq_row(kernel, &soa, i, i + 1, &mut got[..m]);
                    sq_row_scalar(&soa, i, i + 1, &mut want[..m]);
                    for t in 0..m {
                        assert_eq!(
                            got[t].to_bits(),
                            want[t].to_bits(),
                            "sum bits differ at n={n} dim={dim} i={i} t={t}"
                        );
                        assert_eq!(
                            got[t].sqrt().to_bits(),
                            pc.dist(i, i + 1 + t).to_bits(),
                            "rooted bits differ from PointCloud::dist"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prefilter_never_rejects_a_kept_pair() {
        let taus = [
            0.0,
            -0.0,
            1.0e-170,
            f64::MIN_POSITIVE,
            0.3,
            1.0,
            1e155,
            f64::INFINITY,
        ];
        let mut rng = Pcg32::new(7);
        for &tau in &taus {
            let bound = sq_prefilter_bound(tau);
            for _ in 0..2000 {
                let s = match rng.next_u32() % 4 {
                    0 => tau * tau,
                    1 => (tau * tau) * (1.0 + f64::EPSILON),
                    2 => rng.next_f64() * 2.0,
                    _ => rng.next_f64() * f64::MIN_POSITIVE,
                };
                if s.sqrt() <= tau {
                    assert!(
                        s <= bound,
                        "kept pair rejected by prefilter: tau={tau:e} s={s:e}"
                    );
                }
            }
        }
        // -inf: bound passes everything, the exact check rejects all.
        assert_eq!(sq_prefilter_bound(f64::NEG_INFINITY), f64::INFINITY);
    }
}
