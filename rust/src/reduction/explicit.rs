//! Explicit boundary-matrix reduction — the correctness oracle.
//!
//! Textbook persistent homology (paper §2, App. A): materialize every
//! simplex up to dim 3, build the boundary matrix, run the standard
//! column (alg. 4) or row (alg. 5) algorithm over Z/2 (or Z/p, the §7
//! extension). Memory is O(#simplices) — fine for the ≤ a-few-thousand
//! simplex fixtures the property tests use, and exactly the profile the
//! paper ascribes to explicit-representation packages (Table 5).

use crate::filtration::{EdgeFiltration, Neighborhoods};
use crate::homology::diagram::Diagram;

/// A simplex in the explicit filtration.
#[derive(Clone, Debug)]
pub struct Simplex {
    pub verts: Vec<u32>,
    pub value: f64,
    pub dim: usize,
}

/// Explicit VR filtration up to dim `max_dim + 1` (deaths in `max_dim`
/// need one dimension higher).
pub struct ExplicitFiltration {
    pub simplices: Vec<Simplex>,
}

impl ExplicitFiltration {
    /// Enumerate all simplices of the flag complex of `f` up to `top_dim`.
    pub fn build(f: &EdgeFiltration, nb: &Neighborhoods, top_dim: usize) -> Self {
        let n = f.n;
        let mut simplices: Vec<Simplex> = Vec::new();
        for v in 0..n {
            simplices.push(Simplex {
                verts: vec![v],
                value: 0.0,
                dim: 0,
            });
        }
        for (o, &(a, b)) in f.edges.iter().enumerate() {
            simplices.push(Simplex {
                verts: vec![a, b],
                value: f.values[o],
                dim: 1,
            });
        }
        if top_dim >= 2 {
            for a in 0..n {
                for b in (a + 1)..n {
                    let oab = match nb.edge_order(a, b) {
                        Some(o) => o,
                        None => continue,
                    };
                    for c in (b + 1)..n {
                        let (oac, obc) = match (nb.edge_order(a, c), nb.edge_order(b, c)) {
                            (Some(x), Some(y)) => (x, y),
                            _ => continue,
                        };
                        let diam = oab.max(oac).max(obc);
                        simplices.push(Simplex {
                            verts: vec![a, b, c],
                            value: f.values[diam as usize],
                            dim: 2,
                        });
                        if top_dim >= 3 {
                            for d in (c + 1)..n {
                                let (oad, obd, ocd) = match (
                                    nb.edge_order(a, d),
                                    nb.edge_order(b, d),
                                    nb.edge_order(c, d),
                                ) {
                                    (Some(x), Some(y), Some(z)) => (x, y, z),
                                    _ => continue,
                                };
                                let diam = diam.max(oad).max(obd).max(ocd);
                                simplices.push(Simplex {
                                    verts: vec![a, b, c, d],
                                    value: f.values[diam as usize],
                                    dim: 3,
                                });
                            }
                        }
                    }
                }
            }
        }
        // Filtration order: by value, then dim (faces first), then verts.
        simplices.sort_by(|x, y| {
            x.value
                .partial_cmp(&y.value)
                .unwrap()
                .then(x.dim.cmp(&y.dim))
                .then(x.verts.cmp(&y.verts))
        });
        Self { simplices }
    }

    /// Sparse boundary matrix: column j lists the filtration indices of
    /// the (dim-1)-faces of simplex j, ascending.
    pub fn boundary_matrix(&self) -> Vec<Vec<usize>> {
        use std::collections::HashMap;
        let mut index: HashMap<&[u32], usize> = HashMap::new();
        for (i, s) in self.simplices.iter().enumerate() {
            index.insert(&s.verts, i);
        }
        let mut cols = Vec::with_capacity(self.simplices.len());
        for s in &self.simplices {
            let mut col = Vec::new();
            if s.dim > 0 {
                for omit in 0..s.verts.len() {
                    let mut face = s.verts.clone();
                    face.remove(omit);
                    let fi = *index
                        .get(face.as_slice())
                        .expect("face must precede coface");
                    col.push(fi);
                }
                col.sort_unstable();
            }
            cols.push(col);
        }
        cols
    }
}

/// Standard column algorithm (App. A alg. 4) over Z/2 on sparse columns.
/// Returns `low[j]`: the pivot row of column j, or `usize::MAX` if zero.
pub fn standard_column_algorithm(mut cols: Vec<Vec<usize>>) -> Vec<usize> {
    let n = cols.len();
    const NONE: usize = usize::MAX;
    let mut low = vec![NONE; n];
    // pivot_of_row[r] = column whose pivot is r.
    let mut pivot_of_row = vec![NONE; n];
    for j in 0..n {
        loop {
            let l = match cols[j].last() {
                Some(&l) => l,
                None => {
                    low[j] = NONE;
                    break;
                }
            };
            let i = pivot_of_row[l];
            if i == NONE {
                low[j] = l;
                pivot_of_row[l] = j;
                break;
            }
            // cols[j] ^= cols[i] (symmetric difference of sorted lists).
            let merged = xor_sorted(&cols[j], &cols[i]);
            cols[j] = merged;
        }
    }
    low
}

/// Standard row algorithm (App. A alg. 5) over Z/2. Produces the same
/// pivots as the column algorithm (De Silva et al. 2011).
pub fn standard_row_algorithm(mut cols: Vec<Vec<usize>>) -> Vec<usize> {
    let n = cols.len();
    const NONE: usize = usize::MAX;
    let mut low = vec![NONE; n];
    for i in (0..n).rev() {
        // Find the first column (left to right) with low == i.
        let mut j = NONE;
        for (c, col) in cols.iter().enumerate() {
            if col.last() == Some(&i) {
                j = c;
                break;
            }
        }
        if j == NONE {
            continue;
        }
        low[j] = i;
        // Eliminate i from every later column with the same low.
        for k in (j + 1)..n {
            if cols[k].last() == Some(&i) {
                cols[k] = xor_sorted(&cols[k], &cols[j]);
            }
        }
    }
    low
}

fn xor_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Column reduction over Z/p (p prime) — the paper's §7 extension.
/// Columns are `(row, coeff)` sorted by row; boundary signs alternate.
pub fn column_algorithm_zp(filtration: &ExplicitFiltration, p: u64) -> Vec<usize> {
    use std::collections::HashMap;
    assert!(p >= 2);
    let mut index: HashMap<&[u32], usize> = HashMap::new();
    for (i, s) in filtration.simplices.iter().enumerate() {
        index.insert(&s.verts, i);
    }
    let n = filtration.simplices.len();
    let mut cols: Vec<Vec<(usize, u64)>> = Vec::with_capacity(n);
    for s in &filtration.simplices {
        let mut col = Vec::new();
        if s.dim > 0 {
            for omit in 0..s.verts.len() {
                let mut face = s.verts.clone();
                face.remove(omit);
                let fi = index[face.as_slice()];
                let sign = if omit % 2 == 0 { 1u64 } else { p - 1 };
                col.push((fi, sign));
            }
            col.sort_unstable();
        }
        cols.push(col);
    }
    const NONE: usize = usize::MAX;
    let mut low = vec![NONE; n];
    let mut pivot_of_row = vec![NONE; n];
    let inv = |a: u64| mod_pow(a, p - 2, p); // Fermat (p prime)
    for j in 0..n {
        loop {
            let (l, c) = match cols[j].last() {
                Some(&(l, c)) => (l, c),
                None => {
                    low[j] = NONE;
                    break;
                }
            };
            let i = pivot_of_row[l];
            if i == NONE {
                low[j] = l;
                pivot_of_row[l] = j;
                break;
            }
            // cols[j] -= (c / pivot_coeff(i)) * cols[i]  (mod p)
            let ci = cols[i].last().unwrap().1;
            let factor = (c * inv(ci)) % p;
            let mut merged: Vec<(usize, u64)> = Vec::new();
            let (a, b) = (&cols[j], &cols[i]);
            let (mut x, mut y) = (0, 0);
            while x < a.len() && y < b.len() {
                match a[x].0.cmp(&b[y].0) {
                    std::cmp::Ordering::Less => {
                        merged.push(a[x]);
                        x += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        let v = (p - (factor * b[y].1) % p) % p;
                        if v != 0 {
                            merged.push((b[y].0, v));
                        }
                        y += 1;
                    }
                    std::cmp::Ordering::Equal => {
                        let v = (a[x].1 + p - (factor * b[y].1) % p) % p;
                        if v != 0 {
                            merged.push((a[x].0, v));
                        }
                        x += 1;
                        y += 1;
                    }
                }
            }
            merged.extend_from_slice(&a[x..]);
            for &(r, v) in &b[y..] {
                let v = (p - (factor * v) % p) % p;
                if v != 0 {
                    merged.push((r, v));
                }
            }
            cols[j] = merged;
        }
    }
    low
}

fn mod_pow(mut base: u64, mut exp: u64, m: u64) -> u64 {
    let mut acc = 1u64;
    base %= m;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = acc * base % m;
        }
        base = base * base % m;
        exp >>= 1;
    }
    acc
}

/// Turn pivots into persistence diagrams per dimension (0..=max_dim).
pub fn pairs_to_diagram(
    filtration: &ExplicitFiltration,
    low: &[usize],
    max_dim: usize,
) -> Diagram {
    const NONE: usize = usize::MAX;
    let n = low.len();
    let mut is_death = vec![false; n];
    let mut diagram = Diagram::new(max_dim);
    for j in 0..n {
        if low[j] != NONE {
            is_death[j] = true;
            let i = low[j];
            let d = filtration.simplices[i].dim;
            if d <= max_dim {
                let birth = filtration.simplices[i].value;
                let death = filtration.simplices[j].value;
                diagram.push(d, birth, death);
            }
        }
    }
    // Essential classes: zero columns never appearing as a pivot row.
    let mut is_pivot_row = vec![false; n];
    for j in 0..n {
        if low[j] != NONE {
            is_pivot_row[low[j]] = true;
        }
    }
    for j in 0..n {
        if low[j] == NONE && !is_pivot_row[j] {
            let d = filtration.simplices[j].dim;
            if d <= max_dim {
                diagram.push(d, filtration.simplices[j].value, f64::INFINITY);
            }
        }
    }
    diagram
}

/// Full oracle: PD up to `max_dim` via the standard column algorithm.
pub fn oracle_diagram(f: &EdgeFiltration, nb: &Neighborhoods, max_dim: usize) -> Diagram {
    let ex = ExplicitFiltration::build(f, nb, max_dim + 1);
    let low = standard_column_algorithm(ex.boundary_matrix());
    pairs_to_diagram(&ex, &low, max_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{MetricData, PointCloud};

    fn circle(n: usize, r: f64) -> MetricData {
        let mut coords = Vec::with_capacity(n * 2);
        for i in 0..n {
            let t = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
            coords.push(r * t.cos());
            coords.push(r * t.sin());
        }
        MetricData::Points(PointCloud::new(2, coords))
    }

    #[test]
    fn circle_has_one_loop() {
        let data = circle(12, 1.0);
        let f = EdgeFiltration::build(&data, 3.0);
        let nb = Neighborhoods::build(&f, false);
        let d = oracle_diagram(&f, &nb, 1);
        // H0: 12 births, 11 die, 1 essential.
        assert_eq!(d.essential_count(0), 1);
        assert_eq!(d.finite(0).len(), 11);
        // H1: exactly one significant loop.
        let fin = d.finite(1);
        let sig: Vec<_> = fin.iter().filter(|p| p.death - p.birth > 0.2).collect();
        assert_eq!(sig.len(), 1, "{fin:?}");
    }

    #[test]
    fn column_and_row_algorithms_agree() {
        let data = circle(10, 1.0);
        let f = EdgeFiltration::build(&data, 3.0);
        let nb = Neighborhoods::build(&f, false);
        let ex = ExplicitFiltration::build(&f, &nb, 2);
        let lc = standard_column_algorithm(ex.boundary_matrix());
        let lr = standard_row_algorithm(ex.boundary_matrix());
        assert_eq!(lc, lr, "De Silva et al. 2011: same R");
    }

    #[test]
    fn z2_and_z3_agree_on_torus_free_fixtures() {
        // For complexes without torsion the PD is field-independent.
        let data = circle(9, 1.0);
        let f = EdgeFiltration::build(&data, 3.0);
        let nb = Neighborhoods::build(&f, false);
        let ex = ExplicitFiltration::build(&f, &nb, 2);
        let l2 = standard_column_algorithm(ex.boundary_matrix());
        let l3 = column_algorithm_zp(&ex, 3);
        let l5 = column_algorithm_zp(&ex, 5);
        let d2 = pairs_to_diagram(&ex, &l2, 1);
        let d3 = pairs_to_diagram(&ex, &l3, 1);
        let d5 = pairs_to_diagram(&ex, &l5, 1);
        assert!(d2.multiset_eq(&d3, 1e-12));
        assert!(d2.multiset_eq(&d5, 1e-12));
    }

    #[test]
    fn two_components() {
        let pc = PointCloud::new(1, vec![0.0, 0.1, 5.0, 5.1]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 1.0);
        let nb = Neighborhoods::build(&f, false);
        let d = oracle_diagram(&f, &nb, 1);
        assert_eq!(d.essential_count(0), 2);
    }

    #[test]
    fn xor_sorted_basics() {
        assert_eq!(xor_sorted(&[1, 3, 5], &[3, 4]), vec![1, 4, 5]);
        assert_eq!(xor_sorted(&[], &[2]), vec![2]);
        assert_eq!(xor_sorted(&[2], &[2]), Vec::<usize>::new());
    }
}
