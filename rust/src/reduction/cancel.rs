//! Cooperative cancellation for long-running reductions.
//!
//! A [`CancelToken`] carries an optional wall-clock deadline plus a
//! manual cancel flag. The reduction scheduler polls it at batch-commit
//! boundaries — the only points where no pipeline ticket is outstanding,
//! so aborting there never strands borrowed columns — and the engine
//! polls it between homology dimensions. Cancellation is therefore
//! *cooperative*: a cancelled query returns a typed
//! [`DoryError::DeadlineExceeded`](crate::error::DoryError) promptly
//! (within one batch commit), and because every structure it touched was
//! request-local, the shared [`FiltrationHandle`] stays fully serviceable.
//!
//! The default token ([`CancelToken::none`]) holds no allocation and
//! every poll is a single `Option` test, so un-deadlined callers pay
//! nothing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Inner {
    deadline: Option<Instant>,
    cancelled: AtomicBool,
}

/// Shared cancel/deadline signal; cheap to clone, `None` costs nothing.
#[derive(Clone, Default)]
pub struct CancelToken(Option<Arc<Inner>>);

impl CancelToken {
    /// A token that never cancels — the zero-cost default.
    #[inline]
    pub fn none() -> Self {
        CancelToken(None)
    }

    /// A token whose deadline is `timeout_ms` from now. `0` produces an
    /// already-expired deadline (useful for deterministic tests).
    pub fn with_timeout_ms(timeout_ms: u64) -> Self {
        CancelToken(Some(Arc::new(Inner {
            deadline: Some(Instant::now() + Duration::from_millis(timeout_ms)),
            cancelled: AtomicBool::new(false),
        })))
    }

    /// A deadline-free token that only cancels manually.
    pub fn manual() -> Self {
        CancelToken(Some(Arc::new(Inner {
            deadline: None,
            cancelled: AtomicBool::new(false),
        })))
    }

    /// Trip the manual cancel flag (idempotent).
    pub fn cancel(&self) {
        if let Some(i) = &self.0 {
            i.cancelled.store(true, Ordering::Release);
        }
    }

    /// Has the deadline passed or the flag been tripped?
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        match &self.0 {
            None => false,
            Some(i) => {
                i.cancelled.load(Ordering::Acquire)
                    || i.deadline.is_some_and(|d| Instant::now() >= d)
            }
        }
    }

    /// Poll point: `Err(DeadlineExceeded)` once cancelled.
    #[inline]
    pub fn check(&self) -> Result<(), crate::error::DoryError> {
        if self.is_cancelled() {
            Err(crate::error::DoryError::DeadlineExceeded(
                "request cancelled before the reduction finished".into(),
            ))
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_cancels() {
        let t = CancelToken::none();
        assert!(!t.is_cancelled());
        t.cancel(); // no-op on the empty token
        assert!(!t.is_cancelled());
        assert!(t.check().is_ok());
    }

    #[test]
    fn zero_timeout_is_immediately_expired() {
        let t = CancelToken::with_timeout_ms(0);
        assert!(t.is_cancelled());
        assert!(matches!(
            t.check(),
            Err(crate::error::DoryError::DeadlineExceeded(_))
        ));
    }

    #[test]
    fn generous_timeout_is_live() {
        let t = CancelToken::with_timeout_ms(3_600_000);
        assert!(!t.is_cancelled());
    }

    #[test]
    fn manual_cancel_propagates_to_clones() {
        let t = CancelToken::manual();
        let c = t.clone();
        assert!(!c.is_cancelled());
        t.cancel();
        assert!(c.is_cancelled());
    }
}
