//! Implicit row algorithm (paper §4.3.2) — the simpler implicit engine.
//!
//! The reduction state is a flat list of cursors; every step scans all of
//! them to find the smallest current key and its coefficient. Duplicate
//! columns are *not* cancelled and the scan is over the whole of `v` —
//! the two pitfalls §4.3.3 calls out. It shares the committed
//! [`GlobalState`] with the fast engine, so the two are interchangeable
//! inside the serial–parallel scheduler, which is exactly the comparison
//! Table 4 makes.

use super::fast_column::GlobalState;
use super::{ColumnSpace, ReduceResult, ReduceStats};
use crate::filtration::Key;

/// One column's reduction state: flat cursor list.
pub struct RowTable<C: Copy> {
    pub cursors: Vec<C>,
}

impl<C: Copy> Default for RowTable<C> {
    fn default() -> Self {
        Self::new()
    }
}

impl<C: Copy> RowTable<C> {
    pub fn new() -> Self {
        Self {
            cursors: Vec::new(),
        }
    }

    /// δ*: smallest key with odd coefficient; advances cursors at even
    /// lows (paper Figure 9 'reduce' step).
    pub fn find_low<S: ColumnSpace<Cursor = C>>(
        &mut self,
        space: &S,
        stats: &mut ReduceStats,
    ) -> Key {
        loop {
            // Full scan: the smallest current key and its multiplicity.
            let mut low = Key::NONE;
            let mut count = 0usize;
            for c in &self.cursors {
                let k = space.key(c);
                if k < low {
                    low = k;
                    count = 1;
                } else if k == low && !k.is_none() {
                    count += 1;
                }
            }
            if low.is_none() {
                return Key::NONE;
            }
            if count % 2 == 1 {
                return low;
            }
            // Even coefficient: advance every cursor sitting at `low`.
            let mut i = 0;
            while i < self.cursors.len() {
                if space.key(&self.cursors[i]) == low {
                    space.next(&mut self.cursors[i]);
                    stats.find_next_calls += 1;
                    if space.key(&self.cursors[i]).is_none() {
                        self.cursors.swap_remove(i);
                        continue;
                    }
                }
                i += 1;
            }
        }
    }

    pub fn insert<S: ColumnSpace<Cursor = C>>(&mut self, space: &S, cur: C) {
        if !space.key(&cur).is_none() {
            self.cursors.push(cur);
        }
    }

    /// Odd-parity column ids among live cursors (V⊥ extraction).
    pub fn odd_parity_cols<S: ColumnSpace<Cursor = C>>(&self, space: &S) -> Vec<u64> {
        let mut counts: crate::util::fxhash::FxHashMap<u64, u32> = Default::default();
        for c in &self.cursors {
            *counts.entry(space.col(c)).or_insert(0) += 1;
        }
        let mut out: Vec<u64> = counts
            .into_iter()
            .filter(|&(_, n)| n % 2 == 1)
            .map(|(col, _)| col)
            .collect();
        out.sort_unstable();
        out
    }
}

/// Sequential implicit-row reduction of `columns` (reverse filtration
/// order, clearing pre-applied). Mirrors `fast_column::reduce_all`.
pub fn reduce_all<S: ColumnSpace>(
    space: &S,
    columns: impl Iterator<Item = u64>,
    keep_zero_pairs: bool,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> ReduceResult {
    let mut state = GlobalState::new(keep_zero_pairs);
    let mut stats = ReduceStats::default();
    for col in columns {
        stats.columns += 1;
        let mut table = RowTable::new();
        table.insert(space, space.smallest(col));
        let outcome = loop {
            let low = table.find_low(space, &mut stats);
            if low.is_none() {
                break None;
            }
            // Hash probe before the (expensive) trivial probe — the two
            // pivot sets are disjoint.
            if let Some(&owner) = state.pivots.pivot_owner.get(&low.pack()) {
                table.insert(space, space.geq(owner, low));
                stats.appends += 1;
                if let Some(ops) = state.pivots.ops.get(&owner) {
                    for &op in ops {
                        table.insert(space, space.geq(op, low));
                        stats.appends += 1;
                    }
                }
                continue;
            }
            if let Some(owner) = space.trivial_owner(low) {
                if owner == col {
                    break Some((low, true));
                }
                table.insert(space, space.geq(owner, low));
                stats.appends += 1;
                continue;
            }
            break Some((low, false));
        };
        match outcome {
            None => {
                state.result.stats.zero_columns += 1;
                state.result.stats.essential += 1;
                state.result.essential.push(col);
            }
            Some((low, self_trivial)) => {
                if self_trivial {
                    state.result.stats.trivial_pairs += 1;
                } else {
                    state.pivots.pivot_owner.insert(low.pack(), col);
                    let mut ops = table.odd_parity_cols(space);
                    ops.retain(|&c| c != col);
                    if !ops.is_empty() {
                        state.pivots.ops.insert(col, ops.into_boxed_slice());
                    }
                    state.result.stats.pairs += 1;
                    if keep_zero_pairs || value_of(col) != key_value(low) {
                        state.result.pairs.push((col, low));
                    }
                }
            }
        }
    }
    let mut result = state.result;
    result.stats.columns = stats.columns;
    result.stats.appends = stats.appends;
    result.stats.find_next_calls = stats.find_next_calls;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::{EdgeFiltration, Neighborhoods};
    use crate::geometry::{MetricData, PointCloud};
    use crate::reduction::EdgeColumns;
    use crate::util::rng::Pcg32;

    #[test]
    fn row_and_fast_column_agree() {
        for seed in 0..6 {
            let mut rng = Pcg32::new(seed);
            let coords = (0..20 * 3).map(|_| rng.next_f64()).collect();
            let f = EdgeFiltration::build(
                &MetricData::Points(PointCloud::new(3, coords)),
                0.9,
            );
            let nb = Neighborhoods::build(&f, false);
            let space = EdgeColumns::new(&nb, &f);
            let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
            let a = reduce_all(
                &space,
                cols.iter().copied(),
                true,
                |c| f.values[c as usize],
                |k| f.key_value(k),
            );
            let b = crate::reduction::fast_column::reduce_all(
                &space,
                cols.iter().copied(),
                true,
                |c| f.values[c as usize],
                |k| f.key_value(k),
            );
            let mut pa = a.pairs.clone();
            let mut pb = b.pairs.clone();
            pa.sort_unstable();
            pb.sort_unstable();
            assert_eq!(pa, pb, "seed={seed}: pairs must match exactly");
            let mut ea = a.essential.clone();
            let mut eb = b.essential.clone();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "seed={seed}: essentials must match");
            assert_eq!(a.stats.trivial_pairs, b.stats.trivial_pairs, "seed={seed}");
        }
    }
}
