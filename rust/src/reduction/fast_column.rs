//! Fast implicit column algorithm (paper §4.3.3–4.3.5).
//!
//! The reduction state `v` of the current column is a hash table keyed by
//! *primary key*: only the bucket holding the smallest primary key is ever
//! ordered (a min-heap by `(secondary, column)`), every other bucket is an
//! unordered Vec — exactly the paper's trick for making insertion cheap
//! while still extracting δ* in order. Buckets are freed as soon as they
//! are drained, so `v` never approaches the size of the reduced column
//! `r` (the §4.3.3 pitfall).
//!
//! Two cursors of the same column at the same simplex are bit-identical
//! (canonical states), represent identical coboundary suffixes, and cancel
//! in pairs — the paper's flag-next elimination.
//!
//! The committed reduction state is split so the pipelined scheduler can
//! overlap phases safely:
//!
//! * [`PivotState`] — the p⊥/V⊥ maps alone. Entries are immutable once
//!   inserted, which is what makes stale reads sound (see
//!   [`super::serial_parallel`]).
//! * [`PivotView`] — read-only lookup trait. The sequential engine reads
//!   a single [`PivotState`]; the pipelined scheduler reads an
//!   [`Overlay`] of a frozen base plus the in-progress batch delta.
//! * [`GlobalState`] — [`PivotState`] plus the result accumulator, the
//!   package the sequential engines carry around.

use std::cmp::Reverse;
use std::collections::hash_map::Entry;
use std::collections::BinaryHeap;

use crate::util::fxhash::FxHashMap;

use super::{ColumnSpace, ReduceResult, ReduceStats};
use crate::filtration::Key;

/// Reduction state for one column: cursors bucketed by primary key.
pub struct BucketTable<C: Copy> {
    /// Inactive buckets: primary key -> unordered cursors.
    buckets: FxHashMap<u32, Vec<C>>,
    /// Lazy min-heap over primary keys (may contain stale duplicates).
    kp_heap: BinaryHeap<Reverse<u32>>,
    /// The active (minimal-key) bucket, ordered by `(secondary, column)`.
    active_kp: u32,
    active: BinaryHeap<Reverse<(u32, u64, usize)>>,
    slots: Vec<C>,
    free_slots: Vec<usize>,
    len: usize,
}

impl<C: Copy> BucketTable<C> {
    pub fn new() -> Self {
        Self {
            buckets: FxHashMap::default(),
            kp_heap: BinaryHeap::new(),
            active_kp: u32::MAX,
            active: BinaryHeap::new(),
            slots: Vec::new(),
            free_slots: Vec::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a (non-exhausted) cursor.
    pub fn insert<S: ColumnSpace<Cursor = C>>(&mut self, space: &S, cur: C) {
        let key = space.key(&cur);
        debug_assert!(!key.is_none());
        self.len += 1;
        if key.p == self.active_kp {
            let slot = self.alloc_slot(cur);
            self.active
                .push(Reverse((key.s, space.col(&cur), slot)));
            return;
        }
        match self.buckets.entry(key.p) {
            Entry::Occupied(mut e) => e.get_mut().push(cur),
            Entry::Vacant(e) => {
                e.insert(vec![cur]);
                self.kp_heap.push(Reverse(key.p));
            }
        }
    }

    fn alloc_slot(&mut self, cur: C) -> usize {
        if let Some(s) = self.free_slots.pop() {
            self.slots[s] = cur;
            s
        } else {
            self.slots.push(cur);
            self.slots.len() - 1
        }
    }

    /// Activate the bucket with the minimal primary key, heapifying it.
    /// Returns false when the table is exhausted.
    fn activate_min_bucket<S: ColumnSpace<Cursor = C>>(&mut self, space: &S) -> bool {
        debug_assert!(self.active.is_empty());
        // Reclaim active slot storage between buckets.
        self.slots.clear();
        self.free_slots.clear();
        while let Some(&Reverse(p)) = self.kp_heap.peek() {
            // Lazy dedup of repeated heap entries.
            self.kp_heap.pop();
            while self.kp_heap.peek() == Some(&Reverse(p)) {
                self.kp_heap.pop();
            }
            if let Some(bucket) = self.buckets.remove(&p) {
                self.active_kp = p;
                for cur in bucket {
                    let key = space.key(&cur);
                    debug_assert_eq!(key.p, p);
                    let col = space.col(&cur);
                    let slot = self.alloc_slot(cur);
                    self.active.push(Reverse((key.s, col, slot)));
                }
                return true;
            }
        }
        self.active_kp = u32::MAX;
        false
    }

    /// Find δ*: the smallest simplex with odd coefficient across the
    /// table, advancing/cancelling cursors below it. Surviving cursors at
    /// δ* remain in the table. Returns `Key::NONE` when the column is zero.
    pub fn find_low<S: ColumnSpace<Cursor = C>>(
        &mut self,
        space: &S,
        stats: &mut ReduceStats,
    ) -> Key {
        let mut run: Vec<(u64, usize)> = Vec::new();
        loop {
            if self.active.is_empty() && !self.activate_min_bucket(space) {
                return Key::NONE;
            }
            let p = self.active_kp;
            // Process one run of equal secondary key.
            let Reverse((s, col0, slot0)) = *self.active.peek().unwrap();
            run.clear();
            while let Some(&Reverse((s2, c2, sl2))) = self.active.peek() {
                if s2 != s {
                    break;
                }
                self.active.pop();
                run.push((c2, sl2));
            }
            let _ = (col0, slot0);
            // Cancel identical-column pairs: same (p, s, col) => identical
            // cursors => identical suffixes. run is sorted by col (heap pop
            // order within equal s is by col).
            let mut survivors: Vec<usize> = Vec::with_capacity(run.len());
            let mut i = 0;
            while i < run.len() {
                let col = run[i].0;
                let mut j = i;
                while j < run.len() && run[j].0 == col {
                    j += 1;
                }
                if (j - i) % 2 == 1 {
                    survivors.push(run[i].1);
                }
                // Cancelled cursors disappear entirely.
                self.len -= (j - i) - ((j - i) % 2);
                for &(_, sl) in &run[i..j] {
                    if (j - i) % 2 == 1 && sl == run[i].1 {
                        continue;
                    }
                    self.free_slots.push(sl);
                }
                i = j;
            }
            if survivors.len() % 2 == 1 {
                // δ* found; survivors stay, re-pushed at their position.
                for &sl in &survivors {
                    let cur = self.slots[sl];
                    self.active
                        .push(Reverse((s, space.col(&cur), sl)));
                }
                return Key::new(p, s);
            }
            // Even coefficient: advance every survivor past ⟨p, s⟩.
            for &sl in &survivors {
                let mut cur = self.slots[sl];
                space.next(&mut cur);
                stats.find_next_calls += 1;
                self.len -= 1;
                self.free_slots.push(sl);
                let key = space.key(&cur);
                if !key.is_none() {
                    self.insert(space, cur);
                }
            }
        }
    }

    /// Parity of occurrences per column id among all surviving cursors.
    /// Used to extract `V⊥(col)` when a pivot is claimed.
    pub fn odd_parity_cols<S: ColumnSpace<Cursor = C>>(&self, space: &S) -> Vec<u64> {
        let mut counts: FxHashMap<u64, u32> = FxHashMap::default();
        for &Reverse((_, col, _)) in self.active.iter() {
            *counts.entry(col).or_insert(0) += 1;
        }
        for bucket in self.buckets.values() {
            for cur in bucket {
                *counts.entry(space.col(cur)).or_insert(0) += 1;
            }
        }
        let mut out: Vec<u64> = counts
            .into_iter()
            .filter(|&(_, c)| c % 2 == 1)
            .map(|(col, _)| col)
            .collect();
        out.sort_unstable();
        out
    }

    /// Capacity (entries per internal buffer) a table may retain across
    /// [`BucketTable::clear`] calls. One pathological column can grow
    /// `slots`/`kp_heap`/`buckets` to the size of its reduced column
    /// (the §4.3.3 pitfall, but for *capacity* instead of content);
    /// without a bound, a reused table would pin that worst case for
    /// the rest of the run.
    const RETAINED_CAPACITY: usize = 1024;

    /// Reset the table for reuse on another column, shrinking every
    /// internal buffer to the `RETAINED_CAPACITY` high-water
    /// mark. Reusing one cleared table across a dimension's columns
    /// amortizes the per-column allocations of the dominant path while
    /// keeping the retained footprint bounded.
    pub fn clear(&mut self) {
        self.buckets.clear();
        self.buckets.shrink_to(Self::RETAINED_CAPACITY);
        self.kp_heap.clear();
        self.kp_heap.shrink_to(Self::RETAINED_CAPACITY);
        self.active.clear();
        self.active.shrink_to(Self::RETAINED_CAPACITY);
        self.slots.clear();
        self.slots.shrink_to(Self::RETAINED_CAPACITY);
        self.free_slots.clear();
        self.free_slots.shrink_to(Self::RETAINED_CAPACITY);
        self.active_kp = u32::MAX;
        self.len = 0;
    }

    /// Drain every cursor (used by tests and table-merging call sites).
    pub fn drain_cursors(&mut self) -> Vec<C> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(Reverse((_, _, slot))) = self.active.pop() {
            out.push(self.slots[slot]);
        }
        for (_, bucket) in self.buckets.drain() {
            out.extend(bucket);
        }
        self.kp_heap.clear();
        self.slots.clear();
        self.free_slots.clear();
        self.active_kp = u32::MAX;
        self.len = 0;
        out
    }
}

impl<C: Copy> Default for BucketTable<C> {
    fn default() -> Self {
        Self::new()
    }
}

/// The committed pivot maps of one dimension's reduction: p⊥ and V⊥.
///
/// Both maps are **insert-only** during a reduction (an entry, once
/// written, never changes), which is the invariant that lets the
/// pipelined scheduler read a stale snapshot: a stale miss only delays a
/// reduction step, a stale hit returns exactly the final value.
#[derive(Default)]
pub struct PivotState {
    /// Pivot key (packed) -> owning column. Trivial pivots are never here.
    pub pivot_owner: FxHashMap<u64, u64>,
    /// Column -> reduction ops (other columns summed into it). Columns
    /// with no ops are absent. Boxed slices: exact-size allocations —
    /// V⊥ dominates PH-memory (paper §4.3.1), capacity slack matters.
    pub ops: FxHashMap<u64, Box<[u64]>>,
}

impl PivotState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.pivot_owner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pivot_owner.is_empty()
    }

    /// Move every entry of `delta` into `self` (the batch-boundary merge
    /// of the pipelined scheduler). The pivot sets are disjoint — each
    /// pivot is claimed exactly once — so plain extension is exact.
    pub fn merge_from(&mut self, delta: &mut PivotState) {
        self.pivot_owner.extend(delta.pivot_owner.drain());
        self.ops.extend(delta.ops.drain());
    }
}

/// Read-only view of committed pivots, used by the reduction loops.
pub trait PivotView: Sync {
    fn owner_of(&self, packed: u64) -> Option<u64>;
    fn ops_of(&self, col: u64) -> Option<&[u64]>;

    #[inline]
    fn is_claimed(&self, packed: u64) -> bool {
        self.owner_of(packed).is_some()
    }
}

impl PivotView for PivotState {
    #[inline]
    fn owner_of(&self, packed: u64) -> Option<u64> {
        self.pivot_owner.get(&packed).copied()
    }

    #[inline]
    fn ops_of(&self, col: u64) -> Option<&[u64]> {
        self.ops.get(&col).map(|b| &b[..])
    }
}

/// Frozen base + current-batch delta, the serial-phase view of the
/// pipelined scheduler. The two pivot sets are disjoint, so lookup order
/// is a performance choice only (delta first: recent collisions cluster).
pub struct Overlay<'a> {
    pub committed: &'a PivotState,
    pub delta: &'a PivotState,
}

impl PivotView for Overlay<'_> {
    #[inline]
    fn owner_of(&self, packed: u64) -> Option<u64> {
        self.delta
            .owner_of(packed)
            .or_else(|| self.committed.owner_of(packed))
    }

    #[inline]
    fn ops_of(&self, col: u64) -> Option<&[u64]> {
        self.delta.ops_of(col).or_else(|| self.committed.ops_of(col))
    }
}

/// Committed global reduction state for one dimension (p⊥, V⊥, pairs) —
/// the bundle the sequential engines thread through their loop.
pub struct GlobalState {
    pub pivots: PivotState,
    pub result: ReduceResult,
    /// Drop zero-persistence pairs from storage (H2*: they are legion and
    /// never consulted again; H1* keeps them for clearing).
    pub keep_zero_pairs: bool,
}

impl GlobalState {
    pub fn new(keep_zero_pairs: bool) -> Self {
        Self {
            pivots: PivotState::new(),
            result: ReduceResult::default(),
            keep_zero_pairs,
        }
    }
}

/// Outcome of pushing one column as far as the committed state allows.
pub enum ColumnOutcome<C: Copy> {
    /// Reduced to zero — essential class. Carries the (emptied) table
    /// back so reuse-minded callers keep its allocations.
    Zero { table: BucketTable<C> },
    /// Ends at an unclaimed, non-trivial pivot: ready to commit.
    /// `self_trivial` records whether `low` is the column's *own* trivial
    /// pivot (so commit never re-probes — the probe is expensive for H2*).
    Claim {
        low: Key,
        self_trivial: bool,
        table: BucketTable<C>,
    },
}

/// Reduce column `col` against the committed view only (no claim).
/// This is the parallel-phase body; with immediate commit it is also the
/// whole sequential algorithm.
pub fn reduce_against<S: ColumnSpace, V: PivotView>(
    space: &S,
    view: &V,
    col: u64,
    stats: &mut ReduceStats,
) -> ColumnOutcome<S::Cursor> {
    reduce_against_reusing(space, view, col, BucketTable::new(), stats)
}

/// [`reduce_against`], reusing a caller-provided (cleared) table's
/// allocations — the sequential engine threads one table through every
/// column, recovering it from the `Claim` it commits.
pub fn reduce_against_reusing<S: ColumnSpace, V: PivotView>(
    space: &S,
    view: &V,
    col: u64,
    mut table: BucketTable<S::Cursor>,
    stats: &mut ReduceStats,
) -> ColumnOutcome<S::Cursor> {
    debug_assert!(table.is_empty(), "reuse requires a cleared table");
    let c0 = space.smallest(col);
    let low0 = space.key(&c0);
    // Apparent-pair fast path: the first low of a fresh column is the
    // smallest simplex of δcol, so self-triviality is an O(1) test — no
    // probe, no bucket table. This is the dominant case (most positive
    // simplices form trivial pairs; EXPERIMENTS §Perf). With the
    // engine's enumeration-time shortcut on, these columns are resolved
    // in-shard and never reach this path; it remains the exact fallback.
    if !low0.is_none() && space.is_self_trivial_first(col, low0) {
        return ColumnOutcome::Claim {
            low: low0,
            self_trivial: true,
            table,
        };
    }
    if !low0.is_none() {
        table.insert(space, c0);
    }
    resume_reduce(space, view, col, table, stats)
}

/// Continue reducing an existing table against the committed view.
///
/// `find_low` is idempotent on a stopped table, so a column stopped
/// against one view may be resumed against a later (larger) view — the
/// pipelined scheduler relies on exactly this.
pub fn resume_reduce<S: ColumnSpace, V: PivotView>(
    space: &S,
    view: &V,
    col: u64,
    mut table: BucketTable<S::Cursor>,
    stats: &mut ReduceStats,
) -> ColumnOutcome<S::Cursor> {
    loop {
        let low = table.find_low(space, stats);
        if low.is_none() {
            return ColumnOutcome::Zero { table };
        }
        // Committed-pivot lookup first: a hash probe is far cheaper than
        // the trivial-pair probe (FindSmallesth for H2*), and the two
        // pivot sets are disjoint (trivial pivots never enter p⊥).
        if let Some(owner) = view.owner_of(low.pack()) {
            // Note: δ(owner) alone need not contain `low` — the owner's
            // ops contribute it. Only the summed suffix has low == `low`.
            let cur = space.geq(owner, low);
            if !space.key(&cur).is_none() {
                table.insert(space, cur);
            }
            stats.appends += 1;
            if let Some(ops) = view.ops_of(owner) {
                for &op in ops {
                    let c = space.geq(op, low);
                    if !space.key(&c).is_none() {
                        table.insert(space, c);
                    }
                    stats.appends += 1;
                }
            }
            continue;
        }
        if let Some(owner) = space.trivial_owner(low) {
            if owner == col {
                // Our own trivial pivot: claimable immediately.
                return ColumnOutcome::Claim {
                    low,
                    self_trivial: true,
                    table,
                };
            }
            // Reduce with the trivial owner's raw coboundary.
            let cur = space.geq(owner, low);
            debug_assert_eq!(space.key(&cur), low);
            table.insert(space, cur);
            stats.appends += 1;
            continue;
        }
        return ColumnOutcome::Claim {
            low,
            self_trivial: false,
            table,
        };
    }
}

/// Commit a claimed column: record the pair, pivot ownership and ops.
/// `self_trivial` comes from the Claim (no re-probe). `pivots` is the
/// map the commit lands in — the live state for the sequential engines,
/// the batch delta for the pipelined scheduler.
#[allow(clippy::too_many_arguments)]
pub fn commit_claim<S: ColumnSpace>(
    space: &S,
    pivots: &mut PivotState,
    result: &mut ReduceResult,
    keep_zero_pairs: bool,
    col: u64,
    low: Key,
    self_trivial: bool,
    table: &BucketTable<S::Cursor>,
    col_value: f64,
    low_value: f64,
) {
    if self_trivial {
        // Trivial pairs: zero persistence, no p⊥/V⊥ entry (paper §4.3.5).
        result.stats.trivial_pairs += 1;
        return;
    }
    pivots.pivot_owner.insert(low.pack(), col);
    let mut ops = table.odd_parity_cols(space);
    ops.retain(|&c| c != col);
    if !ops.is_empty() {
        pivots.ops.insert(col, ops.into_boxed_slice());
    }
    result.stats.pairs += 1;
    if keep_zero_pairs || col_value != low_value {
        result.pairs.push((col, low));
    }
}

/// Sequential fast-implicit-column reduction of `columns` (already in
/// reverse filtration order, clearing applied by the caller).
pub fn reduce_all<S: ColumnSpace>(
    space: &S,
    columns: impl Iterator<Item = u64>,
    keep_zero_pairs: bool,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> ReduceResult {
    let mut state = GlobalState::new(keep_zero_pairs);
    let mut stats = ReduceStats::default();
    // One table reused across all columns (cleared with a bounded
    // retained capacity between them): the per-column allocation churn
    // of the dominant path goes away, while a pathological column's
    // high-water mark is dropped at the next `clear`.
    let mut spare: BucketTable<S::Cursor> = BucketTable::new();
    for col in columns {
        stats.columns += 1;
        let table = std::mem::take(&mut spare);
        match reduce_against_reusing(space, &state.pivots, col, table, &mut stats) {
            ColumnOutcome::Zero { table } => {
                // The table emptied itself reducing to zero; reclaim its
                // allocations for the next column too.
                state.result.stats.zero_columns += 1;
                state.result.stats.essential += 1;
                state.result.essential.push(col);
                spare = table;
                spare.clear();
            }
            ColumnOutcome::Claim {
                low,
                self_trivial,
                table,
            } => {
                commit_claim(
                    space,
                    &mut state.pivots,
                    &mut state.result,
                    keep_zero_pairs,
                    col,
                    low,
                    self_trivial,
                    &table,
                    value_of(col),
                    key_value(low),
                );
                spare = table;
                spare.clear();
            }
        }
    }
    let mut result = state.result;
    result.stats.columns = stats.columns;
    result.stats.appends = stats.appends;
    result.stats.find_next_calls = stats.find_next_calls;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::{EdgeFiltration, Neighborhoods};
    use crate::geometry::{MetricData, PointCloud};
    use crate::reduction::EdgeColumns;
    use crate::util::rng::Pcg32;

    fn random_filtration(n: usize, dim: usize, tau: f64, seed: u64) -> EdgeFiltration {
        let mut rng = Pcg32::new(seed);
        let coords = (0..n * dim).map(|_| rng.next_f64()).collect();
        EdgeFiltration::build(&MetricData::Points(PointCloud::new(dim, coords)), tau)
    }

    #[test]
    fn bucket_table_single_cursor_roundtrip() {
        let f = random_filtration(16, 2, 1.2, 1);
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        // A single cursor: find_low must walk the coboundary in order,
        // returning each key exactly once if we advance manually.
        for e in 0..f.n_edges() as u64 {
            let c0 = space.smallest(e);
            if space.key(&c0).is_none() {
                continue;
            }
            let mut t = BucketTable::new();
            t.insert(&space, c0);
            let mut stats = ReduceStats::default();
            let low = t.find_low(&space, &mut stats);
            assert_eq!(low, space.key(&c0), "first low is the smallest simplex");
        }
    }

    #[test]
    fn identical_cursors_cancel() {
        let f = random_filtration(16, 2, 1.2, 2);
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        for e in 0..f.n_edges() as u64 {
            let c0 = space.smallest(e);
            if space.key(&c0).is_none() {
                continue;
            }
            let mut t = BucketTable::new();
            t.insert(&space, c0);
            t.insert(&space, c0);
            let mut stats = ReduceStats::default();
            let low = t.find_low(&space, &mut stats);
            assert!(low.is_none(), "e={e}: duplicate column must cancel to zero");
            assert_eq!(t.len(), 0);
        }
    }

    #[test]
    fn two_cursors_xor_coboundaries() {
        // Table with cursors of two different edges must produce the
        // symmetric difference of their coboundaries, in order.
        let f = random_filtration(14, 3, 1.0, 3);
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        let ne = f.n_edges() as u32;
        let mut checked = 0;
        for e1 in 0..ne.min(30) {
            for e2 in (e1 + 1)..ne.min(30) {
                let a = crate::coboundary::edges::brute_force_coboundary(&nb, &f, e1);
                let b = crate::coboundary::edges::brute_force_coboundary(&nb, &f, e2);
                let mut want: Vec<_> = a
                    .iter()
                    .filter(|k| !b.contains(k))
                    .chain(b.iter().filter(|k| !a.contains(k)))
                    .copied()
                    .collect();
                want.sort_unstable();
                let c1 = space.smallest(e1 as u64);
                let c2 = space.smallest(e2 as u64);
                let mut t = BucketTable::new();
                if !space.key(&c1).is_none() {
                    t.insert(&space, c1);
                }
                if !space.key(&c2).is_none() {
                    t.insert(&space, c2);
                }
                let mut got = Vec::new();
                let mut stats = ReduceStats::default();
                loop {
                    let low = t.find_low(&space, &mut stats);
                    if low.is_none() {
                        break;
                    }
                    got.push(low);
                    // Advance every cursor sitting at `low`.
                    let drained = t.drain_cursors();
                    for mut c in drained {
                        if space.key(&c) == low {
                            space.next(&mut c);
                        }
                        if !space.key(&c).is_none() {
                            t.insert(&space, c);
                        }
                    }
                }
                assert_eq!(got, want, "e1={e1} e2={e2}");
                checked += 1;
            }
        }
        assert!(checked > 10);
    }

    #[test]
    fn clear_bounds_retained_capacity_and_preserves_behavior() {
        type T = BucketTable<TestCursor>;
        #[derive(Clone, Copy)]
        struct TestCursor; // capacity test only — never dereferenced
        // Grow every internal buffer far past the retention bound via
        // the raw fields (same-module test), then clear and check the
        // high-water mark is dropped.
        let big = 50 * T::RETAINED_CAPACITY;
        let mut t: T = BucketTable::new();
        t.slots.reserve(big);
        t.free_slots.reserve(big);
        t.kp_heap.reserve(big);
        t.active.reserve(big);
        for k in 0..big as u32 {
            t.buckets.insert(k, Vec::new());
        }
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.len(), 0);
        assert!(t.slots.capacity() <= 2 * T::RETAINED_CAPACITY, "slots");
        assert!(t.free_slots.capacity() <= 2 * T::RETAINED_CAPACITY, "free_slots");
        assert!(t.kp_heap.capacity() <= 2 * T::RETAINED_CAPACITY, "kp_heap");
        assert!(t.active.capacity() <= 2 * T::RETAINED_CAPACITY, "active");
        assert!(t.buckets.capacity() <= 4 * T::RETAINED_CAPACITY, "buckets");

        // And a cleared-then-reused table reduces identically to a
        // fresh one (the reduce_all loop relies on this).
        let f = random_filtration(16, 2, 1.2, 9);
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        let mut reused = BucketTable::new();
        for e in 0..f.n_edges() as u64 {
            let c0 = space.smallest(e);
            if space.key(&c0).is_none() {
                continue;
            }
            let mut fresh = BucketTable::new();
            fresh.insert(&space, c0);
            reused.insert(&space, c0);
            let mut s1 = ReduceStats::default();
            let mut s2 = ReduceStats::default();
            assert_eq!(
                fresh.find_low(&space, &mut s1),
                reused.find_low(&space, &mut s2),
                "e={e}"
            );
            reused.clear();
        }
    }

    #[test]
    fn overlay_prefers_no_side_and_misses_nowhere() {
        // Disjoint maps: every entry of either side is visible, none is
        // shadowed, and misses stay misses.
        let mut base = PivotState::new();
        base.pivot_owner.insert(1, 10);
        base.ops.insert(10, vec![3, 4].into_boxed_slice());
        let mut delta = PivotState::new();
        delta.pivot_owner.insert(2, 20);
        let view = Overlay {
            committed: &base,
            delta: &delta,
        };
        assert_eq!(view.owner_of(1), Some(10));
        assert_eq!(view.owner_of(2), Some(20));
        assert_eq!(view.owner_of(3), None);
        assert_eq!(view.ops_of(10), Some(&[3u64, 4][..]));
        assert_eq!(view.ops_of(20), None);
        assert!(view.is_claimed(1) && view.is_claimed(2) && !view.is_claimed(99));
        // Merge empties the delta and lands everything in the base.
        base.merge_from(&mut delta);
        assert!(delta.is_empty());
        assert_eq!(base.len(), 2);
        assert_eq!(base.owner_of(2), Some(20));
    }
}
