//! Persistent work-stealing thread pool (paper §4.4, rebuilt).
//!
//! The paper's pool ("threads are created before the computation of PH
//! and woken up when they are required") handed out *fixed* chunks
//! through a wake-all condvar: every worker got one contiguous slice and
//! the caller blocked until the slowest worker finished — one straggler
//! column idled the whole pool. This rebuild keeps the persistent
//! workers and the borrow-the-caller's-stack job model, but replaces the
//! fixed chunks with **per-worker deques and work stealing**:
//!
//! * a generation splits `0..len` into `grain`-sized tasks dealt
//!   round-robin into per-worker deques;
//! * a worker pops its own deque from the *front* and, when empty,
//!   steals from the *back* of a victim's deque (classic Chase–Lev
//!   discipline, here with plain mutexed deques — tasks are
//!   column-granular, so queue ops are not the bottleneck);
//! * completion is task-counted, not worker-counted: the caller's
//!   [`Ticket`] resolves when the last *task* of its generation retires,
//!   no matter which workers ran it.
//!
//! **Multiple generations may be in flight at once.** Each generation
//! owns its job closure, task count, and panic flag, and every queued
//! task is tagged with its generation, so N callers (concurrent session
//! queries, the pipelined scheduler, the parallel front-end) share one
//! pool without coordinating. Per-worker queues keep one *lane* per live
//! generation and pick lanes with a rotating cursor — bounded streaks of
//! same-generation tasks for job-handle locality, then a forced rotation
//! — so a huge generation cannot starve a small one submitted after it.
//! Panics are reported to the owning generation's ticket only; other
//! in-flight generations are unaffected.
//!
//! [`ThreadPool::submit_stealing`] returns without blocking, which is
//! what lets the serial–parallel scheduler overlap batch *k*'s serial
//! commit phase with batch *k+1*'s parallel push phase (see
//! [`super::serial_parallel`]). The pool also keeps cumulative counters
//! (tasks, steals, busy time, generation spans) that back the
//! `EngineStats` scheduler report.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

type Job = Arc<dyn Fn(usize, Range<usize>) + Send + Sync>;

/// Max consecutive same-generation tasks a worker runs before the lane
/// pick is forced to rotate. Small enough that a competing generation is
/// served within a few column-granular tasks; large enough to amortize
/// the state-lock job acquire/release across a streak.
const FAIR_STREAK: u32 = 8;

/// A raw shared view of a mutable slice for pool jobs that write
/// provably disjoint index sets (filtration tile splices, the CSR
/// counting-scatter, sorted-chunk splits). The safe alternative — one
/// `Mutex` per destination — would serialize exactly the writes the
/// parallel front-end exists to spread across workers.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _life: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write slot `i`.
    ///
    /// # Safety
    ///
    /// While the generation runs, no two tasks may touch the same index
    /// and nobody may read an index a writer holds.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(v) }
    }

    /// Exclusive view of `range`.
    ///
    /// # Safety
    ///
    /// Ranges handed to concurrently running tasks must be pairwise
    /// disjoint, and nobody may read them while the tasks run.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, range: Range<usize>) -> &'a mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len()) }
    }
}

/// Per-worker task queues: one non-empty *lane* of index ranges per live
/// generation, in submit order. Lanes are pruned the moment they drain,
/// so `lanes` only ever holds generations with queued work here.
#[derive(Default)]
struct WorkerQueues {
    lanes: Vec<(u64, VecDeque<Range<usize>>)>,
    /// Retired lane buffers kept for reuse (bounded, mirroring
    /// `BucketTable::clear`'s retained-capacity discipline — without the
    /// cap a pathological generation would pin its high-water mark for
    /// the pool's engine-long lifetime).
    spares: Vec<VecDeque<Range<usize>>>,
}

impl WorkerQueues {
    fn retire_lane(&mut self, idx: usize) {
        let (_, dq) = self.lanes.remove(idx);
        if self.spares.len() < 2 && dq.capacity() <= 4096 {
            self.spares.push(dq);
        }
    }
}

/// Cumulative pool counters (monotone; snapshot before/after a section
/// and subtract to get per-section numbers). With concurrent callers the
/// deltas attribute the *pool's* work in a window, not one caller's.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Generations submitted.
    pub generations: u64,
    /// Tasks executed.
    pub tasks: u64,
    /// Tasks taken from another worker's deque.
    pub steals: u64,
    /// Total worker time spent inside task bodies.
    pub busy_ns: u64,
    /// Total wall time from submit to last-task-retired, per generation.
    pub span_ns: u64,
}

/// One in-flight generation: its job closure, progress counters and
/// panic flag. Lives in `State::live` from submit until the owning
/// [`Ticket`] observes completion and removes it.
struct GenEntry {
    gen: u64,
    job: Job,
    /// Tasks of this generation not yet retired.
    remaining: usize,
    /// Workers currently holding a clone of `job`. The ticket resolves
    /// only when this hits zero, so captured borrows are never released
    /// while any worker still holds the (lifetime-erased) closure — true
    /// scoped-thread semantics, not just last-task-retired.
    held: usize,
    /// A task body of this generation panicked (re-raised by the owning
    /// ticket's wait; other generations are unaffected).
    panicked: bool,
    /// Submit instant (for span accounting).
    started: Instant,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Per-worker task queues.
    queues: Vec<Mutex<WorkerQueues>>,
    /// Tasks dealt into queues and not yet popped (any generation).
    /// Governs worker sleep: incremented under the state lock at submit,
    /// decremented at pop, re-checked under the state lock before a
    /// worker parks — so a wakeup can never be lost.
    pending: AtomicUsize,
    /// Rotating lane cursor shared by all workers: statistically fair
    /// selection among live generations without per-worker bookkeeping.
    rr: AtomicUsize,
    generations: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
    busy_ns: AtomicU64,
    span_ns: AtomicU64,
}

struct State {
    /// Last generation id handed out.
    generation: u64,
    /// In-flight generations, submit order. Small (one per concurrent
    /// caller), so linear scans are fine.
    live: Vec<GenEntry>,
    shutdown: bool,
}

/// Fixed-size pool; workers live for the pool's lifetime. `Sync`: any
/// number of threads may submit generations concurrently through a
/// shared reference.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    n: usize,
}

/// Handle for an in-flight generation. Dropping it waits too, so
/// borrowed job data can never be released while workers still run.
#[must_use = "wait on the ticket before the job's borrowed data goes out of scope"]
pub struct Ticket<'p> {
    pool: &'p ThreadPool,
    gen: u64,
    done: bool,
}

impl Ticket<'_> {
    /// Block until every task of this generation has retired and every
    /// worker has dropped its handle on the job closure.
    pub fn wait(mut self) {
        self.wait_ref();
    }

    fn wait_ref(&mut self) {
        if self.done {
            return;
        }
        let shared = &self.pool.shared;
        let mut st = shared.state.lock().unwrap();
        let entry = loop {
            let idx = st
                .live
                .iter()
                .position(|e| e.gen == self.gen)
                .expect("ticket's generation must be live until its own wait removes it");
            if st.live[idx].remaining == 0 && st.live[idx].held == 0 {
                break st.live.remove(idx);
            }
            st = shared.done_cv.wait(st).unwrap();
        };
        drop(st);
        self.done = true;
        let panicked = entry.panicked;
        // The job closure (and any captured values' destructors) drops
        // here, on the owning caller's thread, outside the state lock.
        drop(entry);
        if panicked {
            panic!("ThreadPool: a job panicked in a worker thread");
        }
    }
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        self.wait_ref();
    }
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                live: Vec::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            queues: (0..n).map(|_| Mutex::new(WorkerQueues::default())).collect(),
            pending: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            generations: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            span_ns: AtomicU64::new(0),
        });
        let workers = (0..n)
            .map(|tid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dory-worker-{tid}"))
                    .spawn(move || worker_loop(tid, shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, n }
    }

    pub fn threads(&self) -> usize {
        self.n
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            generations: self.shared.generations.load(Ordering::Relaxed),
            tasks: self.shared.tasks.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            busy_ns: self.shared.busy_ns.load(Ordering::Relaxed),
            span_ns: self.shared.span_ns.load(Ordering::Relaxed),
        }
    }

    /// Start a generation: split `0..len` into `grain`-sized tasks, deal
    /// them round-robin into the worker deques, wake the pool and return
    /// immediately. `f(tid, range)` runs once per task on whichever
    /// worker pops (or steals) it. Any number of generations may be in
    /// flight at once — concurrent session queries and the pipelined
    /// scheduler all share the pool — and the workers interleave them
    /// fairly (see the module docs).
    ///
    /// The returned ticket is tied to `'scope`, so the borrow checker
    /// keeps everything the closure captures alive until the ticket is
    /// waited on or dropped (both block until every task has retired —
    /// the same discipline as a scoped thread).
    ///
    /// # Safety
    ///
    /// The closure is type-erased behind an `Arc` whose `'static` bound
    /// is obtained via transmute. The lifetime tie above makes ordinary
    /// drop-based control flow sound, but the caller must not leak the
    /// ticket (`mem::forget`, `ManuallyDrop`, leaked `Rc` cycles, …):
    /// a leaked ticket skips the drop-wait, after which captured borrows
    /// may dangle while workers still execute. The safe wrappers
    /// ([`Self::run`], [`Self::run_stealing`]) wait before returning and
    /// are sound for any caller.
    pub unsafe fn submit_stealing<'scope, F>(
        &'scope self,
        len: usize,
        grain: usize,
        f: F,
    ) -> Ticket<'scope>
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        // SAFETY: forwarded to submit_stealing_regions under the same
        // contract (caller must not leak the ticket).
        unsafe { self.submit_stealing_regions(&[(len, grain)], f) }
    }

    /// Start a generation over several concatenated index *regions*, each
    /// with its own task grain. Region `r` covers the global indices
    /// `offset_r..offset_r + len_r` where `offset_r` is the summed length
    /// of all earlier regions, and is split into `grain_r`-sized tasks.
    /// Tasks never straddle a region boundary, so a heterogeneous
    /// generation (e.g. fine-grained column pushes alongside coarse
    /// enumeration shards) keeps each region independently stealable.
    ///
    /// Regions are dealt in order, continuing the round-robin across the
    /// boundary: a later region's tasks land at the *backs* of the worker
    /// deques, which is exactly where idle workers steal from first.
    ///
    /// # Safety
    ///
    /// Identical contract to [`Self::submit_stealing`].
    pub unsafe fn submit_stealing_regions<'scope, F>(
        &'scope self,
        regions: &[(usize, usize)],
        f: F,
    ) -> Ticket<'scope>
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        let arc: Arc<dyn Fn(usize, Range<usize>) + Send + Sync + 'scope> = Arc::new(f);
        // Erase the lifetime (see safety note above).
        let arc: Job = unsafe { std::mem::transmute(arc) };
        let mut n_tasks = 0usize;
        for &(len, grain) in regions {
            n_tasks += len.div_ceil(grain.max(1));
        }
        let mut st = self.shared.state.lock().unwrap();
        st.generation += 1;
        let gen = st.generation;
        self.shared.generations.fetch_add(1, Ordering::Relaxed);
        if n_tasks == 0 {
            // Nothing to do: pre-resolve so wait() returns immediately.
            return Ticket {
                pool: self,
                gen,
                done: true,
            };
        }
        st.live.push(GenEntry {
            gen,
            job: arc,
            remaining: n_tasks,
            held: 0,
            panicked: false,
            started: Instant::now(),
        });
        // Deal while holding the state lock: nothing of this generation
        // can retire before the lock is released, and workers parked on
        // `work_cv` re-check `pending` under the same lock, so the
        // increment below can never be missed.
        let mut offset = 0usize;
        let mut w = 0usize;
        for &(len, grain) in regions {
            let grain = grain.max(1);
            let mut start = 0usize;
            while start < len {
                let end = (start + grain).min(len);
                let mut q = self.shared.queues[w % self.n].lock().unwrap();
                if q.lanes.last().map(|l| l.0) != Some(gen) {
                    let dq = q.spares.pop().unwrap_or_default();
                    q.lanes.push((gen, dq));
                }
                q.lanes
                    .last_mut()
                    .unwrap()
                    .1
                    .push_back(offset + start..offset + end);
                drop(q);
                start = end;
                w += 1;
            }
            offset += len;
        }
        self.shared.pending.fetch_add(n_tasks, Ordering::Release);
        self.shared.work_cv.notify_all();
        drop(st);
        Ticket {
            pool: self,
            gen,
            done: false,
        }
    }

    /// Blocking fan-out over `0..len` with work stealing.
    pub fn run_stealing<'scope, F>(&self, len: usize, grain: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        // SAFETY: the ticket is waited on before this frame returns, so
        // every capture of `f` outlives all worker uses.
        unsafe { self.submit_stealing(len, grain, f) }.wait();
    }

    /// Run `f(i)` once per index `i in 0..threads()`; blocks until all
    /// return. (Task-indexed: `i` is the task id, not the executing
    /// worker — with stealing the two can differ.)
    pub fn run<'scope, F>(&self, f: F)
    where
        F: Fn(usize) + Send + Sync + 'scope,
    {
        self.run_stealing(self.n, 1, move |_tid, r| {
            for i in r {
                f(i);
            }
        });
    }
}

/// Pop a task from this worker's own queue, front-first within a lane.
/// `prefer` biases the pick toward the generation whose job handle the
/// worker already holds; the caller clears it every [`FAIR_STREAK`]
/// tasks so a competing generation is always served promptly.
fn pop_own(shared: &Shared, tid: usize, prefer: Option<u64>) -> Option<(u64, Range<usize>)> {
    let mut q = shared.queues[tid].lock().unwrap();
    let k = q.lanes.len();
    if k == 0 {
        return None;
    }
    let idx = prefer
        .and_then(|g| q.lanes.iter().position(|l| l.0 == g))
        .unwrap_or_else(|| shared.rr.fetch_add(1, Ordering::Relaxed) % k);
    let gen = q.lanes[idx].0;
    // Lanes are pruned when drained, so every lane is non-empty.
    let r = q.lanes[idx].1.pop_front().unwrap();
    if q.lanes[idx].1.is_empty() {
        q.retire_lane(idx);
    }
    drop(q);
    shared.pending.fetch_sub(1, Ordering::AcqRel);
    Some((gen, r))
}

/// Steal a task from a victim's queue, back-first within a rotating lane.
fn steal(shared: &Shared, tid: usize) -> Option<(u64, Range<usize>)> {
    let n = shared.queues.len();
    for off in 1..n {
        let victim = (tid + off) % n;
        let mut q = shared.queues[victim].lock().unwrap();
        let k = q.lanes.len();
        if k == 0 {
            continue;
        }
        let idx = shared.rr.fetch_add(1, Ordering::Relaxed) % k;
        let gen = q.lanes[idx].0;
        let r = q.lanes[idx].1.pop_back().unwrap();
        if q.lanes[idx].1.is_empty() {
            q.retire_lane(idx);
        }
        drop(q);
        shared.pending.fetch_sub(1, Ordering::AcqRel);
        shared.steals.fetch_add(1, Ordering::Relaxed);
        return Some((gen, r));
    }
    None
}

/// Clone the generation's job and mark this worker as holding it.
fn acquire_job(shared: &Shared, gen: u64) -> Job {
    let mut st = shared.state.lock().unwrap();
    let e = st
        .live
        .iter_mut()
        .find(|e| e.gen == gen)
        .expect("a queued task's generation must be live");
    e.held += 1;
    e.job.clone()
}

/// Drop the held job clone and, if that was the last handle on a fully
/// retired generation, wake its ticket. The clone is dropped *before*
/// the bookkeeping, so once a ticket sees `held == 0` no worker can
/// touch the closure again (not even destructors of captured values).
fn release_job(shared: &Shared, held: &mut Option<(u64, Job)>) {
    let Some((gen, job)) = held.take() else {
        return;
    };
    drop(job);
    let mut st = shared.state.lock().unwrap();
    let e = st
        .live
        .iter_mut()
        .find(|e| e.gen == gen)
        .expect("a held generation stays live until every handle is released");
    e.held -= 1;
    let resolve = e.held == 0 && e.remaining == 0;
    drop(st);
    if resolve {
        shared.done_cv.notify_all();
    }
}

fn worker_loop(tid: usize, shared: Arc<Shared>) {
    // Job handle cached across consecutive same-generation tasks, and a
    // streak counter that forces the lane pick to rotate (fairness).
    let mut held: Option<(u64, Job)> = None;
    let mut streak = 0u32;
    loop {
        let prefer = match &held {
            Some((g, _)) if streak < FAIR_STREAK => Some(*g),
            _ => None,
        };
        match pop_own(&shared, tid, prefer).or_else(|| steal(&shared, tid)) {
            Some((gen, range)) => {
                if held.as_ref().map(|(g, _)| *g) != Some(gen) {
                    release_job(&shared, &mut held);
                    held = Some((gen, acquire_job(&shared, gen)));
                }
                // A rotated (non-preferred) pick starts a fresh streak.
                streak = if prefer.is_some() { streak + 1 } else { 1 };
                let job = &held.as_ref().unwrap().1;
                let t0 = Instant::now();
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    job(tid, range);
                }));
                shared
                    .busy_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                shared.tasks.fetch_add(1, Ordering::Relaxed);
                // Retire the task against its generation.
                let mut st = shared.state.lock().unwrap();
                let e = st
                    .live
                    .iter_mut()
                    .find(|e| e.gen == gen)
                    .expect("an executing task's generation must be live");
                if ok.is_err() {
                    e.panicked = true;
                }
                e.remaining -= 1;
                if e.remaining == 0 {
                    // Last task of the generation: stamp the span. The
                    // ticket still waits for `held` to drain — this very
                    // worker holds a handle — so no wakeup is needed yet;
                    // the final `release_job` delivers it.
                    let span = e.started.elapsed().as_nanos() as u64;
                    shared.span_ns.fetch_add(span, Ordering::Relaxed);
                }
            }
            None => {
                // Out of work: release the cached job handle (waking any
                // ticket this worker was the last holder for), then park
                // until new tasks are dealt.
                release_job(&shared, &mut held);
                streak = 0;
                let mut st = shared.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if shared.pending.load(Ordering::Acquire) > 0 {
                        break;
                    }
                    st = shared.work_cv.wait(st).unwrap();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_one_task_per_index() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.run(|_i| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn reusable_across_generations() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(|i| {
                sum.fetch_add(i as u64 + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(sum.load(Ordering::SeqCst), 50 * (1 + 2 + 3));
    }

    #[test]
    fn coarse_chunks_cover_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let len = 1003usize;
        let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        pool.run_stealing(len, len.div_ceil(4), |_tid, range| {
            for i in range {
                marks[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn stealing_covers_range_exactly_once() {
        let pool = ThreadPool::new(4);
        for grain in [1usize, 3, 17, 1000] {
            let len = 997;
            let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
            pool.run_stealing(len, grain, |_tid, range| {
                for i in range {
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "grain={grain}"
            );
        }
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let total = AtomicU64::new(0);
        pool.run_stealing(data.len(), 2, |_tid, r| {
            let s: u64 = data[r].iter().sum();
            total.fetch_add(s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let hits = AtomicU64::new(0);
        pool.run_stealing(10, 3, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn empty_generation_completes() {
        let pool = ThreadPool::new(3);
        pool.run_stealing(0, 1, |_t, _r| panic!("no tasks must run"));
        // And again after a real generation (generation counter moves on).
        let hits = AtomicU64::new(0);
        pool.run_stealing(5, 2, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        pool.run_stealing(0, 1, |_t, _r| panic!("no tasks must run"));
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn submit_overlaps_caller_work() {
        // The pipelining contract: the caller keeps computing while the
        // generation runs, then joins at the ticket.
        let pool = ThreadPool::new(2);
        let worker_sum = AtomicU64::new(0);
        // SAFETY: the ticket is waited on below, before worker_sum dies.
        let ticket = unsafe {
            pool.submit_stealing(64, 4, |_t, r| {
                for i in r {
                    worker_sum.fetch_add(i as u64, Ordering::SeqCst);
                }
            })
        };
        // Caller-side "serial phase".
        let mut serial_sum = 0u64;
        for i in 0..64u64 {
            serial_sum += i;
        }
        ticket.wait();
        assert_eq!(worker_sum.load(Ordering::SeqCst), serial_sum);
    }

    #[test]
    fn ticket_drop_waits_for_completion() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        {
            // SAFETY: the ticket is dropped at the end of this block,
            // which blocks until every task retired; `hits` outlives it.
            let _ticket = unsafe {
                pool.submit_stealing(256, 1, |_t, r| {
                    for _ in r {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
            };
            // _ticket dropped here → must block until all 256 ran.
        }
        assert_eq!(hits.load(Ordering::SeqCst), 256);
    }

    #[test]
    fn clean_shutdown_under_load() {
        for round in 0..20 {
            let pool = ThreadPool::new(4);
            if round % 3 != 0 {
                let spin = AtomicU64::new(0);
                pool.run_stealing(500, 1, |_t, _r| {
                    // A few hundred ns of real work per task.
                    for _ in 0..50 {
                        spin.fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert_eq!(spin.load(Ordering::Relaxed), 500 * 50);
            }
            // Pool dropped immediately — workers must join cleanly.
        }
    }

    #[test]
    fn stealing_occurs_under_imbalance() {
        // Deal slow tasks to worker 0's deque (round-robin puts task
        // ids ≡ 0 (mod n) there); the other workers drain instantly and
        // must steal from it.
        let pool = ThreadPool::new(8);
        let before = pool.stats();
        let marks: Vec<AtomicU64> = (0..800).map(|_| AtomicU64::new(0)).collect();
        let sink = AtomicU64::new(0);
        pool.run_stealing(800, 1, |_t, r| {
            for i in r {
                if i % 8 == 0 {
                    // ~tens of µs of spinning: worker 0 cannot drain its
                    // 100 slow tasks before the 7 idle workers steal.
                    for k in 0..20_000u64 {
                        sink.fetch_add(k, Ordering::Relaxed);
                    }
                }
                marks[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        let after = pool.stats();
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
        assert!(
            after.steals > before.steals,
            "expected steals under an imbalanced load, got {}",
            after.steals - before.steals
        );
        assert_eq!(after.tasks - before.tasks, 800);
    }

    #[test]
    fn interleaving_stress_across_seeds() {
        // Loom-style substitute: many seeded schedules of mixed-duration
        // tasks; every index must be executed exactly once, every
        // generation must terminate.
        for seed in 0..40u64 {
            let mut rng = Pcg32::new(seed);
            let threads = 1 + rng.gen_range(8) as usize;
            let len = 1 + rng.gen_range(300) as usize;
            let grain = 1 + rng.gen_range(16) as usize;
            let weights: Vec<u32> = (0..len).map(|_| rng.gen_range(400)).collect();
            let pool = ThreadPool::new(threads);
            let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
            let sink = AtomicU64::new(0);
            pool.run_stealing(len, grain, |_t, r| {
                for i in r {
                    for k in 0..weights[i] {
                        sink.fetch_add(k as u64, Ordering::Relaxed);
                    }
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "seed={seed} threads={threads} len={len} grain={grain}"
            );
        }
    }

    #[test]
    fn concurrent_generations_cover_their_ranges_exactly_once() {
        // N caller threads share one pool, each submitting its own
        // generations; every caller's indices execute exactly once.
        let pool = ThreadPool::new(4);
        let callers = 6usize;
        let marks: Vec<Vec<AtomicU64>> = (0..callers)
            .map(|_| (0..503).map(|_| AtomicU64::new(0)).collect())
            .collect();
        std::thread::scope(|s| {
            for (c, m) in marks.iter().enumerate() {
                let pool = &pool;
                s.spawn(move || {
                    for _round in 0..3 {
                        pool.run_stealing(m.len(), 1 + c % 5, |_t, r| {
                            for i in r {
                                m[i].fetch_add(1, Ordering::SeqCst);
                            }
                        });
                    }
                });
            }
        });
        for (c, m) in marks.iter().enumerate() {
            assert!(
                m.iter().all(|x| x.load(Ordering::SeqCst) == 3),
                "caller {c} lost or duplicated tasks"
            );
        }
    }

    #[test]
    fn overlapping_submits_from_one_thread() {
        // Two generations in flight at once from a single caller: the
        // second submit must not require the first ticket to resolve.
        let pool = ThreadPool::new(3);
        let a = AtomicU64::new(0);
        let b = AtomicU64::new(0);
        // SAFETY: both tickets are waited on below, before a/b die.
        let ta = unsafe {
            pool.submit_stealing(100, 7, |_t, r| {
                for _ in r {
                    a.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let tb = unsafe {
            pool.submit_stealing(64, 3, |_t, r| {
                for _ in r {
                    b.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        tb.wait();
        ta.wait();
        assert_eq!(a.load(Ordering::SeqCst), 100);
        assert_eq!(b.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn small_generation_completes_while_large_one_runs() {
        // Fairness: a small generation submitted after a large one must
        // finish long before the pool drains the large one's tasks.
        let pool = ThreadPool::new(2);
        let slow_done = AtomicU64::new(0);
        let sink = AtomicU64::new(0);
        // SAFETY: waited below; captures outlive the workers' use.
        let big = unsafe {
            pool.submit_stealing(4000, 1, |_t, r| {
                for _ in r {
                    for k in 0..2000u64 {
                        sink.fetch_add(k, Ordering::Relaxed);
                    }
                    slow_done.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let hits = AtomicU64::new(0);
        pool.run_stealing(8, 1, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        // The small generation resolved; the big one must still have
        // work outstanding (8 interleaved tasks ≪ 4000 slow ones).
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        assert!(
            slow_done.load(Ordering::SeqCst) < 4000,
            "small generation was starved behind the large one"
        );
        big.wait();
        assert_eq!(slow_done.load(Ordering::SeqCst), 4000);
    }

    #[test]
    fn panic_reported_to_owning_ticket_only() {
        let pool = ThreadPool::new(4);
        let good = AtomicU64::new(0);
        let bad = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_stealing(16, 1, |_t, r| {
                if r.start == 7 {
                    panic!("intentional test panic");
                }
            });
        }));
        assert!(bad.is_err(), "panicking generation must re-raise");
        // The pool stays healthy and later generations are unaffected.
        pool.run_stealing(32, 2, |_t, r| {
            good.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(good.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn regions_cover_concatenated_range_exactly_once() {
        let pool = ThreadPool::new(4);
        for (la, ga, lb, gb) in [
            (100usize, 7usize, 13usize, 1usize),
            (0, 1, 20, 3),
            (20, 3, 0, 1),
            (1, 1, 1, 1),
            (997, 16, 31, 1),
        ] {
            let total = la + lb;
            let marks: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            let pool_ref = &pool;
            // SAFETY: the ticket is waited on before `marks` goes away.
            unsafe { pool_ref.submit_stealing_regions(&[(la, ga), (lb, gb)], |_t, r| {
                for i in r {
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            }) }
            .wait();
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "la={la} ga={ga} lb={lb} gb={gb}"
            );
        }
    }

    #[test]
    fn region_tasks_respect_their_own_grain() {
        // Region A (grain 5) must never hand out a range crossing into
        // region B's index space, and region B (grain 1) must arrive as
        // single-index tasks.
        let pool = ThreadPool::new(3);
        let (la, lb) = (23usize, 9usize);
        let bad = AtomicU64::new(0);
        let b_tasks = AtomicU64::new(0);
        pool_run_regions(&pool, &[(la, 5), (lb, 1)], |r: Range<usize>| {
            if r.start < la && r.end > la {
                bad.fetch_add(1, Ordering::SeqCst);
            }
            if r.start >= la {
                b_tasks.fetch_add(1, Ordering::SeqCst);
                if r.len() != 1 {
                    bad.fetch_add(1, Ordering::SeqCst);
                }
            }
        });
        assert_eq!(bad.load(Ordering::SeqCst), 0);
        assert_eq!(b_tasks.load(Ordering::SeqCst), lb as u64);
    }

    fn pool_run_regions(pool: &ThreadPool, regions: &[(usize, usize)], f: impl Fn(Range<usize>) + Send + Sync) {
        // SAFETY: waited on before returning, so captures outlive workers.
        unsafe { pool.submit_stealing_regions(regions, |_t, r| f(r)) }.wait();
    }

    #[test]
    fn empty_regions_generation_completes() {
        let pool = ThreadPool::new(2);
        pool_run_regions(&pool, &[(0, 1), (0, 4)], |_r| panic!("no tasks must run"));
        pool_run_regions(&pool, &[], |_r| panic!("no tasks must run"));
        let hits = AtomicU64::new(0);
        pool_run_regions(&pool, &[(0, 1), (6, 2)], |r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn shared_slice_disjoint_writes_from_workers() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1000];
        {
            let s = SharedSlice::new(&mut data);
            assert_eq!(s.len(), 1000);
            assert!(!s.is_empty());
            pool.run_stealing(1000, 7, |_t, r| {
                for i in r {
                    // SAFETY: stealing hands out each index exactly once.
                    unsafe { s.write(i, i as u64 + 1) };
                }
            });
        }
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u64 + 1));
        let mut chunks = vec![0u32; 64];
        {
            let s = SharedSlice::new(&mut chunks);
            pool.run_stealing(4, 1, |_t, r| {
                for c in r {
                    // SAFETY: chunk ranges are pairwise disjoint.
                    let sl = unsafe { s.slice_mut(c * 16..(c + 1) * 16) };
                    sl.fill(c as u32 + 1);
                }
            });
        }
        for (i, &v) in chunks.iter().enumerate() {
            assert_eq!(v, (i / 16) as u32 + 1);
        }
    }

    #[test]
    fn stats_accumulate() {
        let pool = ThreadPool::new(2);
        let s0 = pool.stats();
        pool.run_stealing(10, 2, |_t, _r| {});
        pool.run_stealing(4, 4, |_t, _r| {});
        let s1 = pool.stats();
        assert_eq!(s1.generations - s0.generations, 2);
        assert_eq!(s1.tasks - s0.tasks, 5 + 1);
    }
}
