//! Persistent worker thread pool (paper §4.4).
//!
//! "To reduce the overhead of creating and destroying threads, we create
//! threads before the computation of PH. The jobs are allocated in fixed
//! chunks to these threads and the threads are woken up when they are
//! required" — this module is exactly that: `threads` workers parked on a
//! condvar, a generation counter to publish jobs, and a scoped-pointer
//! trick so jobs may borrow the caller's stack (the caller blocks until
//! the generation completes, so the borrow is sound).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Arc<dyn Fn(usize) + Send + Sync>;

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    active: AtomicUsize,
}

struct State {
    generation: u64,
    job: Option<Job>,
    shutdown: bool,
    done: u64,
}

/// Fixed-size pool; workers live for the pool's lifetime.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    n: usize,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                shutdown: false,
                done: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            active: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|tid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dory-worker-{tid}"))
                    .spawn(move || worker_loop(tid, shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, n }
    }

    pub fn threads(&self) -> usize {
        self.n
    }

    /// Run `job(tid)` on every worker; blocks until all return.
    ///
    /// Safety of borrowing: the closure is type-erased behind an Arc with a
    /// 'static bound obtained via transmute, but `run` does not return
    /// until every worker has finished the generation, so borrowed data
    /// outlives all uses.
    pub fn run<'scope, F>(&self, job: F)
    where
        F: Fn(usize) + Send + Sync + 'scope,
    {
        let arc: Arc<dyn Fn(usize) + Send + Sync + 'scope> = Arc::new(job);
        // Erase the lifetime (see safety note above).
        let arc: Job = unsafe { std::mem::transmute(arc) };
        let mut st = self.shared.state.lock().unwrap();
        st.generation += 1;
        st.done = 0;
        st.job = Some(arc);
        let gen = st.generation;
        self.shared.work_cv.notify_all();
        while st.done < self.n as u64 || st.generation != gen {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Split `0..len` into `threads()` contiguous chunks; `f(tid, range)`.
    pub fn run_chunks<'scope, F>(&self, len: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Send + Sync + 'scope,
    {
        let n = self.n;
        let chunk = len.div_ceil(n.max(1)).max(1);
        self.run(move |tid| {
            let start = tid * chunk;
            if start < len {
                let end = (start + chunk).min(len);
                f(tid, start..end);
            }
        });
    }
}

fn worker_loop(tid: usize, shared: Arc<Shared>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen && st.job.is_some() {
                    last_gen = st.generation;
                    break st.job.clone().unwrap();
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        shared.active.fetch_add(1, Ordering::SeqCst);
        job(tid);
        shared.active.fetch_sub(1, Ordering::SeqCst);
        let mut st = shared.state.lock().unwrap();
        st.done += 1;
        shared.done_cv.notify_all();
        drop(st);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_on_all_workers() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.run(|_tid| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn reusable_across_generations() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(|tid| {
                sum.fetch_add(tid as u64 + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(sum.load(Ordering::SeqCst), 50 * (1 + 2 + 3));
    }

    #[test]
    fn chunks_cover_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let len = 1003;
        let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        pool.run_chunks(len, |_tid, range| {
            for i in range {
                marks[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let total = AtomicU64::new(0);
        pool.run_chunks(data.len(), |_tid, r| {
            let s: u64 = data[r].iter().sum();
            total.fetch_add(s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let hits = AtomicU64::new(0);
        pool.run_chunks(10, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }
}
