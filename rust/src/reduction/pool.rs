//! Persistent work-stealing thread pool (paper §4.4, rebuilt).
//!
//! The paper's pool ("threads are created before the computation of PH
//! and woken up when they are required") handed out *fixed* chunks
//! through a wake-all condvar: every worker got one contiguous slice and
//! the caller blocked until the slowest worker finished — one straggler
//! column idled the whole pool. This rebuild keeps the persistent
//! workers and the borrow-the-caller's-stack job model, but replaces the
//! fixed chunks with **per-worker deques and work stealing**:
//!
//! * a generation splits `0..len` into `grain`-sized tasks dealt
//!   round-robin into per-worker deques;
//! * a worker pops its own deque from the *front* and, when empty,
//!   steals from the *back* of a victim's deque (classic Chase–Lev
//!   discipline, here with plain mutexed deques — tasks are
//!   column-granular, so queue ops are not the bottleneck);
//! * tasks carry their generation tag, so a straggler from generation
//!   `k` can never execute (or steal) generation `k+1` work;
//! * completion is task-counted, not worker-counted: the caller's
//!   [`Ticket`] resolves when the last *task* retires, no matter which
//!   workers ran it.
//!
//! [`ThreadPool::submit_stealing`] returns without blocking, which is
//! what lets the serial–parallel scheduler overlap batch *k*'s serial
//! commit phase with batch *k+1*'s parallel push phase (see
//! [`super::serial_parallel`]). The pool also keeps cumulative counters
//! (tasks, steals, busy time, generation spans) that back the
//! `EngineStats` scheduler report.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

type Job = Arc<dyn Fn(usize, Range<usize>) + Send + Sync>;

/// A raw shared view of a mutable slice for pool jobs that write
/// provably disjoint index sets (filtration tile splices, the CSR
/// counting-scatter, sorted-chunk splits). The safe alternative — one
/// `Mutex` per destination — would serialize exactly the writes the
/// parallel front-end exists to spread across workers.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _life: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write slot `i`.
    ///
    /// # Safety
    ///
    /// While the generation runs, no two tasks may touch the same index
    /// and nobody may read an index a writer holds.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(v) }
    }

    /// Exclusive view of `range`.
    ///
    /// # Safety
    ///
    /// Ranges handed to concurrently running tasks must be pairwise
    /// disjoint, and nobody may read them while the tasks run.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, range: Range<usize>) -> &'a mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len()) }
    }
}

/// Per-worker deque of `(generation, index range)` tasks.
type TaskQueue = Mutex<VecDeque<(u64, Range<usize>)>>;

/// Cumulative pool counters (monotone; snapshot before/after a section
/// and subtract to get per-section numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Generations submitted.
    pub generations: u64,
    /// Tasks executed.
    pub tasks: u64,
    /// Tasks taken from another worker's deque.
    pub steals: u64,
    /// Total worker time spent inside task bodies.
    pub busy_ns: u64,
    /// Total wall time from submit to last-task-retired, per generation.
    pub span_ns: u64,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Per-worker deques of `(generation, index range)` tasks.
    queues: Vec<TaskQueue>,
    /// Tasks of the in-flight generation not yet retired.
    remaining: AtomicUsize,
    /// A job body panicked (reported by the ticket's wait).
    panicked: AtomicBool,
    generations: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
    busy_ns: AtomicU64,
    span_ns: AtomicU64,
}

struct State {
    generation: u64,
    /// Highest generation whose last task has retired.
    done_gen: u64,
    job: Option<Job>,
    /// Workers still holding a clone of some generation's job closure.
    /// A ticket resolves only when this hits zero, so captured borrows
    /// are never released while any worker still holds the (lifetime-
    /// erased) closure — true scoped-thread semantics, not just
    /// last-task-retired.
    live_jobs: usize,
    /// Submit instant of the in-flight generation (for span accounting).
    started: Option<Instant>,
    in_flight: bool,
    shutdown: bool,
}

/// Fixed-size pool; workers live for the pool's lifetime.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    n: usize,
}

/// Handle for an in-flight generation. Dropping it waits too, so
/// borrowed job data can never be released while workers still run.
#[must_use = "wait on the ticket before the job's borrowed data goes out of scope"]
pub struct Ticket<'p> {
    pool: &'p ThreadPool,
    gen: u64,
    done: bool,
}

impl Ticket<'_> {
    /// Block until every task of this generation has retired.
    pub fn wait(mut self) {
        self.wait_ref();
    }

    fn wait_ref(&mut self) {
        if self.done {
            return;
        }
        let shared = &self.pool.shared;
        let mut st = shared.state.lock().unwrap();
        while st.done_gen < self.gen || st.live_jobs > 0 {
            st = shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        st.in_flight = false;
        drop(st);
        self.done = true;
        if shared.panicked.swap(false, Ordering::Relaxed) {
            panic!("ThreadPool: a job panicked in a worker thread");
        }
    }
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        self.wait_ref();
    }
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                done_gen: 0,
                job: None,
                live_jobs: 0,
                started: None,
                in_flight: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            generations: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            span_ns: AtomicU64::new(0),
        });
        let workers = (0..n)
            .map(|tid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dory-worker-{tid}"))
                    .spawn(move || worker_loop(tid, shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, n }
    }

    pub fn threads(&self) -> usize {
        self.n
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            generations: self.shared.generations.load(Ordering::Relaxed),
            tasks: self.shared.tasks.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            busy_ns: self.shared.busy_ns.load(Ordering::Relaxed),
            span_ns: self.shared.span_ns.load(Ordering::Relaxed),
        }
    }

    /// Start a generation: split `0..len` into `grain`-sized tasks, deal
    /// them round-robin into the worker deques, wake the pool and return
    /// immediately. `f(tid, range)` runs once per task on whichever
    /// worker pops (or steals) it. At most one generation may be in
    /// flight per pool; the caller must resolve the [`Ticket`] before
    /// submitting again (dropping it resolves it).
    ///
    /// The returned ticket is tied to `'scope`, so the borrow checker
    /// keeps everything the closure captures alive until the ticket is
    /// waited on or dropped (both block until every task has retired —
    /// the same discipline as a scoped thread).
    ///
    /// # Safety
    ///
    /// The closure is type-erased behind an `Arc` whose `'static` bound
    /// is obtained via transmute. The lifetime tie above makes ordinary
    /// drop-based control flow sound, but the caller must not leak the
    /// ticket (`mem::forget`, `ManuallyDrop`, leaked `Rc` cycles, …):
    /// a leaked ticket skips the drop-wait, after which captured borrows
    /// may dangle while workers still execute. The safe wrappers
    /// ([`Self::run`], [`Self::run_stealing`]) wait before returning and
    /// are sound for any caller.
    pub unsafe fn submit_stealing<'scope, F>(
        &'scope self,
        len: usize,
        grain: usize,
        f: F,
    ) -> Ticket<'scope>
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        // SAFETY: forwarded to submit_stealing_regions under the same
        // contract (caller must not leak the ticket).
        unsafe { self.submit_stealing_regions(&[(len, grain)], f) }
    }

    /// Start a generation over several concatenated index *regions*, each
    /// with its own task grain. Region `r` covers the global indices
    /// `offset_r..offset_r + len_r` where `offset_r` is the summed length
    /// of all earlier regions, and is split into `grain_r`-sized tasks.
    /// Tasks never straddle a region boundary, so a heterogeneous
    /// generation (e.g. fine-grained column pushes alongside coarse
    /// enumeration shards) keeps each region independently stealable.
    ///
    /// Regions are dealt in order, continuing the round-robin across the
    /// boundary: a later region's tasks land at the *backs* of the worker
    /// deques, which is exactly where idle workers steal from first.
    ///
    /// # Safety
    ///
    /// Identical contract to [`Self::submit_stealing`].
    pub unsafe fn submit_stealing_regions<'scope, F>(
        &'scope self,
        regions: &[(usize, usize)],
        f: F,
    ) -> Ticket<'scope>
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        let arc: Arc<dyn Fn(usize, Range<usize>) + Send + Sync + 'scope> = Arc::new(f);
        // Erase the lifetime (see safety note above).
        let arc: Job = unsafe { std::mem::transmute(arc) };
        let mut n_tasks = 0usize;
        for &(len, grain) in regions {
            n_tasks += len.div_ceil(grain.max(1));
        }
        let mut st = self.shared.state.lock().unwrap();
        assert!(
            !st.in_flight,
            "ThreadPool: generation already in flight (wait on the previous Ticket first)"
        );
        st.generation += 1;
        let gen = st.generation;
        self.shared.generations.fetch_add(1, Ordering::Relaxed);
        if n_tasks == 0 {
            // Nothing to do: pre-resolve so wait() returns immediately.
            st.done_gen = gen;
            return Ticket {
                pool: self,
                gen,
                done: true,
            };
        }
        // Publish the task count before any queue is filled: stragglers
        // from the previous generation are fenced off by the generation
        // tag on each task, and nothing of this generation can retire
        // before the state lock (held throughout) is released.
        self.shared.remaining.store(n_tasks, Ordering::Release);
        let mut offset = 0usize;
        let mut w = 0usize;
        for &(len, grain) in regions {
            let grain = grain.max(1);
            let mut start = 0usize;
            while start < len {
                let end = (start + grain).min(len);
                self.shared.queues[w % self.n]
                    .lock()
                    .unwrap()
                    .push_back((gen, offset + start..offset + end));
                start = end;
                w += 1;
            }
            offset += len;
        }
        st.job = Some(arc);
        st.in_flight = true;
        st.started = Some(Instant::now());
        self.shared.work_cv.notify_all();
        drop(st);
        Ticket {
            pool: self,
            gen,
            done: false,
        }
    }

    /// Blocking fan-out over `0..len` with work stealing.
    pub fn run_stealing<'scope, F>(&self, len: usize, grain: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Send + Sync + 'scope,
    {
        // SAFETY: the ticket is waited on before this frame returns, so
        // every capture of `f` outlives all worker uses.
        unsafe { self.submit_stealing(len, grain, f) }.wait();
    }

    /// Run `f(i)` once per index `i in 0..threads()`; blocks until all
    /// return. (Task-indexed: `i` is the task id, not the executing
    /// worker — with stealing the two can differ.)
    pub fn run<'scope, F>(&self, f: F)
    where
        F: Fn(usize) + Send + Sync + 'scope,
    {
        self.run_stealing(self.n, 1, move |_tid, r| {
            for i in r {
                f(i);
            }
        });
    }
}

fn pop_own(shared: &Shared, tid: usize, gen: u64) -> Option<Range<usize>> {
    let mut q = shared.queues[tid].lock().unwrap();
    if q.front().is_some_and(|&(g, _)| g == gen) {
        return q.pop_front().map(|(_, r)| r);
    }
    None
}

fn steal(shared: &Shared, tid: usize, gen: u64) -> Option<Range<usize>> {
    let n = shared.queues.len();
    for off in 1..n {
        let victim = (tid + off) % n;
        let mut q = shared.queues[victim].lock().unwrap();
        if q.back().is_some_and(|&(g, _)| g == gen) {
            let task = q.pop_back().map(|(_, r)| r);
            drop(q);
            shared.steals.fetch_add(1, Ordering::Relaxed);
            return task;
        }
    }
    None
}

fn worker_loop(tid: usize, shared: Arc<Shared>) {
    let mut last_gen = 0u64;
    loop {
        // Sleep until a new generation is published (or shutdown).
        let (job, gen) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen && st.job.is_some() {
                    last_gen = st.generation;
                    st.live_jobs += 1;
                    break (st.job.clone().unwrap(), st.generation);
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Drain: own deque first, then steal. Tasks never re-enter a
        // queue, so an empty sweep means this worker is done for the
        // generation (others may still be executing their last task).
        loop {
            let Some(range) = pop_own(&shared, tid, gen).or_else(|| steal(&shared, tid, gen))
            else {
                break;
            };
            let t0 = Instant::now();
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job(tid, range);
            }));
            shared
                .busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            shared.tasks.fetch_add(1, Ordering::Relaxed);
            if ok.is_err() {
                shared.panicked.store(true, Ordering::Relaxed);
            }
            if shared.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                // Last task of the generation: stamp the span, publish
                // completion, wake the ticket holder.
                let mut st = shared.state.lock().unwrap();
                if let Some(t) = st.started.take() {
                    shared
                        .span_ns
                        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                st.done_gen = gen;
                drop(st);
                shared.done_cv.notify_all();
            }
        }
        // Between generations, drop any buffer capacity a pathological
        // generation left in this worker's deque: the tasks are gone,
        // but without the shrink the high-water mark would pin memory
        // for the pool's (engine-long) lifetime. The bound mirrors
        // `BucketTable::clear`'s retained-capacity discipline. No new
        // generation can be dealt yet — the previous ticket cannot
        // resolve before `live_jobs` drops below.
        {
            let mut q = shared.queues[tid].lock().unwrap();
            if q.is_empty() && q.capacity() > 4096 {
                q.shrink_to(4096);
            }
        }
        // Release the job clone *before* announcing it: the ticket only
        // resolves once every worker has dropped its closure, so the
        // caller's borrowed data can never be touched afterwards (not
        // even by destructors of captured values).
        drop(job);
        let mut st = shared.state.lock().unwrap();
        st.live_jobs -= 1;
        let release = st.live_jobs == 0;
        drop(st);
        if release {
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_one_task_per_index() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.run(|_i| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn reusable_across_generations() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(|i| {
                sum.fetch_add(i as u64 + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(sum.load(Ordering::SeqCst), 50 * (1 + 2 + 3));
    }

    #[test]
    fn coarse_chunks_cover_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let len = 1003usize;
        let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        pool.run_stealing(len, len.div_ceil(4), |_tid, range| {
            for i in range {
                marks[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn stealing_covers_range_exactly_once() {
        let pool = ThreadPool::new(4);
        for grain in [1usize, 3, 17, 1000] {
            let len = 997;
            let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
            pool.run_stealing(len, grain, |_tid, range| {
                for i in range {
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "grain={grain}"
            );
        }
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let total = AtomicU64::new(0);
        pool.run_stealing(data.len(), 2, |_tid, r| {
            let s: u64 = data[r].iter().sum();
            total.fetch_add(s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let hits = AtomicU64::new(0);
        pool.run_stealing(10, 3, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn empty_generation_completes() {
        let pool = ThreadPool::new(3);
        pool.run_stealing(0, 1, |_t, _r| panic!("no tasks must run"));
        // And again after a real generation (generation counter moves on).
        let hits = AtomicU64::new(0);
        pool.run_stealing(5, 2, |_t, r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        pool.run_stealing(0, 1, |_t, _r| panic!("no tasks must run"));
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn submit_overlaps_caller_work() {
        // The pipelining contract: the caller keeps computing while the
        // generation runs, then joins at the ticket.
        let pool = ThreadPool::new(2);
        let worker_sum = AtomicU64::new(0);
        // SAFETY: the ticket is waited on below, before worker_sum dies.
        let ticket = unsafe {
            pool.submit_stealing(64, 4, |_t, r| {
                for i in r {
                    worker_sum.fetch_add(i as u64, Ordering::SeqCst);
                }
            })
        };
        // Caller-side "serial phase".
        let mut serial_sum = 0u64;
        for i in 0..64u64 {
            serial_sum += i;
        }
        ticket.wait();
        assert_eq!(worker_sum.load(Ordering::SeqCst), serial_sum);
    }

    #[test]
    fn ticket_drop_waits_for_completion() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        {
            // SAFETY: the ticket is dropped at the end of this block,
            // which blocks until every task retired; `hits` outlives it.
            let _ticket = unsafe {
                pool.submit_stealing(256, 1, |_t, r| {
                    for _ in r {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
            };
            // _ticket dropped here → must block until all 256 ran.
        }
        assert_eq!(hits.load(Ordering::SeqCst), 256);
    }

    #[test]
    fn clean_shutdown_under_load() {
        for round in 0..20 {
            let pool = ThreadPool::new(4);
            if round % 3 != 0 {
                let spin = AtomicU64::new(0);
                pool.run_stealing(500, 1, |_t, _r| {
                    // A few hundred ns of real work per task.
                    for _ in 0..50 {
                        spin.fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert_eq!(spin.load(Ordering::Relaxed), 500 * 50);
            }
            // Pool dropped immediately — workers must join cleanly.
        }
    }

    #[test]
    fn stealing_occurs_under_imbalance() {
        // Deal slow tasks to worker 0's deque (round-robin puts task
        // ids ≡ 0 (mod n) there); the other workers drain instantly and
        // must steal from it.
        let pool = ThreadPool::new(8);
        let before = pool.stats();
        let marks: Vec<AtomicU64> = (0..800).map(|_| AtomicU64::new(0)).collect();
        let sink = AtomicU64::new(0);
        pool.run_stealing(800, 1, |_t, r| {
            for i in r {
                if i % 8 == 0 {
                    // ~tens of µs of spinning: worker 0 cannot drain its
                    // 100 slow tasks before the 7 idle workers steal.
                    for k in 0..20_000u64 {
                        sink.fetch_add(k, Ordering::Relaxed);
                    }
                }
                marks[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        let after = pool.stats();
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
        assert!(
            after.steals > before.steals,
            "expected steals under an imbalanced load, got {}",
            after.steals - before.steals
        );
        assert_eq!(after.tasks - before.tasks, 800);
    }

    #[test]
    fn interleaving_stress_across_seeds() {
        // Loom-style substitute: many seeded schedules of mixed-duration
        // tasks; every index must be executed exactly once, every
        // generation must terminate.
        for seed in 0..40u64 {
            let mut rng = Pcg32::new(seed);
            let threads = 1 + rng.gen_range(8) as usize;
            let len = 1 + rng.gen_range(300) as usize;
            let grain = 1 + rng.gen_range(16) as usize;
            let weights: Vec<u32> = (0..len).map(|_| rng.gen_range(400)).collect();
            let pool = ThreadPool::new(threads);
            let marks: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
            let sink = AtomicU64::new(0);
            pool.run_stealing(len, grain, |_t, r| {
                for i in r {
                    for k in 0..weights[i] {
                        sink.fetch_add(k as u64, Ordering::Relaxed);
                    }
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "seed={seed} threads={threads} len={len} grain={grain}"
            );
        }
    }

    #[test]
    fn regions_cover_concatenated_range_exactly_once() {
        let pool = ThreadPool::new(4);
        for (la, ga, lb, gb) in [
            (100usize, 7usize, 13usize, 1usize),
            (0, 1, 20, 3),
            (20, 3, 0, 1),
            (1, 1, 1, 1),
            (997, 16, 31, 1),
        ] {
            let total = la + lb;
            let marks: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            let pool_ref = &pool;
            // SAFETY: the ticket is waited on before `marks` goes away.
            unsafe { pool_ref.submit_stealing_regions(&[(la, ga), (lb, gb)], |_t, r| {
                for i in r {
                    marks[i].fetch_add(1, Ordering::SeqCst);
                }
            }) }
            .wait();
            assert!(
                marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                "la={la} ga={ga} lb={lb} gb={gb}"
            );
        }
    }

    #[test]
    fn region_tasks_respect_their_own_grain() {
        // Region A (grain 5) must never hand out a range crossing into
        // region B's index space, and region B (grain 1) must arrive as
        // single-index tasks.
        let pool = ThreadPool::new(3);
        let (la, lb) = (23usize, 9usize);
        let bad = AtomicU64::new(0);
        let b_tasks = AtomicU64::new(0);
        pool_run_regions(&pool, &[(la, 5), (lb, 1)], |r: Range<usize>| {
            if r.start < la && r.end > la {
                bad.fetch_add(1, Ordering::SeqCst);
            }
            if r.start >= la {
                b_tasks.fetch_add(1, Ordering::SeqCst);
                if r.len() != 1 {
                    bad.fetch_add(1, Ordering::SeqCst);
                }
            }
        });
        assert_eq!(bad.load(Ordering::SeqCst), 0);
        assert_eq!(b_tasks.load(Ordering::SeqCst), lb as u64);
    }

    fn pool_run_regions(pool: &ThreadPool, regions: &[(usize, usize)], f: impl Fn(Range<usize>) + Send + Sync) {
        // SAFETY: waited on before returning, so captures outlive workers.
        unsafe { pool.submit_stealing_regions(regions, |_t, r| f(r)) }.wait();
    }

    #[test]
    fn empty_regions_generation_completes() {
        let pool = ThreadPool::new(2);
        pool_run_regions(&pool, &[(0, 1), (0, 4)], |_r| panic!("no tasks must run"));
        pool_run_regions(&pool, &[], |_r| panic!("no tasks must run"));
        let hits = AtomicU64::new(0);
        pool_run_regions(&pool, &[(0, 1), (6, 2)], |r| {
            hits.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn shared_slice_disjoint_writes_from_workers() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1000];
        {
            let s = SharedSlice::new(&mut data);
            assert_eq!(s.len(), 1000);
            assert!(!s.is_empty());
            pool.run_stealing(1000, 7, |_t, r| {
                for i in r {
                    // SAFETY: stealing hands out each index exactly once.
                    unsafe { s.write(i, i as u64 + 1) };
                }
            });
        }
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u64 + 1));
        let mut chunks = vec![0u32; 64];
        {
            let s = SharedSlice::new(&mut chunks);
            pool.run_stealing(4, 1, |_t, r| {
                for c in r {
                    // SAFETY: chunk ranges are pairwise disjoint.
                    let sl = unsafe { s.slice_mut(c * 16..(c + 1) * 16) };
                    sl.fill(c as u32 + 1);
                }
            });
        }
        for (i, &v) in chunks.iter().enumerate() {
            assert_eq!(v, (i / 16) as u32 + 1);
        }
    }

    #[test]
    fn stats_accumulate() {
        let pool = ThreadPool::new(2);
        let s0 = pool.stats();
        pool.run_stealing(10, 2, |_t, _r| {});
        pool.run_stealing(4, 4, |_t, _r| {});
        let s1 = pool.stats();
        assert_eq!(s1.generations - s0.generations, 2);
        assert_eq!(s1.tasks - s0.tasks, 5 + 1);
    }
}
