//! Cohomology reduction engines (paper §4.3–4.4).
//!
//! All engines reduce *columns* of the coboundary matrix one at a time,
//! never materializing it. A column is identified by a `u64` id — the edge
//! order for the H1* computation, the packed triangle key for H2* — and a
//! [`ColumnSpace`] provides cursor operations over its coboundary plus the
//! trivial-pair probe. The engines differ in how they find the lowest
//! odd-coefficient simplex δ*:
//!
//! * [`implicit_row`]: flat cursor list, full scan per step (§4.3.2);
//! * [`fast_column`]: hash table keyed by primary key, only the active
//!   bucket ordered (§4.3.4) — the paper's headline algorithm;
//! * [`explicit`]: textbook boundary-matrix reduction (App. A), the
//!   correctness oracle;
//! * [`serial_parallel`]: the pipelined work-stealing batch scheduler
//!   over the persistent [`pool::ThreadPool`] (§4.4, rebuilt — batch
//!   *k*'s serial commit overlaps batch *k+1*'s parallel push).

pub mod cancel;
pub mod explicit;
pub mod fast_column;
pub mod implicit_row;
pub mod pool;
pub mod serial_parallel;

pub use cancel::CancelToken;
pub use serial_parallel::{shard_plan, ColumnShards, SchedConfig, SchedStats, SliceShards};

use crate::coboundary::{TetCursor, TriCursor};
use crate::filtration::{EdgeFiltration, Key, Neighborhoods};

/// Counters reported by EXPERIMENTS.md and the ablation benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReduceStats {
    /// Columns that entered the reduction stream (shortcut columns are
    /// resolved at enumeration time and counted separately).
    pub columns: usize,
    pub cleared: usize,
    /// Total trivial (apparent) pairs, wherever they were resolved —
    /// invariant under the enumeration-time shortcut.
    pub trivial_pairs: usize,
    pub pairs: usize,
    pub essential: usize,
    pub appends: usize,
    pub find_next_calls: usize,
    pub zero_columns: usize,
    /// Trivial pairs resolved by the in-shard apparent-pair shortcut
    /// (subset of `trivial_pairs`): these columns never entered a
    /// `BucketTable`, the batch pipeline, or the column stream.
    pub shortcut_pairs: usize,
}

impl ReduceStats {
    pub fn merge(&mut self, o: &ReduceStats) {
        self.columns += o.columns;
        self.cleared += o.cleared;
        self.trivial_pairs += o.trivial_pairs;
        self.pairs += o.pairs;
        self.essential += o.essential;
        self.appends += o.appends;
        self.find_next_calls += o.find_next_calls;
        self.zero_columns += o.zero_columns;
        self.shortcut_pairs += o.shortcut_pairs;
    }

    /// Fraction of reduction candidates (surviving clearing) resolved by
    /// the enumeration-time apparent-pair shortcut.
    pub fn skip_rate(&self) -> f64 {
        let total = self.columns + self.shortcut_pairs;
        if total == 0 {
            0.0
        } else {
            self.shortcut_pairs as f64 / total as f64
        }
    }
}

/// A (co)boundary column universe for one homology dimension.
///
/// Cursor state at a given simplex is canonical (verified by the
/// coboundary tests), so cursors compare by `(key, column)` alone.
pub trait ColumnSpace: Sync {
    type Cursor: Copy + Send;

    /// Cursor at the least simplex of the column's coboundary.
    fn smallest(&self, col: u64) -> Self::Cursor;
    /// Cursor at the least simplex >= `target`.
    fn geq(&self, col: u64, target: Key) -> Self::Cursor;
    /// Advance to the next-greater simplex.
    fn next(&self, cur: &mut Self::Cursor);
    /// Current simplex key (`Key::NONE` = exhausted).
    fn key(&self, cur: &Self::Cursor) -> Key;
    /// The column this cursor belongs to.
    fn col(&self, cur: &Self::Cursor) -> u64;
    /// If `key` forms a trivial (apparent) pair `(key, owner)`, return the
    /// owning column (paper §4.3.5). The owner's reduced column is exactly
    /// its raw coboundary — no ops needed.
    fn trivial_owner(&self, key: Key) -> Option<u64>;
    /// O(1) self-trivial test, valid ONLY when `low` is the smallest
    /// simplex of `δcol` (the first low of a fresh column): is
    /// `(low, col)` a trivial pair? Avoids the (possibly expensive)
    /// `trivial_owner` probe on the dominant apparent-pair fast path.
    fn is_self_trivial_first(&self, col: u64, low: Key) -> bool;
}

/// H1*: columns are edges (id = edge order), coboundary simplices are
/// triangles enumerated by [`TriCursor`].
pub struct EdgeColumns<'a> {
    pub nb: &'a Neighborhoods,
    pub f1: &'a EdgeFiltration,
    /// Smallest triangle of every edge's coboundary, precomputed a priori
    /// at `O(n_e)` memory (paper §4.3.5) — backs the trivial-pair probe
    /// and seeds the initial cursors.
    pub smallest_tri: Vec<Key>,
}

impl<'a> EdgeColumns<'a> {
    pub fn new(nb: &'a Neighborhoods, f1: &'a EdgeFiltration) -> Self {
        let smallest_tri = (0..f1.n_edges() as u32)
            .map(|e| {
                let (a, b) = f1.edges[e as usize];
                TriCursor::find_smallest(nb, e, a, b).cur
            })
            .collect();
        Self {
            nb,
            f1,
            smallest_tri,
        }
    }
}

impl<'a> ColumnSpace for EdgeColumns<'a> {
    type Cursor = TriCursor;

    fn smallest(&self, col: u64) -> TriCursor {
        let e = col as u32;
        let (a, b) = self.f1.edges[e as usize];
        // Seed from the precomputed table: jump straight to the known
        // smallest key via binary searches instead of a full merge.
        let k = self.smallest_tri[e as usize];
        if k.is_none() {
            TriCursor {
                e,
                a,
                b,
                ia: 0,
                ib: 0,
                case2: true,
                cur: Key::NONE,
            }
        } else {
            let c = TriCursor::find_geq(self.nb, e, a, b, k);
            debug_assert_eq!(c.cur, k);
            c
        }
    }

    fn geq(&self, col: u64, target: Key) -> TriCursor {
        let e = col as u32;
        let (a, b) = self.f1.edges[e as usize];
        TriCursor::find_geq(self.nb, e, a, b, target)
    }

    #[inline]
    fn next(&self, cur: &mut TriCursor) {
        cur.find_next(self.nb);
    }

    #[inline]
    fn key(&self, cur: &TriCursor) -> Key {
        cur.cur
    }

    #[inline]
    fn col(&self, cur: &TriCursor) -> u64 {
        cur.e as u64
    }

    /// `(key, e')` is trivial iff `e' = key.p` (the diameter edge itself)
    /// and `key` is the smallest simplex of `δe'`.
    #[inline]
    fn trivial_owner(&self, key: Key) -> Option<u64> {
        if self.smallest_tri[key.p as usize] == key {
            Some(key.p as u64)
        } else {
            None
        }
    }

    /// `low` is the smallest of `δcol`; trivial iff its diameter IS col.
    #[inline]
    fn is_self_trivial_first(&self, col: u64, low: Key) -> bool {
        low.p as u64 == col
    }
}

/// H2*: columns are triangles (id = packed key), coboundary simplices are
/// tetrahedra enumerated by [`TetCursor`].
pub struct TriangleColumns<'a> {
    pub nb: &'a Neighborhoods,
    pub f1: &'a EdgeFiltration,
}

impl<'a> TriangleColumns<'a> {
    pub fn new(nb: &'a Neighborhoods, f1: &'a EdgeFiltration) -> Self {
        Self { nb, f1 }
    }
}

impl<'a> ColumnSpace for TriangleColumns<'a> {
    type Cursor = TetCursor;

    fn smallest(&self, col: u64) -> TetCursor {
        TetCursor::find_smallest(self.nb, self.f1, Key::unpack(col))
    }

    fn geq(&self, col: u64, target: Key) -> TetCursor {
        TetCursor::find_geq(self.nb, self.f1, Key::unpack(col), target)
    }

    #[inline]
    fn next(&self, cur: &mut TetCursor) {
        cur.find_next(self.nb);
    }

    #[inline]
    fn key(&self, cur: &TetCursor) -> Key {
        cur.cur
    }

    #[inline]
    fn col(&self, cur: &TetCursor) -> u64 {
        cur.t.pack()
    }

    /// For a tetrahedron `h = ⟨k1, k2⟩` the greatest boundary triangle is
    /// `t' = ⟨k1, max(c,d)⟩` with `{c,d} = f1⁻¹(k2)`; `(h, t')` is trivial
    /// iff `h` is the smallest simplex of `δt'` (checked by FindSmallesth,
    /// paper §4.3.5).
    fn trivial_owner(&self, key: Key) -> Option<u64> {
        let (c, d) = self.f1.edges[key.s as usize];
        let t = Key::new(key.p, c.max(d));
        let probe = TetCursor::find_smallest(self.nb, self.f1, t);
        if probe.cur == key {
            Some(t.pack())
        } else {
            None
        }
    }

    /// `low` is the smallest of `δcol` by construction, so the
    /// FindSmallesth probe is redundant: trivial iff the greatest
    /// boundary triangle of `low` is `col` itself.
    #[inline]
    fn is_self_trivial_first(&self, col: u64, low: Key) -> bool {
        let (c, d) = self.f1.edges[low.s as usize];
        Key::new(low.p, c.max(d)).pack() == col
    }
}

/// Result of reducing one dimension's columns.
#[derive(Clone, Debug, Default)]
pub struct ReduceResult {
    /// Persistence pairs `(column simplex id, pivot key)` — the column is
    /// the *birth* simplex, the pivot the *death*. Trivial pairs, which
    /// always have zero persistence (their pivot shares the column's
    /// diameter), are counted in `stats` but not stored.
    pub pairs: Vec<(u64, Key)>,
    /// Columns whose coboundary reduced to zero — essential classes.
    pub essential: Vec<u64>,
    pub stats: ReduceStats,
    /// Scheduler report (all-zero for the sequential engines).
    pub sched: SchedStats,
}
