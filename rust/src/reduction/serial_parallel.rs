//! Serial–parallel batched reduction (paper §4.4, Figures 14–17).
//!
//! A batch of B columns is reduced in two phases:
//!
//! * **parallel** — every column is pushed as far as the *committed*
//!   state allows (pivots owned by previously cleared columns, trivial
//!   pairs, zero columns). Workers share the immutable committed state
//!   and own their column's bucket table, so no synchronization is needed
//!   beyond the phase barrier.
//! * **serial** — columns are visited in filtration-processing order;
//!   intra-batch pivot collisions are resolved by appending the earlier
//!   column's state and resuming (which may re-enter committed-state
//!   reductions). Each resolved column commits immediately, so the final
//!   content of p⊥/V⊥ is *identical* to the sequential algorithm's.
//!
//! Batch-size trade-off per the paper: small batches waste parallelism,
//! large batches shift work into the serial phase. Defaults: 100 for
//! H1*/H2* (the paper's choice), overridable via [`crate::coordinator`].

use std::sync::Mutex;

use super::fast_column::{
    commit_claim, reduce_against, resume_reduce, BucketTable, ColumnOutcome, GlobalState,
};
use super::pool::ThreadPool;
use super::{ColumnSpace, ReduceResult, ReduceStats};
use crate::filtration::Key;

enum Pending<C: Copy> {
    Zero,
    Stopped {
        low: Key,
        self_trivial: bool,
        table: BucketTable<C>,
    },
}

/// Reduce `columns` (already in reverse filtration order, clearing applied
/// by the caller) with batched serial–parallel scheduling.
pub fn reduce_all<S: ColumnSpace>(
    space: &S,
    columns: &[u64],
    batch_size: usize,
    pool: &ThreadPool,
    keep_zero_pairs: bool,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> ReduceResult {
    let batch_size = batch_size.max(1);
    let mut state = GlobalState::new(keep_zero_pairs);
    let mut total_stats = ReduceStats::default();

    for batch in columns.chunks(batch_size) {
        // ---- Parallel phase -------------------------------------------
        let mut pending: Vec<Option<Pending<S::Cursor>>> =
            (0..batch.len()).map(|_| None).collect();
        {
            let slots: Vec<Mutex<(Option<Pending<S::Cursor>>, ReduceStats)>> = (0..batch.len())
                .map(|_| Mutex::new((None, ReduceStats::default())))
                .collect();
            let state_ref = &state;
            pool.run_chunks(batch.len(), |_tid, range| {
                for i in range {
                    let mut stats = ReduceStats::default();
                    let out = reduce_against(space, state_ref, batch[i], &mut stats);
                    let p = match out {
                        ColumnOutcome::Zero => Pending::Zero,
                        ColumnOutcome::Claim {
                            low,
                            self_trivial,
                            table,
                        } => Pending::Stopped {
                            low,
                            self_trivial,
                            table,
                        },
                    };
                    *slots[i].lock().unwrap() = (Some(p), stats);
                }
            });
            for (i, slot) in slots.into_iter().enumerate() {
                let (p, stats) = slot.into_inner().unwrap();
                total_stats.merge(&stats);
                pending[i] = p;
            }
        }

        // ---- Serial phase ----------------------------------------------
        // Visit in filtration-processing order; commits make earlier batch
        // columns visible to later ones exactly as in the sequential run.
        for (i, p) in pending.into_iter().enumerate() {
            let col = batch[i];
            total_stats.columns += 1;
            match p {
                Some(Pending::Zero) | None => {
                    state.result.stats.zero_columns += 1;
                    state.result.stats.essential += 1;
                    state.result.essential.push(col);
                }
                Some(Pending::Stopped {
                    low,
                    self_trivial,
                    table,
                }) => {
                    // Fast path: the stop-pivot is still unclaimed (no
                    // earlier batch column took it) — commit directly, no
                    // find_low re-walk and no trivial re-probe. This is
                    // the overwhelmingly common case and what makes the
                    // parallel phase actually pay off (EXPERIMENTS §Perf).
                    if self_trivial || !state.pivot_owner.contains_key(&low.pack()) {
                        commit_claim(
                            space,
                            &mut state,
                            col,
                            low,
                            self_trivial,
                            &table,
                            value_of(col),
                            key_value(low),
                        );
                        continue;
                    }
                    // Collision: resume against the updated committed
                    // state (find_low is idempotent on a stopped table).
                    let mut stats = ReduceStats::default();
                    match resume_reduce(space, &state, col, table, &mut stats) {
                        ColumnOutcome::Zero => {
                            state.result.stats.zero_columns += 1;
                            state.result.stats.essential += 1;
                            state.result.essential.push(col);
                        }
                        ColumnOutcome::Claim {
                            low,
                            self_trivial,
                            table,
                        } => {
                            commit_claim(
                                space,
                                &mut state,
                                col,
                                low,
                                self_trivial,
                                &table,
                                value_of(col),
                                key_value(low),
                            );
                        }
                    }
                    total_stats.merge(&stats);
                }
            }
        }
    }

    let mut result = state.result;
    result.stats.columns = total_stats.columns;
    result.stats.appends = total_stats.appends;
    result.stats.find_next_calls = total_stats.find_next_calls;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::{EdgeFiltration, Neighborhoods};
    use crate::geometry::{MetricData, PointCloud};
    use crate::reduction::EdgeColumns;
    use crate::util::rng::Pcg32;

    #[test]
    fn serial_parallel_matches_sequential_for_all_batch_sizes() {
        for seed in 0..4 {
            let mut rng = Pcg32::new(seed);
            let coords = (0..24 * 3).map(|_| rng.next_f64()).collect();
            let f = EdgeFiltration::build(
                &MetricData::Points(PointCloud::new(3, coords)),
                0.9,
            );
            let nb = Neighborhoods::build(&f, false);
            let space = EdgeColumns::new(&nb, &f);
            let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
            let seq = crate::reduction::fast_column::reduce_all(
                &space,
                cols.iter().copied(),
                true,
                |c| f.values[c as usize],
                |k| f.key_value(k),
            );
            let pool = ThreadPool::new(4);
            for batch in [1usize, 3, 10, 100, 10_000] {
                let par = reduce_all(
                    &space,
                    &cols,
                    batch,
                    &pool,
                    true,
                    |c| f.values[c as usize],
                    |k| f.key_value(k),
                );
                let mut a = seq.pairs.clone();
                let mut b = par.pairs.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "seed={seed} batch={batch}");
                let mut ea = seq.essential.clone();
                let mut eb = par.essential.clone();
                ea.sort_unstable();
                eb.sort_unstable();
                assert_eq!(ea, eb, "seed={seed} batch={batch}");
                assert_eq!(
                    seq.stats.trivial_pairs, par.stats.trivial_pairs,
                    "seed={seed} batch={batch}"
                );
            }
        }
    }
}
