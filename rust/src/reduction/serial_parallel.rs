//! Pipelined serial–parallel batched reduction (paper §4.4, rebuilt).
//!
//! The paper reduces a batch of B columns in two phases:
//!
//! * **parallel push** — every column is reduced as far as the
//!   *committed* state allows (pivots owned by previously cleared
//!   columns, trivial pairs, zero columns);
//! * **serial commit** — columns are visited in filtration-processing
//!   order; a column whose stop-pivot is still unclaimed commits
//!   directly, intra-batch collisions resume against the updated state.
//!
//! The seed implementation ran a hard barrier between the two phases of
//! every batch: the scheduler thread idled while workers pushed, then
//! the workers idled while the scheduler committed. This rebuild
//! **pipelines** the phases: while the scheduler commits batch *k*, the
//! work-stealing pool is already pushing batch *k+1* against a frozen
//! snapshot of the committed state.
//!
//! ## Why the overlap is exact
//!
//! The committed pivot maps are insert-only: an entry, once written,
//! never changes. A push that reads a *stale* snapshot (missing batch
//! *k*'s commits) therefore either
//!
//! * hits an entry — and applies exactly the reduction step the
//!   sequential algorithm would apply (the entry is final), or
//! * misses — and merely *stops early* at a pivot the serial phase will
//!   re-check against the full state, resuming if it is now claimed.
//!
//! Every op applied anywhere is thus a step of the sequential reduction,
//! and the serial phase replays any remaining steps in filtration order
//! against the exact sequential state — so pairs, essentials and V⊥ are
//! **bit-identical** to the sequential algorithm, for every batch size,
//! thread count and steal schedule. `rust/tests/differential.rs` pins
//! this down against the explicit boundary-matrix oracle.
//!
//! Mechanically, batch *k*'s commits land in a [`PivotState`] *delta*
//! while workers read only the frozen *base*; the serial phase reads an
//! [`Overlay`] of both; at the batch boundary (after the push ticket
//! resolves, so no reader is live) the delta is drained into the base.
//!
//! ## Dynamic batch sizing
//!
//! Batch-size trade-off per the paper: small batches waste parallelism,
//! large batches shift work into the serial phase. With the pipeline the
//! sweet spot is where the serial commit of batch *k* just hides under
//! the parallel push of batch *k+1*, so when [`SchedConfig::adaptive`]
//! is set the scheduler walks the batch size toward that point using the
//! observed serial/push time ratio of the previous iteration (halving
//! when serial-bound, doubling when push-bound, clamped to
//! `[batch_min, batch_max]`). Output is identical for every trajectory,
//! so adaptation is purely a performance knob.

use std::ops::Range;
use std::sync::Mutex;
use std::time::Instant;

use super::fast_column::{
    commit_claim, reduce_against, resume_reduce, BucketTable, ColumnOutcome, Overlay, PivotState,
    PivotView,
};
use super::pool::{ThreadPool, Ticket};
use super::{ColumnSpace, ReduceResult, ReduceStats};
use crate::filtration::Key;

/// Scheduler configuration (plumbed from `EngineOptions` / the run
/// config / the CLI).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Initial (or, with `adaptive` off, fixed) batch size.
    pub batch_size: usize,
    /// Adapt the batch size to the observed serial/push time ratio.
    pub adaptive: bool,
    /// Smallest batch the adaptation may reach.
    pub batch_min: usize,
    /// Largest batch the adaptation may reach.
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto (batch / (threads · 8),
    /// clamped to [1, 64]).
    pub steal_grain: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            batch_size: 100,
            adaptive: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
        }
    }
}

/// Per-reduction scheduler report (exposed via `ReduceResult::sched` and
/// aggregated into `EngineStats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    /// Pool worker count.
    pub threads: usize,
    pub batches: usize,
    pub min_batch: usize,
    pub max_batch: usize,
    /// Work-stealing tasks dispatched / stolen across all batches.
    pub tasks: u64,
    pub steals: u64,
    /// Columns committed straight off their pre-push (fast path).
    pub prepushed_columns: usize,
    /// Columns whose stop-pivot was claimed meanwhile → serial resume.
    pub resumed_columns: usize,
    /// Sum of worker time inside push tasks.
    pub parallel_busy_ns: u64,
    /// Scheduler time in serial commit phases.
    pub serial_ns: u64,
    /// Serial-commit time that ran while a push was in flight — work the
    /// seed's hard barrier would have serialized.
    pub overlap_ns: u64,
    /// Scheduler time blocked waiting on a push after its commit phase
    /// ended (the residual phase-barrier idle).
    pub barrier_wait_ns: u64,
    /// Wall time of the whole reduction.
    pub wall_ns: u64,
}

impl SchedStats {
    /// Worker-time utilization: busy time / (threads × wall).
    pub fn utilization(&self) -> f64 {
        if self.threads == 0 || self.wall_ns == 0 {
            return 0.0;
        }
        self.parallel_busy_ns as f64 / (self.threads as f64 * self.wall_ns as f64)
    }

    /// Fraction of serial-commit time hidden under a parallel push.
    pub fn overlap_fraction(&self) -> f64 {
        if self.serial_ns == 0 {
            return 0.0;
        }
        self.overlap_ns as f64 / self.serial_ns as f64
    }

    pub fn merge(&mut self, o: &SchedStats) {
        self.threads = self.threads.max(o.threads);
        self.batches += o.batches;
        self.min_batch = if self.min_batch == 0 {
            o.min_batch
        } else if o.min_batch == 0 {
            self.min_batch
        } else {
            self.min_batch.min(o.min_batch)
        };
        self.max_batch = self.max_batch.max(o.max_batch);
        self.tasks += o.tasks;
        self.steals += o.steals;
        self.prepushed_columns += o.prepushed_columns;
        self.resumed_columns += o.resumed_columns;
        self.parallel_busy_ns += o.parallel_busy_ns;
        self.serial_ns += o.serial_ns;
        self.overlap_ns += o.overlap_ns;
        self.barrier_wait_ns += o.barrier_wait_ns;
        self.wall_ns += o.wall_ns;
    }

    /// Machine-readable form for run summaries and bench dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .field("threads", self.threads)
            .field("batches", self.batches)
            .field("min_batch", self.min_batch)
            .field("max_batch", self.max_batch)
            .field("tasks", self.tasks as i64)
            .field("steals", self.steals as i64)
            .field("prepushed_columns", self.prepushed_columns)
            .field("resumed_columns", self.resumed_columns)
            .field("parallel_busy_s", self.parallel_busy_ns as f64 * 1e-9)
            .field("serial_s", self.serial_ns as f64 * 1e-9)
            .field("overlap_s", self.overlap_ns as f64 * 1e-9)
            .field("barrier_idle_s", self.barrier_wait_ns as f64 * 1e-9)
            .field("wall_s", self.wall_ns as f64 * 1e-9)
            .field("utilization", self.utilization())
    }

    /// One-line human summary for the CLI and benches.
    pub fn summary(&self) -> String {
        format!(
            "batches {} (size {}..{}), steals {}/{} tasks, resumed {}, util {:.0}%, overlap {:.3}s ({:.0}% of serial), idle {:.3}s",
            self.batches,
            self.min_batch,
            self.max_batch,
            self.steals,
            self.tasks,
            self.resumed_columns,
            self.utilization() * 100.0,
            self.overlap_ns as f64 * 1e-9,
            self.overlap_fraction() * 100.0,
            self.barrier_wait_ns as f64 * 1e-9,
        )
    }
}

enum Pending<C: Copy> {
    Zero,
    Stopped {
        low: Key,
        self_trivial: bool,
        table: BucketTable<C>,
    },
}

type Slot<C> = Mutex<(Option<Pending<C>>, ReduceStats)>;

fn new_slots<C: Copy>(n: usize) -> Vec<Slot<C>> {
    (0..n)
        .map(|_| Mutex::new((None, ReduceStats::default())))
        .collect()
}

/// Submit the parallel push of `columns[range]` against the frozen
/// `base`, writing outcomes into `slots` (one per column of the range).
///
/// # Safety
///
/// The returned ticket must be waited on (or dropped) before any of the
/// borrowed arguments is released or mutably borrowed — see
/// [`ThreadPool::submit_stealing`]. `reduce_all` upholds this: every
/// ticket is resolved before `base` is merged into or the slot vector
/// is consumed.
unsafe fn submit_push<'a, S: ColumnSpace>(
    pool: &'a ThreadPool,
    space: &'a S,
    columns: &'a [u64],
    range: Range<usize>,
    base: &'a PivotState,
    slots: &'a [Slot<S::Cursor>],
    grain: usize,
) -> Ticket<'a> {
    let start = range.start;
    pool.submit_stealing(range.len(), grain, move |_tid, r| {
        for i in r {
            let mut stats = ReduceStats::default();
            let out = reduce_against(space, base, columns[start + i], &mut stats);
            let p = match out {
                ColumnOutcome::Zero => Pending::Zero,
                ColumnOutcome::Claim {
                    low,
                    self_trivial,
                    table,
                } => Pending::Stopped {
                    low,
                    self_trivial,
                    table,
                },
            };
            *slots[i].lock().unwrap() = (Some(p), stats);
        }
    })
}

/// Reduce `columns` (already in reverse filtration order, clearing
/// applied by the caller) with the pipelined work-stealing scheduler.
/// Output is bit-identical to [`super::fast_column::reduce_all`].
pub fn reduce_all<S: ColumnSpace>(
    space: &S,
    columns: &[u64],
    cfg: &SchedConfig,
    pool: &ThreadPool,
    keep_zero_pairs: bool,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> ReduceResult {
    let len = columns.len();
    let threads = pool.threads();
    let wall0 = Instant::now();
    let pool0 = pool.stats();

    let mut base = PivotState::new();
    let mut delta = PivotState::new();
    let mut result = ReduceResult::default();
    let mut total = ReduceStats::default();
    let mut sched = SchedStats {
        threads,
        ..Default::default()
    };
    let mut min_batch = usize::MAX;
    let mut max_batch = 0usize;

    let clamp_batch = |b: usize| -> usize {
        if cfg.adaptive {
            b.clamp(cfg.batch_min.max(1), cfg.batch_max.max(cfg.batch_min).max(1))
        } else {
            b.max(1)
        }
    };
    let grain_for = |l: usize| -> usize {
        if cfg.steal_grain > 0 {
            cfg.steal_grain
        } else {
            (l / (threads * 8).max(1)).clamp(1, 64)
        }
    };
    let mut batch = clamp_batch(cfg.batch_size);

    // Prefetch batch 0 synchronously — there is nothing to overlap yet.
    let mut cur_start = 0usize;
    let mut cur_end = batch.min(len);
    let mut cur_slots: Vec<Slot<S::Cursor>> = new_slots(cur_end - cur_start);
    if cur_end > cur_start {
        // SAFETY: waited on immediately — no borrow is released first.
        unsafe {
            submit_push(
                pool,
                space,
                columns,
                cur_start..cur_end,
                &base,
                &cur_slots,
                grain_for(cur_end - cur_start),
            )
        }
        .wait();
    }

    while cur_start < cur_end {
        // Kick off the next batch's push against the frozen base before
        // committing the current batch: this is the pipeline overlap.
        let next_start = cur_end;
        let next_end = (next_start + batch).min(len);
        let next_slots: Vec<Slot<S::Cursor>> = new_slots(next_end - next_start);
        let span0 = pool.stats().span_ns;
        // SAFETY: the ticket is resolved below (`t.wait()`) before `base`
        // is mutated (merge_from) and before `next_slots` is moved into
        // `cur_slots`; nothing it borrows is released earlier.
        let ticket = if next_end > next_start {
            Some(unsafe {
                submit_push(
                    pool,
                    space,
                    columns,
                    next_start..next_end,
                    &base,
                    &next_slots,
                    grain_for(next_end - next_start),
                )
            })
        } else {
            None
        };
        let had_next = ticket.is_some();

        // ---- Serial commit of the current batch -----------------------
        // Visit in filtration-processing order; commits land in `delta`
        // (the base is frozen while workers read it) and become visible
        // to later columns of this batch through the overlay.
        let t_serial = Instant::now();
        for (i, slot) in std::mem::take(&mut cur_slots).into_iter().enumerate() {
            let col = columns[cur_start + i];
            let (pending, push_stats) = slot.into_inner().unwrap();
            total.merge(&push_stats);
            total.columns += 1;
            match pending {
                Some(Pending::Zero) | None => {
                    // Reduced to zero against committed state alone: the
                    // content is final (every applied op was final), so
                    // this is an essential class exactly as sequentially.
                    result.stats.zero_columns += 1;
                    result.stats.essential += 1;
                    result.essential.push(col);
                }
                Some(Pending::Stopped {
                    low,
                    self_trivial,
                    table,
                }) => {
                    // Fast path: the stop-pivot is still unclaimed in
                    // base ∪ delta — commit directly, no find_low re-walk
                    // and no trivial re-probe. The overwhelmingly common
                    // case, and what makes the pre-push pay off.
                    let claimed = Overlay {
                        committed: &base,
                        delta: &delta,
                    }
                    .is_claimed(low.pack());
                    if self_trivial || !claimed {
                        sched.prepushed_columns += 1;
                        commit_claim(
                            space,
                            &mut delta,
                            &mut result,
                            keep_zero_pairs,
                            col,
                            low,
                            self_trivial,
                            &table,
                            value_of(col),
                            key_value(low),
                        );
                        continue;
                    }
                    // Collision: resume against the full committed view
                    // (find_low is idempotent on a stopped table).
                    sched.resumed_columns += 1;
                    let mut stats = ReduceStats::default();
                    let outcome = {
                        let view = Overlay {
                            committed: &base,
                            delta: &delta,
                        };
                        resume_reduce(space, &view, col, table, &mut stats)
                    };
                    total.merge(&stats);
                    match outcome {
                        ColumnOutcome::Zero => {
                            result.stats.zero_columns += 1;
                            result.stats.essential += 1;
                            result.essential.push(col);
                        }
                        ColumnOutcome::Claim {
                            low,
                            self_trivial,
                            table,
                        } => {
                            commit_claim(
                                space,
                                &mut delta,
                                &mut result,
                                keep_zero_pairs,
                                col,
                                low,
                                self_trivial,
                                &table,
                                value_of(col),
                                key_value(low),
                            );
                        }
                    }
                }
            }
        }
        let serial_ns = t_serial.elapsed().as_nanos() as u64;
        sched.serial_ns += serial_ns;

        // ---- Join the pipelined push, then publish the delta ----------
        let t_wait = Instant::now();
        if let Some(t) = ticket {
            t.wait();
        }
        let wait_ns = t_wait.elapsed().as_nanos() as u64;
        if had_next {
            sched.barrier_wait_ns += wait_ns;
            let push_span = pool.stats().span_ns.saturating_sub(span0);
            sched.overlap_ns += serial_ns.min(push_span);
        }
        // No reader is live now: drain the batch's commits into the base
        // so the next serial phase (and the push after it) see them.
        base.merge_from(&mut delta);

        let cur_len = cur_end - cur_start;
        sched.batches += 1;
        min_batch = min_batch.min(cur_len);
        max_batch = max_batch.max(cur_len);

        // ---- Adapt the batch size -------------------------------------
        // Serial-bound (commit > ~75% of the push span): halve, pushing
        // collision resolution back into the parallel phase. Push-bound
        // (commit < ~25%): double, amortizing dispatch and widening the
        // overlap window. Correctness is batch-size independent.
        if had_next && cfg.adaptive {
            let span = serial_ns + wait_ns;
            if span > 0 {
                if serial_ns * 4 > span * 3 {
                    batch = clamp_batch(batch / 2);
                } else if serial_ns * 4 < span {
                    batch = clamp_batch(batch.saturating_mul(2));
                }
            }
        }

        cur_start = next_start;
        cur_end = next_end;
        cur_slots = next_slots;
    }

    let pool1 = pool.stats();
    sched.tasks = pool1.tasks - pool0.tasks;
    sched.steals = pool1.steals - pool0.steals;
    sched.parallel_busy_ns = pool1.busy_ns - pool0.busy_ns;
    sched.wall_ns = wall0.elapsed().as_nanos() as u64;
    sched.min_batch = if sched.batches > 0 { min_batch } else { 0 };
    sched.max_batch = max_batch;

    result.stats.columns = total.columns;
    result.stats.appends = total.appends;
    result.stats.find_next_calls = total.find_next_calls;
    result.sched = sched;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::{EdgeFiltration, Neighborhoods};
    use crate::geometry::{MetricData, PointCloud};
    use crate::reduction::EdgeColumns;
    use crate::util::rng::Pcg32;

    fn fixed(batch: usize) -> SchedConfig {
        SchedConfig {
            batch_size: batch,
            adaptive: false,
            ..Default::default()
        }
    }

    #[test]
    fn pipelined_matches_sequential_for_all_batch_sizes() {
        for seed in 0..4 {
            let mut rng = Pcg32::new(seed);
            let coords = (0..24 * 3).map(|_| rng.next_f64()).collect();
            let f = EdgeFiltration::build(
                &MetricData::Points(PointCloud::new(3, coords)),
                0.9,
            );
            let nb = Neighborhoods::build(&f, false);
            let space = EdgeColumns::new(&nb, &f);
            let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
            let seq = crate::reduction::fast_column::reduce_all(
                &space,
                cols.iter().copied(),
                true,
                |c| f.values[c as usize],
                |k| f.key_value(k),
            );
            let pool = ThreadPool::new(4);
            let mut cfgs: Vec<SchedConfig> = [1usize, 3, 10, 100, 10_000]
                .iter()
                .map(|&b| fixed(b))
                .collect();
            cfgs.push(SchedConfig {
                batch_size: 4,
                adaptive: true,
                batch_min: 2,
                batch_max: 64,
                steal_grain: 1,
            });
            for cfg in cfgs {
                let par = reduce_all(
                    &space,
                    &cols,
                    &cfg,
                    &pool,
                    true,
                    |c| f.values[c as usize],
                    |k| f.key_value(k),
                );
                let mut a = seq.pairs.clone();
                let mut b = par.pairs.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "seed={seed} cfg={cfg:?}");
                let mut ea = seq.essential.clone();
                let mut eb = par.essential.clone();
                ea.sort_unstable();
                eb.sort_unstable();
                assert_eq!(ea, eb, "seed={seed} cfg={cfg:?}");
                assert_eq!(
                    seq.stats.trivial_pairs, par.stats.trivial_pairs,
                    "seed={seed} cfg={cfg:?}"
                );
                // Every pair/trivial column is either committed straight
                // off its pre-push or serially resumed; columns that end
                // zero may appear in either bucket or in neither.
                let handled = par.sched.prepushed_columns + par.sched.resumed_columns;
                assert!(
                    handled >= seq.stats.pairs + seq.stats.trivial_pairs
                        && handled <= cols.len(),
                    "seed={seed} cfg={cfg:?}: handled={handled}"
                );
            }
        }
    }

    #[test]
    fn empty_column_set() {
        let mut rng = Pcg32::new(9);
        let coords = (0..12 * 2).map(|_| rng.next_f64()).collect();
        let f = EdgeFiltration::build(&MetricData::Points(PointCloud::new(2, coords)), 0.5);
        let nb = Neighborhoods::build(&f, false);
        let space = EdgeColumns::new(&nb, &f);
        let pool = ThreadPool::new(2);
        let r = reduce_all(
            &space,
            &[],
            &SchedConfig::default(),
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        assert_eq!(r.stats.columns, 0);
        assert!(r.pairs.is_empty() && r.essential.is_empty());
        assert_eq!(r.sched.batches, 0);
    }
}
