//! Pipelined serial–parallel batched reduction (paper §4.4, rebuilt).
//!
//! The paper reduces a batch of B columns in two phases:
//!
//! * **parallel push** — every column is reduced as far as the
//!   *committed* state allows (pivots owned by previously cleared
//!   columns, trivial pairs, zero columns);
//! * **serial commit** — columns are visited in filtration-processing
//!   order; a column whose stop-pivot is still unclaimed commits
//!   directly, intra-batch collisions resume against the updated state.
//!
//! The seed implementation ran a hard barrier between the two phases of
//! every batch: the scheduler thread idled while workers pushed, then
//! the workers idled while the scheduler committed. This rebuild
//! **pipelines** the phases: while the scheduler commits batch *k*, the
//! work-stealing pool is already pushing batch *k+1* against a frozen
//! snapshot of the committed state.
//!
//! ## The third stage: sharded column enumeration
//!
//! Columns do not have to be materialized up front. A
//! [`ColumnShards`] source describes the column stream as an ordered
//! sequence of *shards* (for H2\*: runs of descending diameter edges
//! whose triangles are enumerated on the fly); [`reduce_stream`] runs
//! shard enumeration as extra work-stealing tasks **in the same pool
//! generation as the next batch's push**, so the pipeline becomes three
//! stages deep:
//!
//! ```text
//!   enumerate chunk k+2   (pool workers, region B of the generation)
//!   push      batch k+1   (pool workers, region A of the generation)
//!   commit    batch k     (scheduler thread, concurrently)
//! ```
//!
//! Shard buffers are spliced back in shard order at the generation
//! boundary, so the reduction consumes a column sequence **identical to
//! the sequential enumeration** — sharding is invisible to the output.
//! If the lookahead falls behind (a shard-heavy region), the scheduler
//! blocks on enumeration-only generations; that time is reported as
//! `enum_block_ns`, distinct from the push `barrier_wait_ns`.
//!
//! ## Why the overlap is exact
//!
//! The committed pivot maps are insert-only: an entry, once written,
//! never changes. A push that reads a *stale* snapshot (missing batch
//! *k*'s commits) therefore either
//!
//! * hits an entry — and applies exactly the reduction step the
//!   sequential algorithm would apply (the entry is final), or
//! * misses — and merely *stops early* at a pivot the serial phase will
//!   re-check against the full state, resuming if it is now claimed.
//!
//! Every op applied anywhere is thus a step of the sequential reduction,
//! and the serial phase replays any remaining steps in filtration order
//! against the exact sequential state — so pairs, essentials and V⊥ are
//! **bit-identical** to the sequential algorithm, for every batch size,
//! shard plan, thread count and steal schedule.
//! `rust/tests/differential.rs` pins this down against the explicit
//! boundary-matrix oracle.
//!
//! Mechanically, batch *k*'s commits land in a [`PivotState`] *delta*
//! while workers read only the frozen *base*; the serial phase reads an
//! [`Overlay`] of both; at the batch boundary (after the push ticket
//! resolves, so no reader is live) the delta is drained into the base.
//!
//! ## Dynamic batch sizing
//!
//! Batch-size trade-off per the paper: small batches waste parallelism,
//! large batches shift work into the serial phase. With the pipeline the
//! sweet spot is where the serial commit of batch *k* just hides under
//! the parallel push of batch *k+1*, so when [`SchedConfig::adaptive`]
//! is set the scheduler walks the batch size toward that point using the
//! observed serial/push time ratio of the previous iteration (halving
//! when the serial fraction exceeds [`SchedConfig::adapt_high`],
//! doubling when it falls below [`SchedConfig::adapt_low`], clamped to
//! `[batch_min, batch_max]`). Output is identical for every trajectory,
//! so adaptation is purely a performance knob.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::fast_column::{
    commit_claim, reduce_against, resume_reduce, BucketTable, ColumnOutcome, Overlay, PivotState,
    PivotView,
};
use super::cancel::CancelToken;
use super::pool::{ThreadPool, Ticket};
use super::{ColumnSpace, ReduceResult, ReduceStats};
use crate::error::DoryError;
use crate::filtration::Key;

/// Scheduler configuration (plumbed from `EngineOptions` / the run
/// config / the CLI).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Initial (or, with `adaptive` off, fixed) batch size.
    pub batch_size: usize,
    /// Adapt the batch size to the observed serial/push time ratio.
    pub adaptive: bool,
    /// Smallest batch the adaptation may reach.
    pub batch_min: usize,
    /// Largest batch the adaptation may reach.
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto (batch / (threads · 8),
    /// clamped to [1, 64]).
    pub steal_grain: usize,
    /// Serial fraction below which the batch size doubles (push-bound).
    pub adapt_low: f64,
    /// Serial fraction above which the batch size halves (serial-bound).
    pub adapt_high: f64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            batch_size: 100,
            adaptive: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
            adapt_low: 0.25,
            adapt_high: 0.75,
        }
    }
}

/// Per-reduction scheduler report (exposed via `ReduceResult::sched` and
/// aggregated into `EngineStats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    /// Pool worker count.
    pub threads: usize,
    pub batches: usize,
    pub min_batch: usize,
    pub max_batch: usize,
    /// Work-stealing tasks dispatched / stolen across all batches
    /// (pushes *and* enumeration shards).
    pub tasks: u64,
    pub steals: u64,
    /// Columns committed straight off their pre-push (fast path).
    pub prepushed_columns: usize,
    /// Columns whose stop-pivot was claimed meanwhile → serial resume.
    pub resumed_columns: usize,
    /// Sum of worker time inside push and enumeration tasks.
    pub parallel_busy_ns: u64,
    /// Scheduler time in serial commit phases.
    pub serial_ns: u64,
    /// Serial-commit time that ran while a pool generation (the next
    /// batch's push, plus any ride-along enumeration shards sharing its
    /// generation) was in flight — work the seed's hard barrier would
    /// have serialized. The generation span does not distinguish push
    /// from enumeration time, so on enumeration-heavy phases this reads
    /// as "commit hidden under pool work", not "under pushes alone".
    pub overlap_ns: u64,
    /// Scheduler time blocked waiting, after its commit phase ended, on
    /// a generation that contained a push (the residual phase-barrier
    /// idle). The generation may also carry ride-along enumeration
    /// shards; a tail where shards outlast the push is booked here, not
    /// in `enum_block_ns` — the pool does not attribute a mixed
    /// generation's wait per region.
    pub barrier_wait_ns: u64,
    /// Wall time of the whole reduction.
    pub wall_ns: u64,
    /// Column-enumeration shards executed as pool tasks (zero for the
    /// sequential engines, whose enumeration runs inline).
    pub enum_shards: u64,
    /// Columns produced by the sharded enumeration.
    pub enum_columns: u64,
    /// Worker time spent inside shard-enumeration task bodies.
    pub enum_busy_ns: u64,
    /// Scheduler time blocked on enumeration-only work (the batch-0
    /// bootstrap and catch-up generations with no push in flight) — a
    /// lower bound on the enumeration span the pipeline failed to hide,
    /// since mixed-generation tails land in `barrier_wait_ns`.
    pub enum_block_ns: u64,
    /// Columns resolved by the enumeration-time apparent-pair shortcut:
    /// suppressed inside the shard fills, so they never entered the
    /// column stream, a push task, or a serial commit. Set by the
    /// homology engine after the reduction (the scheduler itself never
    /// sees these columns); zero with the shortcut off and for the raw
    /// `reduce_all`/`reduce_stream` entry points.
    pub shortcut_columns: u64,
}

impl SchedStats {
    /// Worker-time utilization: busy time / (threads × wall).
    pub fn utilization(&self) -> f64 {
        if self.threads == 0 || self.wall_ns == 0 {
            return 0.0;
        }
        self.parallel_busy_ns as f64 / (self.threads as f64 * self.wall_ns as f64)
    }

    /// Fraction of serial-commit time hidden under a parallel push.
    pub fn overlap_fraction(&self) -> f64 {
        if self.serial_ns == 0 {
            return 0.0;
        }
        self.overlap_ns as f64 / self.serial_ns as f64
    }

    /// Fraction of the worker-side enumeration span hidden under the
    /// pipeline (1 − blocked/busy, clamped to [0, 1]). Optimistic: only
    /// enumeration-only blocking counts as visible (see
    /// [`SchedStats::enum_block_ns`]).
    pub fn enum_hidden_fraction(&self) -> f64 {
        if self.enum_busy_ns == 0 {
            return 0.0;
        }
        let visible = self.enum_block_ns.min(self.enum_busy_ns);
        1.0 - visible as f64 / self.enum_busy_ns as f64
    }

    /// Fraction of the enumerated column universe resolved by the
    /// in-shard apparent-pair shortcut. Defined only for pooled runs
    /// (`enum_columns` counts the surviving stream); sequential engines
    /// leave `enum_columns` at 0, and this reports 0 rather than a
    /// fabricated 100% — use the engine-level `ReduceStats::skip_rate`
    /// for a path-independent rate.
    pub fn skip_fraction(&self) -> f64 {
        // `enum_shards > 0` marks a pooled run (sharded enumeration
        // actually executed); it distinguishes "sequential, stream size
        // unknown here" from "pooled and everything was skipped".
        let total = self.shortcut_columns + self.enum_columns;
        if total == 0 || self.enum_shards == 0 {
            return 0.0;
        }
        self.shortcut_columns as f64 / total as f64
    }

    pub fn merge(&mut self, o: &SchedStats) {
        self.threads = self.threads.max(o.threads);
        self.batches += o.batches;
        self.min_batch = if self.min_batch == 0 {
            o.min_batch
        } else if o.min_batch == 0 {
            self.min_batch
        } else {
            self.min_batch.min(o.min_batch)
        };
        self.max_batch = self.max_batch.max(o.max_batch);
        self.tasks += o.tasks;
        self.steals += o.steals;
        self.prepushed_columns += o.prepushed_columns;
        self.resumed_columns += o.resumed_columns;
        self.parallel_busy_ns += o.parallel_busy_ns;
        self.serial_ns += o.serial_ns;
        self.overlap_ns += o.overlap_ns;
        self.barrier_wait_ns += o.barrier_wait_ns;
        self.wall_ns += o.wall_ns;
        self.enum_shards += o.enum_shards;
        self.enum_columns += o.enum_columns;
        self.enum_busy_ns += o.enum_busy_ns;
        self.enum_block_ns += o.enum_block_ns;
        self.shortcut_columns += o.shortcut_columns;
    }

    /// Machine-readable form for run summaries and bench dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .field("threads", self.threads)
            .field("batches", self.batches)
            .field("min_batch", self.min_batch)
            .field("max_batch", self.max_batch)
            .field("tasks", self.tasks as i64)
            .field("steals", self.steals as i64)
            .field("prepushed_columns", self.prepushed_columns)
            .field("resumed_columns", self.resumed_columns)
            .field("parallel_busy_s", self.parallel_busy_ns as f64 * 1e-9)
            .field("serial_s", self.serial_ns as f64 * 1e-9)
            .field("overlap_s", self.overlap_ns as f64 * 1e-9)
            .field("barrier_idle_s", self.barrier_wait_ns as f64 * 1e-9)
            .field("wall_s", self.wall_ns as f64 * 1e-9)
            .field("utilization", self.utilization())
            .field("enum_shards", self.enum_shards as i64)
            .field("enum_columns", self.enum_columns as i64)
            .field("enum_busy_s", self.enum_busy_ns as f64 * 1e-9)
            .field("enum_block_s", self.enum_block_ns as f64 * 1e-9)
            .field("enum_hidden", self.enum_hidden_fraction())
            .field("shortcut_columns", self.shortcut_columns as i64)
            .field("skip_rate", self.skip_fraction())
    }

    /// One-line human summary for the CLI and benches.
    pub fn summary(&self) -> String {
        format!(
            "batches {} (size {}..{}), steals {}/{} tasks, resumed {}, util {:.0}%, overlap {:.3}s ({:.0}% of serial), idle {:.3}s, enum {} shards ({:.3}s busy, {:.3}s blocked, {:.0}% hidden), shortcut {} cols ({:.0}% skipped)",
            self.batches,
            self.min_batch,
            self.max_batch,
            self.steals,
            self.tasks,
            self.resumed_columns,
            self.utilization() * 100.0,
            self.overlap_ns as f64 * 1e-9,
            self.overlap_fraction() * 100.0,
            self.barrier_wait_ns as f64 * 1e-9,
            self.enum_shards,
            self.enum_busy_ns as f64 * 1e-9,
            self.enum_block_ns as f64 * 1e-9,
            self.enum_hidden_fraction() * 100.0,
            self.shortcut_columns,
            self.skip_fraction() * 100.0,
        )
    }
}

/// A column stream served shard by shard, in canonical order.
///
/// Concatenating `fill(0), fill(1), …, fill(n_shards()-1)` must yield
/// exactly the sequential column enumeration — the reduction's output is
/// defined over that sequence, and [`reduce_stream`] splices shard
/// buffers back in shard order to reconstruct it. `fill` is called at
/// most once per shard, possibly concurrently (distinct shards) from
/// pool worker threads.
pub trait ColumnShards: Sync {
    fn n_shards(&self) -> usize;
    /// Append shard `shard`'s columns to `out`.
    fn fill(&self, shard: usize, out: &mut Vec<u64>);
}

/// Pre-materialized columns served in fixed chunks — the adapter behind
/// [`reduce_all`] and a useful test double for sharded sources.
pub struct SliceShards<'a> {
    pub cols: &'a [u64],
    pub chunk: usize,
}

impl ColumnShards for SliceShards<'_> {
    fn n_shards(&self) -> usize {
        self.cols.len().div_ceil(self.chunk.max(1))
    }

    fn fill(&self, shard: usize, out: &mut Vec<u64>) {
        let c = self.chunk.max(1);
        let lo = shard * c;
        let hi = (lo + c).min(self.cols.len());
        out.extend_from_slice(&self.cols[lo..hi]);
    }
}

/// Partition `0..n` (an edge-order universe) into **descending** shards
/// for sharded column enumeration: shard 0 covers the highest orders, so
/// walking shards in index order (each walked descending internally)
/// reproduces the engine's reverse-filtration sweep. With
/// `enum_grain > 0` every shard spans that many orders; otherwise with
/// `enum_shards > 0` the range splits into that many near-equal shards;
/// otherwise the grain targets ~16 shards per worker (clamped so tiny
/// inputs do not shatter into empty shards).
pub fn shard_plan(n: usize, threads: usize, enum_shards: usize, enum_grain: usize) -> Vec<Range<u32>> {
    if n == 0 {
        return Vec::new();
    }
    let grain = if enum_grain > 0 {
        enum_grain
    } else if enum_shards > 0 {
        n.div_ceil(enum_shards)
    } else {
        n.div_ceil(threads.max(1) * 16).clamp(8, 16384)
    };
    let mut out = Vec::with_capacity(n.div_ceil(grain));
    let mut hi = n;
    while hi > 0 {
        let lo = hi.saturating_sub(grain);
        out.push(lo as u32..hi as u32);
        hi = lo;
    }
    out
}

enum Pending<C: Copy> {
    Zero,
    Stopped {
        low: Key,
        self_trivial: bool,
        table: BucketTable<C>,
    },
}

type Slot<C> = Mutex<(Option<Pending<C>>, ReduceStats)>;

fn new_slots<C: Copy>(n: usize) -> Vec<Slot<C>> {
    (0..n)
        .map(|_| Mutex::new((None, ReduceStats::default())))
        .collect()
}

/// Submit one combined pool generation: region A pushes
/// `columns[push]` against the frozen `base` into `slots` (one per
/// column of the range), region B enumerates shards
/// `first_shard..first_shard + enum_slots.len()` of `src` into
/// `enum_slots` (one task per shard, so every shard stays individually
/// stealable). Either region may be empty.
///
/// # Safety
///
/// The returned ticket must be waited on (or dropped) before any of the
/// borrowed arguments is released or mutably borrowed — see
/// [`ThreadPool::submit_stealing_regions`]. [`reduce_stream`] upholds
/// this: every ticket is resolved before `columns` grows, `base` is
/// merged into, or either slot vector is consumed.
#[allow(clippy::too_many_arguments)]
unsafe fn submit_batch<'a, S: ColumnSpace, Src: ColumnShards>(
    pool: &'a ThreadPool,
    space: &'a S,
    src: &'a Src,
    columns: &'a [u64],
    push: Range<usize>,
    grain: usize,
    base: &'a PivotState,
    slots: &'a [Slot<S::Cursor>],
    first_shard: usize,
    enum_slots: &'a [Mutex<Vec<u64>>],
    enum_busy_ns: &'a AtomicU64,
) -> Ticket<'a> {
    let push_len = push.len();
    let start = push.start;
    pool.submit_stealing_regions(
        &[(push_len, grain), (enum_slots.len(), 1)],
        move |_tid, r| {
            for i in r {
                if i < push_len {
                    let mut stats = ReduceStats::default();
                    let out = reduce_against(space, base, columns[start + i], &mut stats);
                    let p = match out {
                        // Workers cannot reuse across slots; drop the table.
                        ColumnOutcome::Zero { .. } => Pending::Zero,
                        ColumnOutcome::Claim {
                            low,
                            self_trivial,
                            table,
                        } => Pending::Stopped {
                            low,
                            self_trivial,
                            table,
                        },
                    };
                    *slots[i].lock().unwrap() = (Some(p), stats);
                } else {
                    let j = i - push_len;
                    let t0 = Instant::now();
                    let mut buf = enum_slots[j].lock().unwrap();
                    src.fill(first_shard + j, &mut buf);
                    enum_busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            }
        },
    )
}

/// Enumerate `count` shards starting at `first` on the pool, blocking,
/// and splice the buffers into `columns` in shard order.
fn enum_blocking<Src: ColumnShards>(
    pool: &ThreadPool,
    src: &Src,
    first: usize,
    count: usize,
    columns: &mut Vec<u64>,
    enum_busy_ns: &AtomicU64,
) {
    if count == 0 {
        return;
    }
    let slots: Vec<Mutex<Vec<u64>>> = (0..count).map(|_| Mutex::new(Vec::new())).collect();
    pool.run_stealing(count, 1, |_tid, r| {
        for i in r {
            let t0 = Instant::now();
            let mut buf = slots[i].lock().unwrap();
            src.fill(first + i, &mut buf);
            enum_busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    });
    for s in slots {
        columns.append(&mut s.into_inner().unwrap());
    }
}

/// Splice a generation's ride-along shard buffers into `columns` in
/// shard order (canonical) and advance the shard accounting. Must only
/// be called after the generation's ticket resolved.
fn splice_shards(
    enum_slots: Vec<Mutex<Vec<u64>>>,
    columns: &mut Vec<u64>,
    shard_cursor: &mut usize,
    enum_tasks: &mut u64,
) {
    let n = enum_slots.len();
    for s in enum_slots {
        columns.append(&mut s.into_inner().unwrap());
    }
    *shard_cursor += n;
    *enum_tasks += n as u64;
}

/// Blocking enumeration until `columns` holds at least `want_cols`
/// entries or the stream is exhausted, in `enum_cap`-shard rounds.
/// Used for the bootstrap (nothing to overlap yet) and for catch-up
/// when the ride-along lookahead fell behind. Returns the ns the
/// scheduler spent blocked (0 when there was nothing to do).
#[allow(clippy::too_many_arguments)]
fn enum_until<Src: ColumnShards>(
    pool: &ThreadPool,
    src: &Src,
    want_cols: usize,
    n_shards: usize,
    enum_cap: usize,
    shard_cursor: &mut usize,
    enum_tasks: &mut u64,
    columns: &mut Vec<u64>,
    enum_busy_ns: &AtomicU64,
) -> u64 {
    if columns.len() >= want_cols || *shard_cursor >= n_shards {
        return 0;
    }
    let t0 = Instant::now();
    while columns.len() < want_cols && *shard_cursor < n_shards {
        let k = enum_cap.min(n_shards - *shard_cursor);
        enum_blocking(pool, src, *shard_cursor, k, columns, enum_busy_ns);
        *shard_cursor += k;
        *enum_tasks += k as u64;
    }
    t0.elapsed().as_nanos() as u64
}

/// Reduce the column stream of `src` (canonical reverse filtration
/// order, clearing applied inside the source) with the three-stage
/// pipelined work-stealing scheduler: shard enumeration and batch
/// pushes run as pool tasks while the scheduler thread commits the
/// previous batch. Output is bit-identical to materializing the stream
/// and running [`super::fast_column::reduce_all`] sequentially.
///
/// `cancel` is polled only at batch-commit boundaries — the loop top,
/// where no pipeline ticket is outstanding and no worker borrows
/// `columns`/`base` — so a tripped deadline aborts with a typed
/// [`DoryError::DeadlineExceeded`] without stranding pool state; every
/// run that completes is bit-identical whether or not a (non-tripped)
/// token was supplied.
#[allow(clippy::too_many_arguments)]
pub fn reduce_stream<S: ColumnSpace, Src: ColumnShards>(
    space: &S,
    src: &Src,
    cfg: &SchedConfig,
    pool: &ThreadPool,
    keep_zero_pairs: bool,
    cancel: &CancelToken,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> Result<ReduceResult, DoryError> {
    let threads = pool.threads();
    let wall0 = Instant::now();
    let pool0 = pool.stats();

    let n_shards = src.n_shards();
    let mut shard_cursor = 0usize; // next shard to schedule
    let mut columns: Vec<u64> = Vec::new();
    let enum_busy_ns = AtomicU64::new(0);
    let mut enum_block_ns = 0u64;
    let mut enum_tasks = 0u64;

    let mut base = PivotState::new();
    let mut delta = PivotState::new();
    let mut result = ReduceResult::default();
    let mut total = ReduceStats::default();
    let mut sched = SchedStats {
        threads,
        ..Default::default()
    };
    let mut min_batch = usize::MAX;
    let mut max_batch = 0usize;

    let clamp_batch = |b: usize| -> usize {
        if cfg.adaptive {
            b.clamp(cfg.batch_min.max(1), cfg.batch_max.max(cfg.batch_min).max(1))
        } else {
            b.max(1)
        }
    };
    let grain_for = |l: usize| -> usize {
        if cfg.steal_grain > 0 {
            cfg.steal_grain
        } else {
            (l / (threads * 8).max(1)).clamp(1, 64)
        }
    };
    // Shards per ride-along generation / blocking round are capped so a
    // mis-estimated shard size cannot monopolize a generation.
    let enum_cap = (threads * 4).max(1);
    // The lookahead keeps ~2 batches of columns enumerated beyond the
    // in-flight push, sized with the running columns-per-shard average.
    let lookahead = |cols_len: usize, target_end: usize, batch: usize, cursor: usize| -> usize {
        if cursor >= n_shards {
            return 0;
        }
        let want = target_end + 2 * batch;
        if cols_len >= want {
            return 0;
        }
        let avg = if cursor == 0 {
            1.0
        } else {
            (cols_len as f64 / cursor as f64).max(1.0)
        };
        (((want - cols_len) as f64 / avg).ceil() as usize)
            .max(1)
            .min(n_shards - cursor)
            .min(enum_cap)
    };
    let mut batch = clamp_batch(cfg.batch_size);

    // An expired deadline aborts before any pool work is scheduled.
    cancel.check()?;

    // ---- bootstrap: enumerate (in parallel, blocking) until batch 0
    // has columns or the stream is exhausted.
    enum_block_ns += enum_until(
        pool,
        src,
        batch,
        n_shards,
        enum_cap,
        &mut shard_cursor,
        &mut enum_tasks,
        &mut columns,
        &enum_busy_ns,
    );

    // ---- batch 0: push synchronously (nothing to overlap yet), with
    // the first ride-along enumeration chunk sharing the generation.
    let mut cur_start = 0usize;
    let mut cur_end = batch.min(columns.len());
    let mut cur_slots: Vec<Slot<S::Cursor>> = new_slots(cur_end - cur_start);
    if cur_end > cur_start {
        let n_enum = lookahead(columns.len(), cur_end, batch, shard_cursor);
        let enum_slots: Vec<Mutex<Vec<u64>>> =
            (0..n_enum).map(|_| Mutex::new(Vec::new())).collect();
        // SAFETY: waited on immediately — no borrow is released first.
        unsafe {
            submit_batch(
                pool,
                space,
                src,
                &columns,
                cur_start..cur_end,
                grain_for(cur_end - cur_start),
                &base,
                &cur_slots,
                shard_cursor,
                &enum_slots,
                &enum_busy_ns,
            )
        }
        .wait();
        splice_shards(enum_slots, &mut columns, &mut shard_cursor, &mut enum_tasks);
    }

    while cur_start < cur_end {
        // Batch-commit boundary: the previous generation's ticket has
        // been waited, so nothing borrows `columns`/`base`/the slots —
        // the one place a cooperative abort is safe mid-reduction.
        cancel.check()?;

        // Catch-up: the push we are about to submit reads materialized
        // columns, so if the ride-along lookahead fell behind while
        // shards remain, block on enumeration-only generations now.
        enum_block_ns += enum_until(
            pool,
            src,
            cur_end + batch,
            n_shards,
            enum_cap,
            &mut shard_cursor,
            &mut enum_tasks,
            &mut columns,
            &enum_busy_ns,
        );

        // Kick off the next batch's push (plus the next enumeration
        // chunk) against the frozen base before committing the current
        // batch: this is the pipeline overlap.
        let next_start = cur_end;
        let next_end = (next_start + batch).min(columns.len());
        let next_slots: Vec<Slot<S::Cursor>> = new_slots(next_end - next_start);
        let n_enum = lookahead(columns.len(), next_end, batch, shard_cursor);
        let enum_slots: Vec<Mutex<Vec<u64>>> =
            (0..n_enum).map(|_| Mutex::new(Vec::new())).collect();
        let span0 = pool.stats().span_ns;
        let had_push = next_end > next_start;
        // SAFETY: the ticket is resolved below (`t.wait()`) before
        // `columns` is extended, before `base` is mutated (merge_from)
        // and before `next_slots`/`enum_slots` are consumed; nothing it
        // borrows is released earlier.
        let ticket = if had_push || n_enum > 0 {
            Some(unsafe {
                submit_batch(
                    pool,
                    space,
                    src,
                    &columns,
                    next_start..next_end,
                    grain_for(next_end - next_start),
                    &base,
                    &next_slots,
                    shard_cursor,
                    &enum_slots,
                    &enum_busy_ns,
                )
            })
        } else {
            None
        };

        // ---- Serial commit of the current batch -----------------------
        // Visit in filtration-processing order; commits land in `delta`
        // (the base is frozen while workers read it) and become visible
        // to later columns of this batch through the overlay.
        let t_serial = Instant::now();
        for (i, slot) in std::mem::take(&mut cur_slots).into_iter().enumerate() {
            let col = columns[cur_start + i];
            let (pending, push_stats) = slot.into_inner().unwrap();
            total.merge(&push_stats);
            total.columns += 1;
            match pending {
                Some(Pending::Zero) | None => {
                    // Reduced to zero against committed state alone: the
                    // content is final (every applied op was final), so
                    // this is an essential class exactly as sequentially.
                    result.stats.zero_columns += 1;
                    result.stats.essential += 1;
                    result.essential.push(col);
                }
                Some(Pending::Stopped {
                    low,
                    self_trivial,
                    table,
                }) => {
                    // Fast path: the stop-pivot is still unclaimed in
                    // base ∪ delta — commit directly, no find_low re-walk
                    // and no trivial re-probe. The overwhelmingly common
                    // case, and what makes the pre-push pay off.
                    let claimed = Overlay {
                        committed: &base,
                        delta: &delta,
                    }
                    .is_claimed(low.pack());
                    if self_trivial || !claimed {
                        sched.prepushed_columns += 1;
                        commit_claim(
                            space,
                            &mut delta,
                            &mut result,
                            keep_zero_pairs,
                            col,
                            low,
                            self_trivial,
                            &table,
                            value_of(col),
                            key_value(low),
                        );
                        continue;
                    }
                    // Collision: resume against the full committed view
                    // (find_low is idempotent on a stopped table).
                    sched.resumed_columns += 1;
                    let mut stats = ReduceStats::default();
                    let outcome = {
                        let view = Overlay {
                            committed: &base,
                            delta: &delta,
                        };
                        resume_reduce(space, &view, col, table, &mut stats)
                    };
                    total.merge(&stats);
                    match outcome {
                        ColumnOutcome::Zero { .. } => {
                            result.stats.zero_columns += 1;
                            result.stats.essential += 1;
                            result.essential.push(col);
                        }
                        ColumnOutcome::Claim {
                            low,
                            self_trivial,
                            table,
                        } => {
                            commit_claim(
                                space,
                                &mut delta,
                                &mut result,
                                keep_zero_pairs,
                                col,
                                low,
                                self_trivial,
                                &table,
                                value_of(col),
                                key_value(low),
                            );
                        }
                    }
                }
            }
        }
        let serial_ns = t_serial.elapsed().as_nanos() as u64;
        sched.serial_ns += serial_ns;

        // ---- Join the pipelined generation, publish delta + columns ---
        let t_wait = Instant::now();
        if let Some(t) = ticket {
            t.wait();
        }
        let wait_ns = t_wait.elapsed().as_nanos() as u64;
        if had_push {
            sched.barrier_wait_ns += wait_ns;
            let push_span = pool.stats().span_ns.saturating_sub(span0);
            sched.overlap_ns += serial_ns.min(push_span);
        } else if n_enum > 0 {
            enum_block_ns += wait_ns;
        }
        // No reader is live now: splice the enumerated shards and drain
        // the batch's commits into the base so the next serial phase
        // (and the push after it) see them.
        splice_shards(enum_slots, &mut columns, &mut shard_cursor, &mut enum_tasks);
        base.merge_from(&mut delta);

        let cur_len = cur_end - cur_start;
        sched.batches += 1;
        min_batch = min_batch.min(cur_len);
        max_batch = max_batch.max(cur_len);

        // ---- Adapt the batch size -------------------------------------
        // Serial-bound (commit > adapt_high of the generation span):
        // halve, pushing collision resolution back into the parallel
        // phase. Generation-bound (commit < adapt_low): double,
        // amortizing dispatch and widening the overlap window. The span
        // deliberately covers the WHOLE generation — push plus any
        // ride-along enumeration — because `wait_ns` is real scheduler
        // idle either way, and filling it with a larger commit is the
        // right move regardless of which region caused it; an
        // enumeration-inflated doubling self-corrects within a few
        // batches once the shards drain (frac rises past adapt_high).
        // Correctness is batch-size independent.
        if had_push && cfg.adaptive {
            let span = serial_ns + wait_ns;
            if span > 0 {
                let frac = serial_ns as f64 / span as f64;
                if frac > cfg.adapt_high {
                    batch = clamp_batch(batch / 2);
                } else if frac < cfg.adapt_low {
                    batch = clamp_batch(batch.saturating_mul(2));
                }
            }
        }

        cur_start = next_start;
        cur_end = next_end;
        cur_slots = next_slots;
    }
    debug_assert_eq!(shard_cursor, n_shards, "every shard must be enumerated");

    let pool1 = pool.stats();
    sched.tasks = pool1.tasks - pool0.tasks;
    sched.steals = pool1.steals - pool0.steals;
    sched.parallel_busy_ns = pool1.busy_ns - pool0.busy_ns;
    sched.wall_ns = wall0.elapsed().as_nanos() as u64;
    sched.min_batch = if sched.batches > 0 { min_batch } else { 0 };
    sched.max_batch = max_batch;
    sched.enum_shards = enum_tasks;
    sched.enum_columns = columns.len() as u64;
    sched.enum_busy_ns = enum_busy_ns.load(Ordering::Relaxed);
    sched.enum_block_ns = enum_block_ns;

    result.stats.columns = total.columns;
    result.stats.appends = total.appends;
    result.stats.find_next_calls = total.find_next_calls;
    result.sched = sched;
    Ok(result)
}

/// Reduce `columns` (already in reverse filtration order, clearing
/// applied by the caller) with the pipelined work-stealing scheduler.
/// Output is bit-identical to [`super::fast_column::reduce_all`].
///
/// Thin adapter over [`reduce_stream`]: the pre-materialized columns
/// stream through the same three-stage pipeline in fixed chunks (the
/// enumeration stage degenerates to cheap buffer copies).
pub fn reduce_all<S: ColumnSpace>(
    space: &S,
    columns: &[u64],
    cfg: &SchedConfig,
    pool: &ThreadPool,
    keep_zero_pairs: bool,
    value_of: impl Fn(u64) -> f64,
    key_value: impl Fn(Key) -> f64,
) -> ReduceResult {
    let src = SliceShards {
        cols: columns,
        chunk: 4096,
    };
    reduce_stream(
        space,
        &src,
        cfg,
        pool,
        keep_zero_pairs,
        &CancelToken::none(),
        value_of,
        key_value,
    )
    .expect("a none token never cancels")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::{EdgeFiltration, Neighborhoods};
    use crate::geometry::{MetricData, PointCloud};
    use crate::reduction::EdgeColumns;
    use crate::util::rng::Pcg32;

    fn fixed(batch: usize) -> SchedConfig {
        SchedConfig {
            batch_size: batch,
            adaptive: false,
            ..Default::default()
        }
    }

    fn test_space(seed: u64, n: usize, tau: f64) -> (EdgeFiltration, Neighborhoods) {
        let mut rng = Pcg32::new(seed);
        let coords = (0..n * 3).map(|_| rng.next_f64()).collect();
        let f = EdgeFiltration::build(&MetricData::Points(PointCloud::new(3, coords)), tau);
        let nb = Neighborhoods::build(&f, false);
        (f, nb)
    }

    #[test]
    fn pipelined_matches_sequential_for_all_batch_sizes() {
        for seed in 0..4 {
            let (f, nb) = test_space(seed, 24, 0.9);
            let space = EdgeColumns::new(&nb, &f);
            let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
            let seq = crate::reduction::fast_column::reduce_all(
                &space,
                cols.iter().copied(),
                true,
                |c| f.values[c as usize],
                |k| f.key_value(k),
            );
            let pool = ThreadPool::new(4);
            let mut cfgs: Vec<SchedConfig> = [1usize, 3, 10, 100, 10_000]
                .iter()
                .map(|&b| fixed(b))
                .collect();
            cfgs.push(SchedConfig {
                batch_size: 4,
                adaptive: true,
                batch_min: 2,
                batch_max: 64,
                steal_grain: 1,
                ..Default::default()
            });
            for cfg in cfgs {
                let par = reduce_all(
                    &space,
                    &cols,
                    &cfg,
                    &pool,
                    true,
                    |c| f.values[c as usize],
                    |k| f.key_value(k),
                );
                let mut a = seq.pairs.clone();
                let mut b = par.pairs.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "seed={seed} cfg={cfg:?}");
                let mut ea = seq.essential.clone();
                let mut eb = par.essential.clone();
                ea.sort_unstable();
                eb.sort_unstable();
                assert_eq!(ea, eb, "seed={seed} cfg={cfg:?}");
                assert_eq!(
                    seq.stats.trivial_pairs, par.stats.trivial_pairs,
                    "seed={seed} cfg={cfg:?}"
                );
                // Every pair/trivial column is either committed straight
                // off its pre-push or serially resumed; columns that end
                // zero may appear in either bucket or in neither.
                let handled = par.sched.prepushed_columns + par.sched.resumed_columns;
                assert!(
                    handled >= seq.stats.pairs + seq.stats.trivial_pairs
                        && handled <= cols.len(),
                    "seed={seed} cfg={cfg:?}: handled={handled}"
                );
                assert_eq!(par.sched.enum_columns as usize, cols.len());
            }
        }
    }

    #[test]
    fn sharded_stream_matches_slice_for_all_geometries() {
        // The same column sequence served through different shard
        // geometries (including shards far smaller than a batch, and one
        // giant shard) must give identical output and consume every
        // column exactly once.
        let (f, nb) = test_space(11, 30, 0.8);
        let space = EdgeColumns::new(&nb, &f);
        let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
        let seq = crate::reduction::fast_column::reduce_all(
            &space,
            cols.iter().copied(),
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        let pool = ThreadPool::new(4);
        for chunk in [1usize, 3, 17, 100, usize::MAX / 2] {
            for batch in [1usize, 7, 100] {
                let src = SliceShards {
                    cols: &cols,
                    chunk,
                };
                let r = reduce_stream(
                    &space,
                    &src,
                    &fixed(batch),
                    &pool,
                    true,
                    &CancelToken::none(),
                    |c| f.values[c as usize],
                    |k| f.key_value(k),
                )
                .unwrap();
                let mut a = seq.pairs.clone();
                let mut b = r.pairs.clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "chunk={chunk} batch={batch}");
                assert_eq!(r.stats.columns, cols.len(), "chunk={chunk} batch={batch}");
                assert_eq!(
                    r.sched.enum_shards as usize,
                    src.n_shards(),
                    "chunk={chunk} batch={batch}"
                );
                assert_eq!(
                    r.sched.enum_columns as usize,
                    cols.len(),
                    "chunk={chunk} batch={batch}"
                );
            }
        }
    }

    #[test]
    fn adapt_high_zero_shrinks_batch_to_min() {
        // Synthetic serial-bound workload: adapt_high = 0 classifies
        // every batch as serial-bound (any nonzero commit time exceeds
        // the bound), so the adaptation must walk the batch down to
        // batch_min — with output still exact.
        let (f, nb) = test_space(5, 40, 0.7);
        let space = EdgeColumns::new(&nb, &f);
        let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
        assert!(cols.len() > 200, "need enough columns for several batches");
        let pool = ThreadPool::new(2);
        let cfg = SchedConfig {
            batch_size: 64,
            adaptive: true,
            batch_min: 2,
            batch_max: 64,
            steal_grain: 0,
            adapt_low: 0.0,
            adapt_high: 0.0,
        };
        let r = reduce_all(
            &space,
            &cols,
            &cfg,
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        // Halving fires whenever a batch's commit registers any nonzero
        // time; require a real shrink but not that *every* batch halved,
        // so a coarse monotonic clock (commit rounding to 0ns) cannot
        // flake the test. On ns-resolution clocks this reaches batch_min.
        // (No lower-bound assert: min_batch records actual batch
        // lengths, and the final partial batch may be smaller than
        // batch_min when the column count doesn't divide evenly.)
        assert!(
            r.sched.min_batch < 64,
            "batch must shrink under a serial-bound classification, got min {}",
            r.sched.min_batch
        );
        let seq = crate::reduction::fast_column::reduce_all(
            &space,
            cols.iter().copied(),
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        let mut a = seq.pairs.clone();
        let mut b = r.pairs.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "shrinking trajectory must not change the output");
    }

    #[test]
    fn adapt_low_one_grows_batch() {
        // With adapt_low = adapt_high = 1.0 every batch whose commit
        // finished before the push (serial fraction < 1) is push-bound,
        // so the batch size must grow from its floor.
        let (f, nb) = test_space(6, 40, 0.7);
        let space = EdgeColumns::new(&nb, &f);
        let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
        let pool = ThreadPool::new(2);
        let cfg = SchedConfig {
            batch_size: 2,
            adaptive: true,
            batch_min: 2,
            batch_max: 128,
            steal_grain: 0,
            adapt_low: 1.0,
            adapt_high: 1.0,
        };
        let r = reduce_all(
            &space,
            &cols,
            &cfg,
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        // Growth requires at least one batch whose barrier wait measured
        // nonzero (frac < 1 strictly); on a pathologically coarse clock
        // every wait can round to 0 and no doubling fires, so only
        // require growth when some wait was actually observed.
        assert!(
            r.sched.max_batch > 2 || r.sched.barrier_wait_ns == 0,
            "batch must grow under a push-bound classification, got max {} with {}ns barrier wait",
            r.sched.max_batch,
            r.sched.barrier_wait_ns
        );
    }

    #[test]
    fn shard_plan_tiles_descending() {
        for (n, threads, shards, grain) in [
            (0usize, 4usize, 0usize, 0usize),
            (1, 1, 0, 0),
            (100, 4, 0, 0),
            (100, 4, 7, 0),
            (100, 4, 0, 9),
            (100, 4, 3, 9), // grain wins over shards
            (5, 8, 100, 0), // more shards requested than items
            (1_000_000, 8, 0, 0),
        ] {
            let plan = shard_plan(n, threads, shards, grain);
            // Tiles [0, n) exactly, descending, no gaps or overlaps.
            let mut hi = n as u32;
            for r in &plan {
                assert_eq!(r.end, hi, "n={n} shards={shards} grain={grain}");
                assert!(r.start < r.end);
                hi = r.start;
            }
            assert_eq!(hi, 0, "n={n}: plan must reach order 0");
            if grain > 0 {
                assert!(plan.iter().all(|r| (r.end - r.start) as usize <= grain));
            } else if shards > 0 && n > 0 {
                assert!(plan.len() <= shards.max(1));
            }
        }
        assert!(shard_plan(0, 4, 3, 2).is_empty());
    }

    #[test]
    fn expired_token_aborts_typed_and_pool_stays_usable() {
        let (f, nb) = test_space(13, 30, 0.8);
        let space = EdgeColumns::new(&nb, &f);
        let cols: Vec<u64> = (0..f.n_edges() as u64).rev().collect();
        let pool = ThreadPool::new(2);
        let src = SliceShards {
            cols: &cols,
            chunk: 64,
        };
        let r = reduce_stream(
            &space,
            &src,
            &fixed(16),
            &pool,
            true,
            &CancelToken::with_timeout_ms(0),
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        assert!(matches!(
            r,
            Err(crate::error::DoryError::DeadlineExceeded(_))
        ));
        // The abort left no generation in flight: the same pool serves a
        // full run whose output matches the sequential oracle.
        let seq = crate::reduction::fast_column::reduce_all(
            &space,
            cols.iter().copied(),
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        let full = reduce_all(
            &space,
            &cols,
            &fixed(16),
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        let mut a = seq.pairs.clone();
        let mut b = full.pairs.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "pool must reduce exactly after a cancelled run");
    }

    #[test]
    fn empty_column_set() {
        let (f, nb) = test_space(9, 12, 0.5);
        let space = EdgeColumns::new(&nb, &f);
        let pool = ThreadPool::new(2);
        let r = reduce_all(
            &space,
            &[],
            &SchedConfig::default(),
            &pool,
            true,
            |c| f.values[c as usize],
            |k| f.key_value(k),
        );
        assert_eq!(r.stats.columns, 0);
        assert!(r.pairs.is_empty() && r.essential.is_empty());
        assert_eq!(r.sched.batches, 0);
        assert_eq!(r.sched.enum_shards, 0);
    }
}
