//! Phase timing for Table-2-style breakdowns and bench statistics.

use std::time::{Duration, Instant};

/// Records named phases in order; renders the paper's Table-2 row format.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// End any running phase and start a new one.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// End the running phase (no-op when idle).
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.phases.push((name, t0.elapsed()));
        }
    }

    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.phases
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    /// "F1 1.14s | nbhd 0.49s | H0 0.14s" style summary.
    pub fn summary(&self) -> String {
        self.phases
            .iter()
            .map(|(n, d)| format!("{n} {:.3}s", d.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// Basic statistics over repeated timings (our stand-in for criterion).
#[derive(Debug, Clone, Copy)]
pub struct TimingStats {
    pub n: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

/// Run `f` `reps` times, returning per-rep stats. `reps >= 1`.
pub fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> TimingStats {
    assert!(reps >= 1);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_of(&samples)
}

pub fn stats_of(samples: &[f64]) -> TimingStats {
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    TimingStats {
        n,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_in_order() {
        let mut t = PhaseTimer::new();
        t.start("a");
        std::thread::sleep(Duration::from_millis(2));
        t.start("b");
        std::thread::sleep(Duration::from_millis(2));
        t.stop();
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].0, "a");
        assert!(t.get("b").unwrap() >= Duration::from_millis(1));
        assert!(t.total() >= Duration::from_millis(3));
    }

    #[test]
    fn stats_sane() {
        let s = stats_of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.min_s - 1.0).abs() < 1e-12);
        assert!((s.stddev_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn time_reps_runs() {
        let mut k = 0u64;
        let s = time_reps(3, || k += 1);
        assert_eq!(s.n, 3);
        assert_eq!(k, 3);
    }
}
