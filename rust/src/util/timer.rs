//! Phase timing for Table-2-style breakdowns and bench statistics.
//!
//! Each phase boundary also samples the OS max-RSS high-water mark
//! (`util::memtrack::max_rss_bytes`), so per-phase peak-memory growth —
//! Dory's headline memory claim — is measured, not estimated.

use std::time::{Duration, Instant};

/// One completed phase: wall time plus the process max-RSS high-water
/// mark sampled at the instant the phase ended. Clamped monotone across
/// the timer's phases (Linux `VmHWM` is monotone already; the portable
/// `ps` fallback reports *current* RSS, which can dip), so the delta
/// between consecutive phases localizes where the peak grew; 0 when the
/// platform exposes no RSS source.
#[derive(Debug, Clone)]
pub struct PhaseRecord {
    pub name: String,
    pub duration: Duration,
    /// `util::memtrack::max_rss_bytes()` at the phase boundary.
    pub max_rss_end: usize,
}

/// Records named phases in order; renders the paper's Table-2 row format.
/// Cloning snapshots the completed phases (a session clones its ingest
/// timings into every response served from the same handle).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<PhaseRecord>,
    current: Option<(String, Instant)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// End any running phase and start a new one.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// End the running phase (no-op when idle), sampling max-RSS at the
    /// boundary (clamped to the previous phase's mark so the series
    /// stays monotone even on platforms whose fallback reports current
    /// RSS).
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            let prev = self.phases.last().map(|p| p.max_rss_end).unwrap_or(0);
            self.phases.push(PhaseRecord {
                name,
                duration: t0.elapsed(),
                max_rss_end: crate::util::memtrack::max_rss_bytes().max(prev),
            });
        }
    }

    /// Append an externally measured record — used for sub-phase
    /// breakdowns like `"F1/dist"`. Names containing `'/'` are treated
    /// as sub-phases of the segment before the slash and excluded from
    /// [`Self::total`], so a parent phase is never double-counted.
    pub fn record(&mut self, name: &str, duration: Duration) {
        let prev = self.phases.last().map(|p| p.max_rss_end).unwrap_or(0);
        self.phases.push(PhaseRecord {
            name: name.to_string(),
            duration,
            max_rss_end: crate::util::memtrack::max_rss_bytes().max(prev),
        });
    }

    pub fn phases(&self) -> &[PhaseRecord] {
        &self.phases
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.phases
            .iter()
            .rev()
            .find(|p| p.name == name)
            .map(|p| p.duration)
    }

    /// Max-RSS high-water mark at the end of the named phase.
    pub fn get_rss(&self, name: &str) -> Option<usize> {
        self.phases
            .iter()
            .rev()
            .find(|p| p.name == name)
            .map(|p| p.max_rss_end)
    }

    pub fn total(&self) -> Duration {
        self.phases
            .iter()
            .filter(|p| !p.name.contains('/'))
            .map(|p| p.duration)
            .sum()
    }

    /// "F1 1.14s | nbhd 0.49s | H0 0.14s" style summary.
    pub fn summary(&self) -> String {
        self.phases
            .iter()
            .map(|p| format!("{} {:.3}s", p.name, p.duration.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(" | ")
    }

    /// "F1 41.2 MB | H1* 63.0 MB" style per-phase max-RSS summary
    /// (empty when the platform reports no RSS).
    pub fn rss_summary(&self) -> String {
        if self.phases.iter().all(|p| p.max_rss_end == 0) {
            return String::new();
        }
        self.phases
            .iter()
            .map(|p| {
                format!(
                    "{} {}",
                    p.name,
                    crate::util::memtrack::fmt_bytes(p.max_rss_end)
                )
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// Basic statistics over repeated timings (our stand-in for criterion).
#[derive(Debug, Clone, Copy)]
pub struct TimingStats {
    pub n: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

/// Run `f` `reps` times, returning per-rep stats. `reps >= 1`.
pub fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> TimingStats {
    assert!(reps >= 1);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_of(&samples)
}

pub fn stats_of(samples: &[f64]) -> TimingStats {
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    TimingStats {
        n,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_in_order() {
        let mut t = PhaseTimer::new();
        t.start("a");
        std::thread::sleep(Duration::from_millis(2));
        t.start("b");
        std::thread::sleep(Duration::from_millis(2));
        t.stop();
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].name, "a");
        assert!(t.get("b").unwrap() >= Duration::from_millis(1));
        assert!(t.total() >= Duration::from_millis(3));
    }

    #[test]
    fn rss_sampled_at_phase_boundaries() {
        let mut t = PhaseTimer::new();
        t.start("x");
        t.start("y");
        t.stop();
        // Monotone high-water mark (both 0 when the platform has none).
        let rx = t.get_rss("x").unwrap();
        let ry = t.get_rss("y").unwrap();
        assert!(ry >= rx);
        assert_eq!(t.phases()[1].max_rss_end, ry);
        if rx > 0 {
            assert!(!t.rss_summary().is_empty());
        }
        assert_eq!(t.get_rss("nope"), None);
    }

    #[test]
    fn recorded_subphases_excluded_from_total() {
        let mut t = PhaseTimer::new();
        t.start("F1");
        std::thread::sleep(Duration::from_millis(2));
        t.stop();
        let f1 = t.get("F1").unwrap();
        t.record("F1/dist", Duration::from_millis(500));
        t.record("F1/sort", Duration::from_millis(500));
        assert_eq!(t.get("F1/dist"), Some(Duration::from_millis(500)));
        assert_eq!(t.phases().len(), 3);
        // Sub-phases show in the summary but never in the total.
        assert!(t.summary().contains("F1/dist"));
        assert_eq!(t.total(), f1);
    }

    #[test]
    fn stats_sane() {
        let s = stats_of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.min_s - 1.0).abs() < 1e-12);
        assert!((s.stddev_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn time_reps_runs() {
        let mut k = 0u64;
        let s = time_reps(3, || k += 1);
        assert_eq!(s.n, 3);
        assert_eq!(k, 3);
    }
}
