//! Deterministic fault injection for resilience testing.
//!
//! A *failpoint* is a named site in production code (`spill-write`,
//! `merge-open`, `serve-write`, …) where a fault can be injected on
//! demand. Disarmed — the normal state — a hit costs one relaxed atomic
//! load and nothing else; no registry lookup, no allocation. Armed, the
//! site's trigger spec decides per hit whether to fire:
//!
//! * `nth(N)`  — fire exactly on the Nth hit (1-based), never again;
//! * `first(N)`— fire on hits 1..=N, then stop (retry-then-succeed);
//! * `every(K)`— fire on every Kth hit;
//! * `always`  — fire on every hit;
//! * `off`     — never fire (counts hits only).
//!
//! Arming happens through the test API ([`arm`]/[`clear`]) or, for whole
//! processes under test (CI smokes), the `DORY_FAILPOINTS` environment
//! variable: a `;`-separated list of `name=spec` entries, e.g.
//! `DORY_FAILPOINTS="spill-write=nth(2);serve-query-panic=first(1)"`,
//! parsed once on first hit. Injected faults surface as
//! `std::io::Error` of kind `Other` whose message names the failpoint,
//! so retry layers treat them exactly like real transient I/O errors.
//!
//! The registry is process-global: tests that arm failpoints must
//! serialize behind a lock and [`clear`] on exit (see
//! `rust/tests/faults.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Spill-run file creation/write/flush in `SpillStore::spill_run`.
pub const SPILL_WRITE: &str = "spill-write";
/// Per-key reads inside `RunReader::next` during the k-way merge.
pub const SPILL_READ: &str = "spill-read";
/// Re-opening spilled runs in `SpillStore::finish`.
pub const MERGE_OPEN: &str = "merge-open";
/// Line reads in the streaming COO reader.
pub const STREAM_READ: &str = "stream-read";
/// Response writes in the `dory serve` output loop.
pub const SERVE_WRITE: &str = "serve-write";
/// Synthetic worker panic inside the single-query serve path.
pub const SERVE_QUERY_PANIC: &str = "serve-query-panic";

/// When a named failpoint should fire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trigger {
    /// Fire exactly on the `n`th hit (1-based).
    Nth(u64),
    /// Fire on hits `1..=n`, then never again.
    First(u64),
    /// Fire on every `k`th hit (`k >= 1`).
    Every(u64),
    /// Fire on every hit.
    Always,
    /// Never fire; hits are still counted.
    Off,
}

impl Trigger {
    /// Parse a spec string: `nth(3)`, `first(2)`, `every(5)`, `always`,
    /// `off`.
    pub fn parse(spec: &str) -> Option<Trigger> {
        let s = spec.trim();
        match s {
            "always" => return Some(Trigger::Always),
            "off" => return Some(Trigger::Off),
            _ => {}
        }
        let (head, rest) = s.split_once('(')?;
        let arg: u64 = rest.strip_suffix(')')?.trim().parse().ok()?;
        match head.trim() {
            "nth" if arg >= 1 => Some(Trigger::Nth(arg)),
            "first" => Some(Trigger::First(arg)),
            "every" if arg >= 1 => Some(Trigger::Every(arg)),
            _ => None,
        }
    }

    fn fires(&self, hit: u64) -> bool {
        match *self {
            Trigger::Nth(n) => hit == n,
            Trigger::First(n) => hit <= n,
            Trigger::Every(k) => hit % k == 0,
            Trigger::Always => true,
            Trigger::Off => false,
        }
    }
}

struct Point {
    trigger: Trigger,
    hits: AtomicU64,
    fired: AtomicU64,
}

/// Fast path: a single relaxed load decides "nothing is armed" without
/// touching the registry mutex.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Mutex<HashMap<String, Point>>> = OnceLock::new();
/// Whether `DORY_FAILPOINTS` has been consumed yet.
static ENV_LOADED: OnceLock<()> = OnceLock::new();

fn registry() -> &'static Mutex<HashMap<String, Point>> {
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn load_env_once() {
    ENV_LOADED.get_or_init(|| {
        if let Ok(spec) = std::env::var("DORY_FAILPOINTS") {
            arm_from_spec(&spec);
        }
    });
}

/// Arm failpoints from a `name=spec;name=spec` string (the
/// `DORY_FAILPOINTS` format). Malformed entries are ignored — fault
/// injection must never take down a production process on its own.
pub fn arm_from_spec(spec: &str) {
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        if let Some((name, trig)) = entry.split_once('=') {
            if let Some(t) = Trigger::parse(trig) {
                arm(name.trim(), t);
            }
        }
    }
}

/// Arm one failpoint. Resets its hit counter.
pub fn arm(name: &str, trigger: Trigger) {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.insert(
        name.to_string(),
        Point {
            trigger,
            hits: AtomicU64::new(0),
            fired: AtomicU64::new(0),
        },
    );
    ARMED.store(true, Ordering::Release);
}

/// Disarm every failpoint and restore the zero-cost fast path.
pub fn clear() {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.clear();
    ARMED.store(false, Ordering::Release);
}

/// How many times `name` fired (not merely hit) since it was armed.
pub fn fired_count(name: &str) -> u64 {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.get(name).map_or(0, |p| p.fired.load(Ordering::Relaxed))
}

/// Record a hit at failpoint `name`; returns `true` when the armed
/// trigger says this hit must fail. Disarmed cost: one relaxed load.
#[inline]
pub fn should_fail(name: &str) -> bool {
    load_env_once();
    if !ARMED.load(Ordering::Acquire) {
        return false;
    }
    should_fail_slow(name)
}

#[cold]
fn should_fail_slow(name: &str) -> bool {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    match reg.get(name) {
        Some(p) => {
            let hit = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
            let fire = p.trigger.fires(hit);
            if fire {
                p.fired.fetch_add(1, Ordering::Relaxed);
            }
            fire
        }
        None => false,
    }
}

/// Check failpoint `name`, surfacing a fire as an injected
/// `std::io::Error` (kind `Other`). Production call sites gate their
/// real I/O on this: `failpoint::check(SPILL_WRITE)?;`.
#[inline]
pub fn check(name: &str) -> std::io::Result<()> {
    if should_fail(name) {
        Err(injected(name))
    } else {
        Ok(())
    }
}

/// The error an armed failpoint injects. Message format is stable —
/// [`is_injected`] and the retry layer key off the prefix.
pub fn injected(name: &str) -> std::io::Error {
    std::io::Error::other(format!("failpoint injected fault at `{name}`"))
}

/// Whether `e` was manufactured by a failpoint (as opposed to a real
/// I/O failure). Read retries use this: an injected fault happens
/// *before* any bytes move, so the stream position is intact and the
/// operation is safe to re-issue; a real partial read is not.
pub fn is_injected(e: &std::io::Error) -> bool {
    e.to_string().starts_with("failpoint injected fault at ")
}

/// Process-wide serialization for tests that arm failpoints: the
/// registry is global, so concurrently armed tests would trip each
/// other's triggers. Hold the guard for the test's duration and
/// [`clear`] before releasing it. Poison-recovering — one panicking
/// test must not brick the rest of the suite.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// Bounded retry with backoff.
// ---------------------------------------------------------------------

/// Retry policy for transient spill/serve I/O: `attempts` total tries
/// with a doubling sleep starting at `base_delay` between them.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub attempts: u32,
    pub base_delay: std::time::Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 3,
            // Short enough that tests retrying through `first(2)` specs
            // finish instantly; the doubling matters under real EIO.
            base_delay: std::time::Duration::from_millis(2),
        }
    }
}

impl RetryPolicy {
    /// Run `op` up to `attempts` times. `cleanup` runs between a failed
    /// attempt and its retry (e.g. remove a partially written spill
    /// file so the rewrite starts clean). Each retry is counted into
    /// `retries`. The final error is returned unchanged.
    pub fn run<T>(
        &self,
        retries: &AtomicU64,
        mut op: impl FnMut() -> std::io::Result<T>,
        mut cleanup: impl FnMut(),
    ) -> std::io::Result<T> {
        let mut delay = self.base_delay;
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..self.attempts.max(1) {
            if attempt > 0 {
                cleanup();
                retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one attempt ran"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and tests run threaded: every test
    // that arms a point takes the crate-wide lock and clears on both
    // ends (shared with the io::stream fault tests in this binary).
    fn locked() -> std::sync::MutexGuard<'static, ()> {
        let g = test_lock();
        clear();
        g
    }

    #[test]
    fn trigger_specs_parse() {
        assert_eq!(Trigger::parse("nth(3)"), Some(Trigger::Nth(3)));
        assert_eq!(Trigger::parse(" first(2) "), Some(Trigger::First(2)));
        assert_eq!(Trigger::parse("every(5)"), Some(Trigger::Every(5)));
        assert_eq!(Trigger::parse("always"), Some(Trigger::Always));
        assert_eq!(Trigger::parse("off"), Some(Trigger::Off));
        assert!(Trigger::parse("nth(0)").is_none());
        assert!(Trigger::parse("every(0)").is_none());
        assert!(Trigger::parse("sometimes").is_none());
        assert!(Trigger::parse("nth(x)").is_none());
    }

    #[test]
    fn disarmed_points_never_fire() {
        let _g = locked();
        for _ in 0..100 {
            assert!(!should_fail("unarmed-point"));
        }
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = locked();
        arm("t-nth", Trigger::Nth(3));
        let fires: Vec<bool> = (0..6).map(|_| should_fail("t-nth")).collect();
        assert_eq!(fires, vec![false, false, true, false, false, false]);
        assert_eq!(fired_count("t-nth"), 1);
        clear();
    }

    #[test]
    fn first_fires_then_recovers() {
        let _g = locked();
        arm("t-first", Trigger::First(2));
        let fires: Vec<bool> = (0..4).map(|_| should_fail("t-first")).collect();
        assert_eq!(fires, vec![true, true, false, false]);
        clear();
    }

    #[test]
    fn every_k_cadence() {
        let _g = locked();
        arm("t-every", Trigger::Every(2));
        let fires: Vec<bool> = (0..6).map(|_| should_fail("t-every")).collect();
        assert_eq!(fires, vec![false, true, false, true, false, true]);
        clear();
    }

    #[test]
    fn spec_string_arms_multiple_points() {
        let _g = locked();
        arm_from_spec("a=nth(1); b = every(2) ;; junk; c=bogus(9)");
        assert!(should_fail("a"));
        assert!(!should_fail("a"));
        assert!(!should_fail("b"));
        assert!(should_fail("b"));
        assert!(!should_fail("c"));
        clear();
    }

    #[test]
    fn injected_errors_are_recognizable() {
        let e = injected("spill-write");
        assert!(is_injected(&e));
        assert!(e.to_string().contains("spill-write"));
        let real = std::io::Error::other("disk on fire");
        assert!(!is_injected(&real));
    }

    #[test]
    fn retry_recovers_after_transient_failures() {
        let _g = locked();
        let retries = AtomicU64::new(0);
        let mut left = 2;
        let out = RetryPolicy::default().run(
            &retries,
            || {
                if left > 0 {
                    left -= 1;
                    Err(std::io::Error::other("transient"))
                } else {
                    Ok(42)
                }
            },
            || {},
        );
        assert_eq!(out.unwrap(), 42);
        assert_eq!(retries.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn retry_exhaustion_returns_last_error() {
        let _g = locked();
        let retries = AtomicU64::new(0);
        let mut cleanups = 0;
        let out: std::io::Result<()> = RetryPolicy::default().run(
            &retries,
            || Err(std::io::Error::other("hard down")),
            || cleanups += 1,
        );
        assert!(out.unwrap_err().to_string().contains("hard down"));
        assert_eq!(retries.load(Ordering::Relaxed), 2);
        assert_eq!(cleanups, 2);
    }
}
