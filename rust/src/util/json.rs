//! Minimal JSON writer + parser (no serde in the offline vendor set).
//!
//! The writer covers what the bench/report paths need: objects, arrays,
//! strings, numbers, bools. Escapes per RFC 8259. The parser covers the
//! serve layer's line-delimited wire requests (full RFC 8259 value
//! grammar, including `\uXXXX` escapes with surrogate pairs), plus the
//! writer's own `±1e999` infinity convention (f64 parsing maps it to
//! ±∞ naturally).

use std::fmt::Write as _;

/// A JSON value builder with owned rendering.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    pub fn field(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("field() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) {
        if let Json::Arr(ref mut items) = self {
            items.push(val.into());
        } else {
            panic!("push() on non-array Json");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse one JSON value from `src` (the whole string must be the
    /// value, modulo surrounding whitespace). Errors are positioned
    /// human-readable strings — the serve layer wraps them in
    /// `DoryError::Request`.
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    /// Object member lookup (None on non-objects / absent keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric view: `Num`, `Int`, or a `Null` from a serialized NaN.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Int(i) if *i >= 0 => Some(*i as usize),
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else if x.is_nan() {
                    out.push_str("null");
                } else if *x > 0.0 {
                    out.push_str("1e999"); // +inf: parses as Infinity in most readers
                } else {
                    out.push_str("-1e999");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at offset {}",
                c as char, self.i
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected byte '{}' at offset {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            fields.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u16::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape '{s}'"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: a second \uXXXX must follow.
                                if self.peek() != Some(b'\\') {
                                    return Err("lone high surrogate".into());
                                }
                                self.i += 1;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".into());
                                }
                                0x10000 + (((hi as u32 - 0xD800) << 10) | (lo as u32 - 0xDC00))
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err("lone low surrogate".into());
                            } else {
                                hi as u32
                            };
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        _ => return Err(format!("bad escape '\\{}'", e as char)),
                    }
                }
                _ => {
                    // Copy the raw UTF-8 byte run up to the next quote
                    // or backslash (the input is a &str, so it's valid).
                    let start = self.i - 1;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            // `1e999` overflows to +inf, matching the writer's encoding.
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number '{s}'"))
        } else {
            s.parse::<i64>().map(Json::Int).or_else(|_| {
                s.parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| format!("bad number '{s}'"))
            })
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        // Counters in practice; saturate rather than wrap if ever huge.
        Json::Int(i64::try_from(x).unwrap_or(i64::MAX))
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push(2.5f64);
        let j = Json::obj()
            .field("name", "dory")
            .field("ok", true)
            .field("xs", arr);
        assert_eq!(j.render(), r#"{"name":"dory","ok":true,"xs":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::obj().field("s", "a\"b\\c\nd");
        assert_eq!(j.render(), r#"{"s":"a\"b\\c\nd"}"#);
    }

    #[test]
    fn infinity_encodes() {
        let j = Json::Num(f64::INFINITY);
        assert_eq!(j.render(), "1e999");
    }

    #[test]
    fn parses_what_it_writes() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push(2.5f64);
        arr.push(Json::Null);
        let j = Json::obj()
            .field("name", "dory \"v2\"\nline")
            .field("ok", true)
            .field("inf", f64::INFINITY)
            .field("neg", -3i64)
            .field("xs", arr);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("name").unwrap().as_str(), Some("dory \"v2\"\nline"));
        assert_eq!(back.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("inf").unwrap().as_f64(), Some(f64::INFINITY));
        assert_eq!(back.get("neg").unwrap().as_f64(), Some(-3.0));
        let xs = back.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[0].as_usize(), Some(1));
        assert_eq!(xs[1].as_f64(), Some(2.5));
        assert!(matches!(xs[2], Json::Null));
    }

    #[test]
    fn parses_escapes_and_whitespace() {
        let v = Json::parse(" { \"k\" : [ \"a\\u00e9\\u20ac\", \"\\ud83d\\ude00\", -1.5e2 ] } ")
            .unwrap();
        let xs = v.get("k").unwrap().as_arr().unwrap();
        assert_eq!(xs[0].as_str(), Some("aé€"));
        assert_eq!(xs[1].as_str(), Some("😀"));
        assert_eq!(xs[2].as_f64(), Some(-150.0));
    }

    #[test]
    fn parse_errors_are_typed_strings() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"\\ud800\"").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("tru").is_err());
    }
}
