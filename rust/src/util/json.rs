//! Minimal JSON writer (no serde in the offline vendor set).
//!
//! Only what the bench/report paths need: objects, arrays, strings,
//! numbers, bools. Escapes per RFC 8259.

use std::fmt::Write as _;

/// A JSON value builder with owned rendering.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    pub fn field(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("field() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) {
        if let Json::Arr(ref mut items) = self {
            items.push(val.into());
        } else {
            panic!("push() on non-array Json");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else if x.is_nan() {
                    out.push_str("null");
                } else if *x > 0.0 {
                    out.push_str("1e999"); // +inf: parses as Infinity in most readers
                } else {
                    out.push_str("-1e999");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        // Counters in practice; saturate rather than wrap if ever huge.
        Json::Int(i64::try_from(x).unwrap_or(i64::MAX))
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push(2.5f64);
        let j = Json::obj()
            .field("name", "dory")
            .field("ok", true)
            .field("xs", arr);
        assert_eq!(j.render(), r#"{"name":"dory","ok":true,"xs":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::obj().field("s", "a\"b\\c\nd");
        assert_eq!(j.render(), r#"{"s":"a\"b\\c\nd"}"#);
    }

    #[test]
    fn infinity_encodes() {
        let j = Json::Num(f64::INFINITY);
        assert_eq!(j.render(), "1e999");
    }
}
