//! Memory accounting: a counting global allocator plus OS max-RSS.
//!
//! The paper reports peak memory per run (macOS Instruments). We reproduce
//! that with (a) an allocator wrapper counting live and peak heap bytes —
//! resettable per benchmark section — and (b) OS max-RSS as a sanity bound.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static BASELINE: AtomicUsize = AtomicUsize::new(0);

/// Counting allocator. Install with:
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
/// (done in `lib.rs`; benches and the binary inherit it).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let d = new_size - layout.size();
                let live = LIVE.fetch_add(d, Ordering::Relaxed) + d;
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Live heap bytes right now.
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Peak heap bytes since last `reset_peak`.
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the peak to the current live value and remember the baseline;
/// `section_peak_bytes` then reports peak-above-baseline for the section.
pub fn reset_peak() {
    let live = LIVE.load(Ordering::Relaxed);
    PEAK.store(live, Ordering::Relaxed);
    BASELINE.store(live, Ordering::Relaxed);
}

/// Peak allocated above the baseline captured by the last `reset_peak`.
pub fn section_peak_bytes() -> usize {
    peak_bytes().saturating_sub(BASELINE.load(Ordering::Relaxed))
}

/// OS-reported peak resident set size in bytes, without libc: on Linux
/// parsed from `/proc/self/status` `VmHWM` (KiB — the same number
/// `getrusage` reports); elsewhere approximated by the *current* RSS
/// from `ps` (KiB on macOS/BSD), which under-reports a passed peak.
/// Returns 0 when neither source is available.
pub fn max_rss_bytes() -> usize {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                // Fall through to `ps` on an unparsable value rather
                // than reporting a bogus 0.
                if let Ok(kb) = rest.trim().trim_end_matches("kB").trim().parse::<usize>() {
                    return kb * 1024;
                }
                break;
            }
        }
    }
    // Portable fallback (macOS/BSD): POSIX `ps` reports current RSS in KiB.
    let out = std::process::Command::new("ps")
        .args(["-o", "rss=", "-p"])
        .arg(std::process::id().to_string())
        .output();
    if let Ok(out) = out {
        if let Ok(s) = String::from_utf8(out.stdout) {
            if let Ok(kb) = s.trim().parse::<usize>() {
                return kb * 1024;
            }
        }
    }
    0
}

/// Human formatting used by the bench tables ("6.23 GB", "328 MB").
pub fn fmt_bytes(b: usize) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_tracks_alloc() {
        reset_peak();
        let before = section_peak_bytes();
        let v: Vec<u8> = Vec::with_capacity(8 * 1024 * 1024);
        let after = section_peak_bytes();
        assert!(after >= before + 8 * 1024 * 1024, "{before} -> {after}");
        drop(v);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.0 MB"));
        assert!(fmt_bytes(2 * 1024 * 1024 * 1024).starts_with("2.00 GB"));
    }

    #[test]
    #[cfg(any(target_os = "linux", target_os = "macos"))]
    fn rss_nonzero() {
        assert!(max_rss_bytes() > 0);
    }
}
