//! Fast non-cryptographic hasher for the reduction hash maps.
//!
//! The reduction state hashes nothing but `u64` keys (packed paired
//! indices, column ids). SipHash showed up at ~8% of the Hi-C profile
//! (EXPERIMENTS §Perf); this Fibonacci-multiply hasher is a few cycles.
//! Not DoS-resistant — keys are internal, never attacker-controlled.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (rare: only non-u64 keys would hit this).
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        let mut h = self.state ^ x;
        h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 32;
        self.state = h;
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.write_u64(x as u64);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }
}

pub type BuildFx = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, BuildFx>;

pub fn fx_map<K, V>() -> FxHashMap<K, V> {
    FxHashMap::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u64> = fx_map();
        for i in 0..10_000u64 {
            m.insert(i.wrapping_mul(0x1234_5678_9abc_def1), i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u64 {
            assert_eq!(m[&i.wrapping_mul(0x1234_5678_9abc_def1)], i);
        }
    }

    #[test]
    fn avalanche_smoke() {
        // Nearby keys should not collide in the low bits hashbrown uses.
        use std::hash::BuildHasher;
        let b = BuildFx::default();
        let mut low7 = std::collections::HashSet::new();
        for k in 0..128u64 {
            low7.insert(b.hash_one(k) >> 57);
        }
        assert!(low7.len() > 48, "top bits too clustered: {}", low7.len());
    }
}
