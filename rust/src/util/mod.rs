//! Infrastructure: PRNG, JSON writer, memory accounting, timers.
//!
//! The offline build has no serde/criterion/rand, so these are small
//! self-contained replacements tailored to what the benches and the
//! coordinator need.

pub mod failpoint;
pub mod fxhash;
pub mod json;
pub mod memtrack;
pub mod rng;
pub mod timer;
