//! Small, deterministic PRNGs for dataset generation and property tests.
//!
//! The offline vendor set has no `rand` crate, so we ship a SplitMix64
//! seeder and a PCG32 core generator (O'Neill 2014). Determinism matters:
//! every benchmark and property test is reproducible from a printed seed.

/// SplitMix64 — used to expand a user seed into PCG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): fast, statistically solid, 8 bytes of state.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let init_state = sm.next_u64();
        let init_seq = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (init_seq << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            if u > 1e-12 {
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Log-normal with given mu/sigma of the underlying normal.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k << n assumed; rejection).
    pub fn sample_distinct(&mut self, n: u32, k: usize) -> Vec<u32> {
        assert!((k as u64) <= n as u64);
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.gen_range(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg32::new(9);
        let mut hit = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            hit[x as usize] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Pcg32::new(17);
        let s = r.sample_distinct(1000, 100);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 100);
    }
}
