//! Byte-budgeted LRU cache of [`FiltrationHandle`]s.
//!
//! The serving layer keys handles by a content fingerprint of the
//! ingested dataset (+ its τ), so two tenants posting the same dataset
//! share one ingest. Eviction is strict LRU over a monotone use tick —
//! no wall-clock, no ties — which makes the eviction order a pure
//! function of the request sequence and therefore testable bit-for-bit.
//!
//! Handles are held behind `Arc`: eviction never invalidates a query
//! in flight, it only stops *new* lookups from finding the handle.

use std::sync::Arc;

use crate::homology::FiltrationHandle;

/// One cached ingest.
struct Entry {
    key: String,
    /// Payload size charged against the budget (edge set + CSR bytes).
    bytes: usize,
    /// Monotone use tick; larger = more recently used.
    last_used: u64,
    handle: Arc<FiltrationHandle>,
}

/// Lifetime counters of the cache, reported in the serve summary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Bytes currently charged.
    pub bytes: usize,
    /// High-water mark of `bytes`.
    pub peak_bytes: usize,
}

/// Strict-LRU handle cache with a byte budget.
///
/// Not internally synchronized — the server wraps it in a `Mutex`;
/// queries clone the `Arc` out under the lock and reduce outside it.
pub struct HandleCache {
    entries: Vec<Entry>,
    budget_bytes: usize,
    tick: u64,
    stats: CacheStats,
}

impl HandleCache {
    /// A cache evicting down to `budget_bytes`. A budget of 0 still
    /// admits each insert (the newest entry is never evicted by its own
    /// insertion) but evicts it on the next one.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            entries: Vec::new(),
            budget_bytes,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up `key`, bumping its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<FiltrationHandle>> {
        self.tick += 1;
        match self.entries.iter_mut().find(|e| e.key == key) {
            Some(e) => {
                e.last_used = self.tick;
                self.stats.hits += 1;
                Some(Arc::clone(&e.handle))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert `handle` under `key` (replacing any same-key entry), then
    /// evict least-recently-used entries until the budget holds —
    /// except the entry just inserted, which always survives its own
    /// insertion even when it alone exceeds the budget (the caller is
    /// about to query it). Returns the evicted keys, oldest first.
    pub fn insert(&mut self, key: &str, handle: Arc<FiltrationHandle>) -> Vec<String> {
        self.tick += 1;
        let bytes = handle.memory_bytes();
        if let Some(pos) = self.entries.iter().position(|e| e.key == key) {
            let old = self.entries.remove(pos);
            self.stats.bytes -= old.bytes;
        }
        self.entries.push(Entry {
            key: key.to_string(),
            bytes,
            last_used: self.tick,
            handle,
        });
        self.stats.insertions += 1;
        self.stats.bytes += bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.bytes);

        let mut evicted = Vec::new();
        while self.stats.bytes > self.budget_bytes && self.entries.len() > 1 {
            let oldest = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("len > 1");
            let e = self.entries.remove(oldest);
            self.stats.bytes -= e.bytes;
            self.stats.evictions += 1;
            evicted.push(e.key);
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::MetricData;
    use crate::homology::{EngineOptions, Session};

    fn handle_of(n: usize, seed: u64, s: &Session) -> Arc<FiltrationHandle> {
        let data: MetricData = crate::datasets::random_cloud(n, 3, seed);
        Arc::new(s.ingest(&data, f64::INFINITY).unwrap())
    }

    #[test]
    fn lru_eviction_is_deterministic() {
        let s = Session::new(EngineOptions {
            threads: 1,
            ..Default::default()
        });
        let a = handle_of(24, 1, &s);
        let per = a.memory_bytes();
        // Budget fits exactly two entries of this shape.
        let mut c = HandleCache::new(2 * per + per / 2);
        assert!(c.insert("a", Arc::clone(&a)).is_empty());
        assert!(c.insert("b", handle_of(24, 2, &s)).is_empty());
        // Touch "a" so "b" becomes the LRU victim.
        assert!(c.get("a").is_some());
        let evicted = c.insert("c", handle_of(24, 3, &s));
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(c.get("a").is_some());
        assert!(c.get("b").is_none());
        assert!(c.get("c").is_some());
        let st = c.stats();
        assert_eq!(st.insertions, 3);
        assert_eq!(st.evictions, 1);
        assert_eq!(st.hits, 3);
        assert_eq!(st.misses, 1);
        assert_eq!(st.bytes, 2 * per);
        assert!(st.peak_bytes >= st.bytes);
    }

    #[test]
    fn newest_insert_survives_even_over_budget() {
        let s = Session::new(EngineOptions {
            threads: 1,
            ..Default::default()
        });
        let mut c = HandleCache::new(0);
        let evicted = c.insert("only", handle_of(16, 7, &s));
        assert!(evicted.is_empty());
        assert_eq!(c.len(), 1);
        assert!(c.get("only").is_some());
        // The next insert evicts it.
        let evicted = c.insert("next", handle_of(16, 8, &s));
        assert_eq!(evicted, vec!["only".to_string()]);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn same_key_reinsert_replaces_without_eviction() {
        let s = Session::new(EngineOptions {
            threads: 1,
            ..Default::default()
        });
        let h = handle_of(16, 9, &s);
        let per = h.memory_bytes();
        let mut c = HandleCache::new(4 * per);
        assert!(c.insert("k", Arc::clone(&h)).is_empty());
        assert!(c.insert("k", h).is_empty());
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().bytes, per);
        assert_eq!(c.stats().insertions, 2);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn evicted_handle_stays_usable_through_its_arc() {
        let s = Session::new(EngineOptions {
            threads: 1,
            ..Default::default()
        });
        let h = handle_of(20, 11, &s);
        let mut c = HandleCache::new(0);
        c.insert("a", Arc::clone(&h));
        c.insert("b", handle_of(20, 12, &s)); // evicts "a"
        assert!(c.get("a").is_none());
        // The in-flight clone still serves queries.
        let resp = s
            .query(&h, &crate::homology::PhRequest::at(f64::INFINITY))
            .unwrap();
        assert!(!resp.result.diagram.points(0).is_empty());
    }
}
