//! Multi-tenant serving front: line-delimited JSON-RPC over any
//! `BufRead`/`Write` pair (`dory serve` wires it to stdio).
//!
//! One request per line, one response per line, in request order:
//!
//! ```text
//! {"id":1,"tenant":"a","method":"ingest","tau":1.5,"dataset":{"kind":"circle","n":64,"seed":7}}
//! {"id":1,"ok":{"handle":"h9c…","cached":false,"n_points":64,"n_edges":812,"tau_capacity":1.5,"evicted":[]}}
//! {"id":2,"tenant":"a","method":"query","handle":"h9c…","tau":0.9,"max_dim":1}
//! {"id":2,"ok":{"tau":0.9,"tau_effective":0.9,"n_edges":..,"truncated":true,"betti":[…]}}
//! ```
//!
//! Methods:
//! - `ingest` — `dataset` is one of `{"kind","n","seed"}` (named
//!   generator), `{"points":[[…],…]}` (point cloud),
//!   `{"n":N,"edges":[[a,b,d],…]}` (explicit weighted edges, validated
//!   by the filtration front-end), or `{"path":"/file.coo"}` (a sparse
//!   `i j d` file stream-ingested from disk in bounded staging memory;
//!   optional `stream_chunk`/`edge_budget_mb` knobs ride alongside);
//!   `tau` defaults to `+∞` (use the `1e999` overflow convention for ∞
//!   on the wire). The dataset is fingerprinted (content hash + τ bits;
//!   `path` datasets also fold in file size + mtime, so a rewritten
//!   file re-ingests rather than hitting a stale cache entry) and
//!   served from the handle cache when already ingested — the response
//!   says `"cached":true` and charges a tenant cache hit. Path ingests
//!   can be confined to a directory with [`Server::with_data_root`]
//!   (`dory serve --data-root`); without one, any server-readable path
//!   is accepted.
//! - `query` — a [`PhRequest`] against a cached `handle`
//!   (`tau`, optional `max_dim`/`shortcut`/`enclosing`/`label`).
//!   An optional `"features":["betti:64","entropy",…]` array computes
//!   derived feature products post-reduction (typed specs, see
//!   [`crate::features::FeatureSpec::parse`]); they ride back as
//!   `"features"`/`"feature_stats"` response fields and count into the
//!   tenant's `feature_queries`/`feature_specs`. An optional
//!   `"diagram":true` flag attaches the full PD point set
//!   (`[{"dim":…,"points":[[birth,death],…]},…]`, ∞ as `1e999`); a
//!   payload above `--max-diagram-points` is refused with a typed
//!   `Request` error.
//! - `batch` — `queries` (array of query bodies) against one `handle`,
//!   run **concurrently** through the session's `&self` query path by a
//!   bounded crew of workers (≈ the pool width, never one OS thread per
//!   query); responses come back in request order and are bit-identical
//!   to serial execution.
//! - `stats` — the summary object (per-tenant counters, cache, session,
//!   peak RSS) without stopping.
//! - `shutdown` — acknowledge and stop; EOF stops too. Either way the
//!   final line written is `{"summary":…}`.
//!
//! Failures never kill the loop: each is answered in place as
//! `{"id":…,"error":{"kind":"<DoryError variant>","message":…}}` so a
//! client can branch on the class ([`DoryError::kind`]) without parsing
//! prose. Every response carries the request's `id` verbatim.
//!
//! ## Resilience
//!
//! - A panicking query — single or batched — is caught per request and
//!   answered as a typed `Internal` wire error; the server, its caches,
//!   and the shared handle keep serving (mutexes recover from
//!   poisoning, and every guarded section leaves its state coherent).
//! - [`Server::with_overload`] arms admission control: at most
//!   `max_inflight` query/batch/ingest requests execute at once, with
//!   an optional per-tenant cap; excess load is shed immediately with a
//!   typed `Overloaded` error instead of queueing without bound.
//! - A query body may carry `timeout_ms`; the deadline is polled at
//!   batch-commit boundaries inside the reduction and an expired
//!   request gets a typed `DeadlineExceeded` — the handle stays
//!   serviceable and later queries are bit-identical.
//! - Construction sweeps `dory-spill-*.run` files orphaned in the spill
//!   directory by dead processes; wire ingests honor
//!   [`Server::with_strict_spill`], and degraded (in-memory fallback)
//!   ingests are flagged on the response and counted in the summary's
//!   `resilience` block.
//!
//! Handles are cached in a byte-budgeted strict-LRU [`HandleCache`]
//! behind a mutex; the handles themselves are `Arc`-shared, so eviction
//! never races an in-flight query. The session and pool are shared by
//! all tenants — concurrency comes from the pool's fair multi-generation
//! scheduling, not from per-tenant engines.

pub mod cache;

pub use cache::{CacheStats, HandleCache};

use std::collections::BTreeMap;
use std::hash::Hasher;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::coordinator::{self, DatasetSpec};
use crate::error::DoryError;
use crate::filtration::{EdgeFiltration, FiltrationStats};
use crate::geometry::{MetricData, PointCloud};
use crate::homology::{EngineOptions, FiltrationHandle, PhRequest, PhResponse, Session};
use crate::util::failpoint;
use crate::util::fxhash::FxHasher;
use crate::util::json::Json;
use crate::util::memtrack;
use crate::util::timer::PhaseTimer;

/// Lock a serve-state mutex, recovering from poisoning. A panicking
/// query thread must not wedge the whole server: every critical
/// section here only performs field updates that are coherent at any
/// point, so the data behind a poisoned lock is still valid — the
/// panic itself is reported separately as a typed `Internal` error.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-tenant lifetime counters, reported in the summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantCounters {
    pub ingests: u64,
    pub queries: u64,
    pub cache_hits: u64,
    pub errors: u64,
    /// Batch scheduling latency: per batched query, the time between
    /// batch dispatch and that query's thread starting, summed.
    pub queue_wait_ns: u64,
    /// Queries that requested derived feature products.
    pub feature_queries: u64,
    /// Individual feature specs computed across those queries.
    pub feature_specs: u64,
    /// Diagram points shipped over the wire via `"diagram":true`.
    pub diagram_points: u64,
}

impl TenantCounters {
    fn to_json(self) -> Json {
        Json::obj()
            .field("ingests", self.ingests)
            .field("queries", self.queries)
            .field("cache_hits", self.cache_hits)
            .field("errors", self.errors)
            .field("queue_wait_ns", self.queue_wait_ns)
            .field("feature_queries", self.feature_queries)
            .field("feature_specs", self.feature_specs)
            .field("diagram_points", self.diagram_points)
    }
}

/// Front-end facts accumulated across this server's (non-cached)
/// ingests, exposed by the `stats` method: which distance kernel the
/// last build selected and the dense-streaming spill totals.
#[derive(Default)]
struct FrontendAgg {
    dist_kernel: &'static str,
    dense_spilled_runs: u64,
    dense_spilled_bytes: u64,
    dense_staging_peak_bytes: u64,
}

/// What the resilience layer observed during one wire ingest: whether
/// the spill store fell back to in-memory staging and how many
/// transient I/O faults its bounded retries absorbed.
#[derive(Default)]
struct IngestFacts {
    degraded: bool,
    io_retries: u64,
}

impl IngestFacts {
    fn from_stats(st: &crate::io::stream::StreamStats) -> Self {
        Self {
            degraded: st.degraded,
            io_retries: st.io_retries,
        }
    }
}

/// Overload control: a bounded count of concurrently executing
/// query/batch/ingest requests, with an optional per-tenant cap.
/// `0` for either limit means unbounded — the default. Excess load is
/// shed immediately with a typed [`DoryError::Overloaded`] rather than
/// queued without bound, so a flooding tenant cannot starve the rest.
struct AdmissionGate {
    max_inflight: usize,
    tenant_quota: usize,
    inflight: AtomicUsize,
    per_tenant: Mutex<BTreeMap<String, usize>>,
    shed: AtomicU64,
}

impl AdmissionGate {
    fn new(max_inflight: usize, tenant_quota: usize) -> Self {
        Self {
            max_inflight,
            tenant_quota,
            inflight: AtomicUsize::new(0),
            per_tenant: Mutex::new(BTreeMap::new()),
            shed: AtomicU64::new(0),
        }
    }

    /// Admit one request for `tenant`, or shed it typed. The returned
    /// permit releases both counts on drop (including via a panicking
    /// unwind, so a crashed request never leaks capacity).
    fn admit(&self, tenant: &str) -> Result<Permit<'_>, DoryError> {
        if self.max_inflight > 0 {
            let admitted = self
                .inflight
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                    (n < self.max_inflight).then_some(n + 1)
                })
                .is_ok();
            if !admitted {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return Err(DoryError::Overloaded(format!(
                    "server at capacity ({} requests in flight); retry later",
                    self.max_inflight
                )));
            }
        } else {
            self.inflight.fetch_add(1, Ordering::AcqRel);
        }
        if self.tenant_quota > 0 {
            let mut map = relock(&self.per_tenant);
            let slot = map.entry(tenant.to_string()).or_insert(0);
            if *slot >= self.tenant_quota {
                drop(map);
                self.inflight.fetch_sub(1, Ordering::AcqRel);
                self.shed.fetch_add(1, Ordering::Relaxed);
                return Err(DoryError::Overloaded(format!(
                    "tenant '{tenant}' at quota ({} requests in flight); retry later",
                    self.tenant_quota
                )));
            }
            *slot += 1;
        }
        Ok(Permit { gate: self, tenant: tenant.to_string() })
    }
}

/// RAII admission slot; see [`AdmissionGate::admit`].
struct Permit<'a> {
    gate: &'a AdmissionGate,
    tenant: String,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        if self.gate.tenant_quota > 0 {
            let mut map = relock(&self.gate.per_tenant);
            if let Some(slot) = map.get_mut(&self.tenant) {
                *slot = slot.saturating_sub(1);
                if *slot == 0 {
                    map.remove(&self.tenant);
                }
            }
        }
        self.gate.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Lifetime resilience counters, reported in the summary's
/// `resilience` block.
#[derive(Default)]
struct ResilienceCounters {
    /// Query panics caught and answered as typed `Internal` errors.
    panics: AtomicU64,
    /// Response-write attempts retried after an injected transient.
    write_retries: AtomicU64,
    /// Wire ingests that fell back to in-memory staging.
    degraded_ingests: AtomicU64,
    /// Spill/stream I/O retries absorbed across wire ingests.
    ingest_io_retries: AtomicU64,
    /// Orphaned `dory-spill-*.run` files removed at construction.
    swept_spill_files: AtomicU64,
}

/// The serving state: one shared [`Session`] (and worker pool), the
/// handle cache, and per-tenant counters. All methods take `&self`.
pub struct Server {
    session: Session,
    cache: Mutex<HandleCache>,
    tenants: Mutex<BTreeMap<String, TenantCounters>>,
    frontend: Mutex<FrontendAgg>,
    data_root: Option<std::path::PathBuf>,
    gate: AdmissionGate,
    resilience: ResilienceCounters,
    strict_spill: bool,
    /// Cap on the diagram point count a `"diagram":true` query may ship
    /// over the wire (`0` = unbounded). Above it, the query is refused
    /// with a typed `Request` error before any payload is rendered.
    max_diagram_points: usize,
}

impl Server {
    /// A server running `opts`, caching at most `cache_budget_bytes` of
    /// handle payload (edge sets + CSRs). Construction sweeps spill
    /// files orphaned in the temp directory by dead processes, so a
    /// crashed predecessor's staging runs don't accumulate.
    pub fn new(opts: EngineOptions, cache_budget_bytes: usize) -> Self {
        let swept = crate::io::stream::sweep_orphaned_spills(&std::env::temp_dir());
        let srv = Self {
            session: Session::new(opts),
            cache: Mutex::new(HandleCache::new(cache_budget_bytes)),
            tenants: Mutex::new(BTreeMap::new()),
            frontend: Mutex::new(FrontendAgg::default()),
            data_root: None,
            gate: AdmissionGate::new(0, 0),
            resilience: ResilienceCounters::default(),
            strict_spill: false,
            max_diagram_points: 0,
        };
        srv.resilience
            .swept_spill_files
            .store(swept as u64, Ordering::Relaxed);
        srv
    }

    /// Arm overload shedding: at most `max_inflight` requests (and at
    /// most `tenant_quota` per tenant) execute concurrently; excess is
    /// answered with a typed `Overloaded` error. `0` = unbounded.
    pub fn with_overload(mut self, max_inflight: usize, tenant_quota: usize) -> Self {
        self.gate = AdmissionGate::new(max_inflight, tenant_quota);
        self
    }

    /// Refuse the in-memory degradation fallback on wire ingests whose
    /// spill writes keep failing: surface the typed I/O error instead
    /// of absorbing the fault into unbounded staging memory.
    pub fn with_strict_spill(mut self, strict: bool) -> Self {
        self.strict_spill = strict;
        self
    }

    /// Cap the diagram point count a `"diagram":true` query may return
    /// (`dory serve --max-diagram-points`). Above the cap the query is
    /// refused with a typed `Request` error — the reduction itself still
    /// ran, so the client can retry without the flag or at a smaller τ.
    /// `0` = unbounded, the default.
    pub fn with_max_diagram_points(mut self, cap: usize) -> Self {
        self.max_diagram_points = cap;
        self
    }

    /// Restrict `{"path":…}` wire ingests to files under `root`
    /// (checked against the canonicalized root, so `..` segments and
    /// symlinks cannot escape it). Without a root — the default — any
    /// path readable by the server process is accepted, which is only
    /// appropriate when every wire client is trusted with the server's
    /// filesystem.
    pub fn with_data_root(mut self, root: std::path::PathBuf) -> Self {
        self.data_root = Some(root);
        self
    }

    /// Refuse a wire-supplied ingest path outside the configured data
    /// root (no-op when no root is set).
    fn check_data_root(&self, path: &std::path::Path) -> Result<(), DoryError> {
        let Some(root) = &self.data_root else {
            return Ok(());
        };
        let refuse = || {
            DoryError::Request(format!(
                "path {} is outside the configured data root (or not resolvable)",
                path.display()
            ))
        };
        let canon_root = std::fs::canonicalize(root).map_err(|e| DoryError::io(root, e))?;
        // A canonicalize failure on the client's path gets the same
        // refusal as an out-of-root one, so probes can't distinguish
        // missing from forbidden.
        let canon = std::fs::canonicalize(path).map_err(|_| refuse())?;
        if !canon.starts_with(&canon_root) {
            return Err(refuse());
        }
        Ok(())
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Drive the request loop until EOF or a `shutdown` request, then
    /// write the `{"summary":…}` trailer. Returns the number of
    /// requests served (including errored ones).
    pub fn serve<R: BufRead, W: Write>(&self, reader: R, mut out: W) -> std::io::Result<u64> {
        let mut served = 0u64;
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            served += 1;
            let (response, stop) = self.handle_line(&line);
            self.write_response(&mut out, &response.render())?;
            if stop {
                break;
            }
        }
        let trailer = Json::obj().field("summary", self.summary_json()).render();
        self.write_response(&mut out, &trailer)?;
        Ok(served)
    }

    /// Write one response line, retrying transient *injected* write
    /// faults a bounded number of times. Injected faults fire before
    /// any byte reaches the sink, so a retry cannot duplicate output;
    /// real write errors (client gone, pipe closed) propagate at once —
    /// retrying a partial real write could interleave garbage.
    fn write_response<W: Write>(&self, out: &mut W, line: &str) -> std::io::Result<()> {
        let mut attempts = 0u32;
        loop {
            let r = failpoint::check(failpoint::SERVE_WRITE)
                .and_then(|()| writeln!(out, "{line}"))
                .and_then(|()| out.flush());
            match r {
                Ok(()) => return Ok(()),
                Err(e) if failpoint::is_injected(&e) && attempts + 1 < 3 => {
                    attempts += 1;
                    self.resilience.write_retries.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Serve one request line; returns the response and whether the
    /// loop should stop.
    pub fn handle_line(&self, line: &str) -> (Json, bool) {
        let req = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                let err = DoryError::Request(format!("parse: {e}"));
                return (wire_error(Json::Null, &err), false);
            }
        };
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        let tenant = req
            .get("tenant")
            .and_then(|t| t.as_str())
            .unwrap_or("default")
            .to_string();
        let method = match req.get("method").and_then(|m| m.as_str()) {
            Some(m) => m.to_string(),
            None => {
                let err = DoryError::Request("missing string field 'method'".into());
                self.bump_tenant(&tenant, |t| t.errors += 1);
                return (wire_error(id, &err), false);
            }
        };
        let (result, stop) = match method.as_str() {
            "ingest" => (self.handle_ingest(&tenant, &req), false),
            "query" => (self.handle_query(&tenant, &req), false),
            "batch" => (self.handle_batch(&tenant, &req), false),
            "stats" => (Ok(self.summary_json()), false),
            "shutdown" => (Ok(Json::obj().field("stopping", true)), true),
            other => (
                Err(DoryError::Request(format!("unknown method '{other}'"))),
                false,
            ),
        };
        match result {
            Ok(ok) => (Json::obj().field("id", id).field("ok", ok), stop),
            Err(e) => {
                self.bump_tenant(&tenant, |t| t.errors += 1);
                (wire_error(id, &e), stop)
            }
        }
    }

    fn bump_tenant(&self, tenant: &str, f: impl FnOnce(&mut TenantCounters)) {
        let mut map = relock(&self.tenants);
        f(map.entry(tenant.to_string()).or_default());
    }

    fn handle_ingest(&self, tenant: &str, req: &Json) -> Result<Json, DoryError> {
        let dataset = req
            .get("dataset")
            .ok_or_else(|| DoryError::Request("ingest needs a 'dataset' object".into()))?;
        let tau = match req.get("tau") {
            None => f64::INFINITY,
            Some(t) => t
                .as_f64()
                .ok_or_else(|| DoryError::Request("'tau' must be a number".into()))?,
        };
        if tau.is_nan() {
            return Err(DoryError::Request("ingest tau is NaN".into()));
        }
        if tau < 0.0 {
            return Err(DoryError::Request(format!(
                "ingest tau must be non-negative, got {tau}"
            )));
        }
        // Path ingests: enforce the data root before touching the file
        // at all (fingerprinting stats it), so out-of-root probes get a
        // uniform Request refusal rather than existence-revealing Io
        // errors.
        if let Some(p) = dataset.get("path").and_then(|p| p.as_str()) {
            self.check_data_root(std::path::Path::new(p))?;
        }
        let key = fingerprint(dataset, tau)?;
        if let Some(h) = relock(&self.cache).get(&key) {
            self.bump_tenant(tenant, |t| {
                t.ingests += 1;
                t.cache_hits += 1;
            });
            return Ok(ingest_ok(&key, &h, true, &[], false));
        }
        let _permit = self.gate.admit(tenant)?;
        let (handle, facts) = self.build_handle(dataset, tau)?;
        let handle = Arc::new(handle);
        if facts.degraded {
            self.resilience.degraded_ingests.fetch_add(1, Ordering::Relaxed);
        }
        self.resilience
            .ingest_io_retries
            .fetch_add(facts.io_retries, Ordering::Relaxed);
        {
            let fs = handle.stats();
            let mut agg = relock(&self.frontend);
            if !fs.dist_kernel.is_empty() {
                agg.dist_kernel = fs.dist_kernel;
            }
            agg.dense_spilled_runs += fs.dense_spilled_runs;
            agg.dense_spilled_bytes += fs.dense_spilled_bytes;
            agg.dense_staging_peak_bytes =
                agg.dense_staging_peak_bytes.max(fs.dense_staging_peak_bytes);
        }
        let evicted = relock(&self.cache).insert(&key, Arc::clone(&handle));
        self.bump_tenant(tenant, |t| t.ingests += 1);
        Ok(ingest_ok(&key, &handle, false, &evicted, facts.degraded))
    }

    /// Materialize and ingest one wire dataset form, plus what the
    /// resilience layer observed while doing it (zero for the
    /// non-streaming forms).
    fn build_handle(
        &self,
        dataset: &Json,
        tau: f64,
    ) -> Result<(FiltrationHandle, IngestFacts), DoryError> {
        if dataset.get("kind").is_some() {
            let kind = dataset
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| DoryError::Request("'kind' must be a string".into()))?
                .to_string();
            let n = match dataset.get("n") {
                None => 64,
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| DoryError::Request("'n' must be a non-negative integer".into()))?,
            };
            let seed = match dataset.get("seed") {
                None => 0,
                Some(v) => v.as_usize().ok_or_else(|| {
                    DoryError::Request("'seed' must be a non-negative integer".into())
                })? as u64,
            };
            let spec = DatasetSpec::Named { kind, n, seed };
            let data =
                coordinator::build_dataset(&spec).map_err(|e| DoryError::Dataset(e.to_string()))?;
            return Ok((self.session.ingest(&data, tau)?, IngestFacts::default()));
        }
        if let Some(rows) = dataset.get("points") {
            let rows = rows
                .as_arr()
                .ok_or_else(|| DoryError::Request("'points' must be an array of rows".into()))?;
            let mut coords = Vec::new();
            let mut dim = 0usize;
            for (i, row) in rows.iter().enumerate() {
                let row = row.as_arr().ok_or_else(|| {
                    DoryError::Request(format!("points[{i}] must be an array of numbers"))
                })?;
                if i == 0 {
                    dim = row.len();
                    if dim == 0 {
                        return Err(DoryError::Request("points rows must be non-empty".into()));
                    }
                } else if row.len() != dim {
                    return Err(DoryError::Request(format!(
                        "points[{i}] has {} coordinates, expected {dim}",
                        row.len()
                    )));
                }
                for (j, v) in row.iter().enumerate() {
                    coords.push(v.as_f64().ok_or_else(|| {
                        DoryError::Request(format!("points[{i}][{j}] must be a number"))
                    })?);
                }
            }
            if coords.is_empty() {
                return Err(DoryError::Request("'points' must be non-empty".into()));
            }
            let data = MetricData::Points(PointCloud::new(dim, coords));
            // An `edge_budget_mb` knob on a points dataset routes the
            // dense front-end tiles through the spill store (bounded
            // staging, bit-identical output) instead of the in-memory
            // build.
            if let Some(v) = dataset.get("edge_budget_mb") {
                let mb = v.as_usize().ok_or_else(|| {
                    DoryError::Request("'edge_budget_mb' must be a non-negative integer".into())
                })?;
                if mb > 0 {
                    let budget_bytes = mb.checked_mul(1 << 20).ok_or_else(|| {
                        DoryError::Request(format!(
                            "'edge_budget_mb' {mb} overflows the byte budget"
                        ))
                    })?;
                    let opts = crate::io::stream::StreamOptions {
                        budget_bytes,
                        strict: self.strict_spill,
                        ..Default::default()
                    };
                    let (h, st) = self.session.ingest_streamed(&data, tau, &opts)?;
                    return Ok((h, IngestFacts::from_stats(&st)));
                }
            }
            return Ok((self.session.ingest(&data, tau)?, IngestFacts::default()));
        }
        if let Some(rows) = dataset.get("edges") {
            let n = req_usize(dataset, "n")? as u32;
            let rows = rows
                .as_arr()
                .ok_or_else(|| DoryError::Request("'edges' must be an array of [a,b,d]".into()))?;
            let mut raw = Vec::with_capacity(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let row = row.as_arr().filter(|r| r.len() == 3).ok_or_else(|| {
                    DoryError::Request(format!("edges[{i}] must be [vertex, vertex, distance]"))
                })?;
                let a = row[0].as_usize().ok_or_else(|| {
                    DoryError::Request(format!("edges[{i}][0] must be a vertex index"))
                })?;
                let b = row[1].as_usize().ok_or_else(|| {
                    DoryError::Request(format!("edges[{i}][1] must be a vertex index"))
                })?;
                let d = row[2].as_f64().ok_or_else(|| {
                    DoryError::Request(format!("edges[{i}][2] must be a distance"))
                })?;
                if a > u32::MAX as usize || b > u32::MAX as usize {
                    return Err(DoryError::Request(format!(
                        "edges[{i}] vertex index exceeds u32"
                    )));
                }
                // Keep over-τ edges out, but let NaN through to the
                // front-end validator so it reports the typed error.
                if d > tau {
                    continue;
                }
                raw.push((d, a as u32, b as u32));
            }
            let mut fstats = FiltrationStats::default();
            let mut timings = PhaseTimer::new();
            timings.start("F1");
            let f = EdgeFiltration::try_from_weighted_edges_pooled(
                n,
                raw,
                tau,
                self.session.engine().pool(),
                &mut fstats,
            )?;
            timings.stop();
            let h = self.session.ingest_filtration(f, timings, fstats, "wire-edges")?;
            return Ok((h, IngestFacts::default()));
        }
        if let Some(p) = dataset.get("path") {
            let path = std::path::PathBuf::from(
                p.as_str()
                    .ok_or_else(|| DoryError::Request("'path' must be a string".into()))?,
            );
            // Stream-ingest a sparse COO file from disk in bounded
            // staging memory. Optional knobs ride in the dataset object;
            // the cache fingerprint covers the dataset JSON (path +
            // knobs + τ) plus the file's size and mtime, so a rewritten
            // file misses the cache instead of serving a stale handle.
            let mut opts = crate::io::stream::StreamOptions {
                strict: self.strict_spill,
                ..Default::default()
            };
            if let Some(v) = dataset.get("stream_chunk") {
                opts.chunk_lines = v.as_usize().ok_or_else(|| {
                    DoryError::Request("'stream_chunk' must be a non-negative integer".into())
                })?;
            }
            if let Some(v) = dataset.get("edge_budget_mb") {
                let mb = v.as_usize().ok_or_else(|| {
                    DoryError::Request("'edge_budget_mb' must be a non-negative integer".into())
                })?;
                opts.budget_bytes = mb.checked_mul(1 << 20).ok_or_else(|| {
                    DoryError::Request(format!(
                        "'edge_budget_mb' {mb} overflows the byte budget"
                    ))
                })?;
            }
            let (h, stats) = self.session.ingest_sparse_file(&path, tau, &opts)?;
            return Ok((h, IngestFacts::from_stats(&stats)));
        }
        Err(DoryError::Request(
            "dataset must specify 'kind', 'points', 'edges', or 'path'".into(),
        ))
    }

    fn lookup(&self, req: &Json) -> Result<Arc<FiltrationHandle>, DoryError> {
        let key = req
            .get("handle")
            .and_then(|h| h.as_str())
            .ok_or_else(|| DoryError::Request("missing string field 'handle'".into()))?;
        relock(&self.cache).get(key).ok_or_else(|| {
            DoryError::Request(format!(
                "unknown or evicted handle '{key}'; re-ingest the dataset"
            ))
        })
    }

    /// One session query with the serve-side panic boundary: a worker
    /// panic (or the armed `serve-query-panic` failpoint) becomes a
    /// typed `Internal` error instead of unwinding into the request
    /// loop. The session's query path takes `&self` and never leaves
    /// the shared handle half-mutated, so catching here is sound — the
    /// handle keeps serving bit-identical diagrams afterwards.
    fn query_caught(
        &self,
        h: &FiltrationHandle,
        ph: &PhRequest,
    ) -> Result<PhResponse, DoryError> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if failpoint::should_fail(failpoint::SERVE_QUERY_PANIC) {
                panic!("injected serve-query panic");
            }
            self.session.query(h, ph)
        }))
        .unwrap_or_else(|_| {
            self.resilience.panics.fetch_add(1, Ordering::Relaxed);
            Err(DoryError::Internal(
                "query worker panicked; the handle remains serviceable".into(),
            ))
        })
    }

    fn handle_query(&self, tenant: &str, req: &Json) -> Result<Json, DoryError> {
        let _permit = self.gate.admit(tenant)?;
        let h = self.lookup(req)?;
        let (ph, diagram) = parse_ph_request(req)?;
        let n_specs = ph.features.len() as u64;
        let resp = self.query_caught(&h, &ph)?;
        let (ok, shipped) = query_ok(&resp, diagram, self.max_diagram_points)?;
        self.bump_tenant(tenant, |t| {
            t.queries += 1;
            if n_specs > 0 {
                t.feature_queries += 1;
                t.feature_specs += n_specs;
            }
            t.diagram_points += shipped;
        });
        Ok(ok)
    }

    fn handle_batch(&self, tenant: &str, req: &Json) -> Result<Json, DoryError> {
        let _permit = self.gate.admit(tenant)?;
        let h = self.lookup(req)?;
        let bodies = req
            .get("queries")
            .and_then(|q| q.as_arr())
            .ok_or_else(|| DoryError::Request("batch needs a 'queries' array".into()))?;
        let parsed = bodies
            .iter()
            .map(parse_ph_request)
            .collect::<Result<Vec<_>, _>>()?;
        let (phs, diagrams): (Vec<PhRequest>, Vec<bool>) = parsed.into_iter().unzip();
        // Fan the batch out over a *bounded* crew of scoped worker
        // threads (≈ the pool width — more OS threads than that just
        // queue on the same pool) pulling query indices from a shared
        // counter: every query still goes through the same `&self`
        // session path a lone `query` request takes, so the pool
        // interleaves them fairly and results stay bit-identical to
        // serial execution. Responses land in per-index slots, so they
        // return in request order, and `queue_wait_ns` keeps its
        // meaning: per query, the time between batch dispatch and that
        // query starting on a worker.
        let n_workers = self
            .session
            .options()
            .threads
            .max(1)
            .min(phs.len().max(1));
        let t0 = Instant::now();
        let next = AtomicUsize::new(0);
        let wait_ns = AtomicU64::new(0);
        let slots: Vec<Mutex<Option<Result<PhResponse, DoryError>>>> =
            phs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                let (h, phs, next, wait_ns, slots) = (&h, &phs, &next, &wait_ns, &slots);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= phs.len() {
                        break;
                    }
                    let waited = t0.elapsed().as_nanos() as u64;
                    // A panicking query must not poison the whole batch:
                    // it is caught per query, reported as a typed
                    // Internal error in its slot, and this worker keeps
                    // draining the rest.
                    let r = self.query_caught(h, &phs[i]);
                    wait_ns.fetch_add(waited, Ordering::Relaxed);
                    *relock(&slots[i]) = Some(r);
                });
            }
        });
        let results: Vec<Result<PhResponse, DoryError>> = slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .unwrap_or_else(|| {
                        Err(DoryError::Internal("batch query worker panicked".into()))
                    })
            })
            .collect();
        self.bump_tenant(tenant, |t| {
            t.queries += results.len() as u64;
            t.queue_wait_ns += wait_ns.load(Ordering::Relaxed);
        });
        let mut arr = Json::arr();
        let mut shipped_total = 0u64;
        let mut feature_queries = 0u64;
        let mut feature_specs = 0u64;
        for ((r, ph), diagram) in results.into_iter().zip(&phs).zip(diagrams) {
            let (ok, shipped) = query_ok(&r?, diagram, self.max_diagram_points)?;
            shipped_total += shipped;
            if !ph.features.is_empty() {
                feature_queries += 1;
                feature_specs += ph.features.len() as u64;
            }
            arr.push(ok);
        }
        self.bump_tenant(tenant, |t| {
            t.diagram_points += shipped_total;
            t.feature_queries += feature_queries;
            t.feature_specs += feature_specs;
        });
        Ok(Json::obj().field("responses", arr))
    }

    /// The summary object: per-tenant counters, cache stats, session
    /// stats, peak RSS.
    pub fn summary_json(&self) -> Json {
        let mut tenants = Json::obj();
        for (name, c) in relock(&self.tenants).iter() {
            tenants = tenants.field(name, c.to_json());
        }
        let cs = relock(&self.cache).stats();
        let cache = Json::obj()
            .field("hits", cs.hits)
            .field("misses", cs.misses)
            .field("insertions", cs.insertions)
            .field("evictions", cs.evictions)
            .field("bytes", cs.bytes)
            .field("peak_bytes", cs.peak_bytes);
        let fa = relock(&self.frontend);
        let frontend = Json::obj()
            .field("dist_kernel", fa.dist_kernel)
            .field("dense_spilled_runs", fa.dense_spilled_runs)
            .field("dense_spilled_bytes", fa.dense_spilled_bytes)
            .field("dense_staging_peak_bytes", fa.dense_staging_peak_bytes);
        drop(fa);
        let rc = &self.resilience;
        let resilience = Json::obj()
            .field("shed", self.gate.shed.load(Ordering::Relaxed))
            .field("panics", rc.panics.load(Ordering::Relaxed))
            .field("write_retries", rc.write_retries.load(Ordering::Relaxed))
            .field("degraded_ingests", rc.degraded_ingests.load(Ordering::Relaxed))
            .field("ingest_io_retries", rc.ingest_io_retries.load(Ordering::Relaxed))
            .field("swept_spill_files", rc.swept_spill_files.load(Ordering::Relaxed));
        Json::obj()
            .field("tenants", tenants)
            .field("cache", cache)
            .field("frontend", frontend)
            .field("resilience", resilience)
            .field("session", self.session.stats().to_json())
            .field("max_rss_bytes", memtrack::max_rss_bytes())
    }
}

/// `{"id":…,"error":{"kind":…,"message":…}}`.
fn wire_error(id: Json, e: &DoryError) -> Json {
    Json::obj().field("id", id).field(
        "error",
        Json::obj()
            .field("kind", e.kind())
            .field("message", e.to_string()),
    )
}

fn req_usize(obj: &Json, key: &str) -> Result<usize, DoryError> {
    obj.get(key).and_then(|v| v.as_usize()).ok_or_else(|| {
        DoryError::Request(format!("missing non-negative integer field '{key}'"))
    })
}

/// The query body shared by `query` and each `batch` element. τ is
/// required; NaN/negative τ pass through to the session's typed guard.
/// Returns the typed request plus the `"diagram":true` wire flag (the
/// full PD point set rides back on the response when set).
fn parse_ph_request(req: &Json) -> Result<(PhRequest, bool), DoryError> {
    let tau = req
        .get("tau")
        .and_then(|t| t.as_f64())
        .ok_or_else(|| DoryError::Request("query needs a numeric 'tau'".into()))?;
    let mut ph = PhRequest::at(tau);
    if let Some(v) = req.get("max_dim") {
        ph.max_dim = Some(v.as_usize().ok_or_else(|| {
            DoryError::Request("'max_dim' must be a non-negative integer".into())
        })?);
    }
    if let Some(v) = req.get("shortcut") {
        ph.shortcut = Some(
            v.as_bool()
                .ok_or_else(|| DoryError::Request("'shortcut' must be a boolean".into()))?,
        );
    }
    if let Some(v) = req.get("enclosing") {
        ph.enclosing = Some(
            v.as_bool()
                .ok_or_else(|| DoryError::Request("'enclosing' must be a boolean".into()))?,
        );
    }
    if let Some(v) = req.get("label") {
        ph.label = Some(
            v.as_str()
                .ok_or_else(|| DoryError::Request("'label' must be a string".into()))?
                .to_string(),
        );
    }
    if let Some(v) = req.get("timeout_ms") {
        ph.timeout_ms = Some(v.as_usize().ok_or_else(|| {
            DoryError::Request("'timeout_ms' must be a non-negative integer".into())
        })? as u64);
    }
    if let Some(v) = req.get("features") {
        let arr = v.as_arr().ok_or_else(|| {
            DoryError::Request("'features' must be an array of spec strings".into())
        })?;
        let mut specs = Vec::with_capacity(arr.len());
        for item in arr {
            let s = item.as_str().ok_or_else(|| {
                DoryError::Request("'features' must be an array of spec strings".into())
            })?;
            specs.push(
                crate::features::FeatureSpec::parse(s).map_err(DoryError::Request)?,
            );
        }
        ph.features = specs;
    }
    let diagram = match req.get("diagram") {
        None => false,
        Some(v) => v
            .as_bool()
            .ok_or_else(|| DoryError::Request("'diagram' must be a boolean".into()))?,
    };
    Ok((ph, diagram))
}

fn ingest_ok(
    key: &str,
    h: &FiltrationHandle,
    cached: bool,
    evicted: &[String],
    degraded: bool,
) -> Json {
    let mut ev = Json::arr();
    for k in evicted {
        ev.push(k.as_str());
    }
    Json::obj()
        .field("handle", key)
        .field("cached", cached)
        .field("n_points", h.n_points())
        .field("n_edges", h.n_edges())
        .field("tau_capacity", h.tau_capacity())
        .field("memory_bytes", h.memory_bytes())
        .field("edge_source", h.edge_source)
        .field("dist_kernel", h.stats().dist_kernel)
        .field("dense_spilled_runs", h.stats().dense_spilled_runs)
        .field("degraded", degraded)
        .field("evicted", ev)
}

/// Render one query response. With `diagram` set, the full PD point
/// set is attached as `"diagram":[{"dim":…,"points":[[b,d],…]},…]`
/// (∞ deaths render as `1e999`, the wire's overflow convention), after
/// checking the server's `max_diagram_points` cap — a too-large payload
/// is a typed `Request` refusal, not a truncated one. Returns the JSON
/// plus how many diagram points it shipped (for the tenant counters).
fn query_ok(
    resp: &PhResponse,
    diagram: bool,
    max_diagram_points: usize,
) -> Result<(Json, u64), DoryError> {
    let d = &resp.result.diagram;
    let mut betti = Json::arr();
    for dim in 0..=d.max_dim() {
        betti.push(
            Json::obj()
                .field("dim", dim)
                .field("finite", d.finite(dim).len())
                .field("essential", d.essential_count(dim)),
        );
    }
    let mut obj = Json::obj();
    if let Some(l) = &resp.label {
        obj = obj.field("label", l.as_str());
    }
    obj = obj
        .field("tau", resp.tau)
        .field("tau_effective", resp.tau_effective)
        .field("n_edges", resp.n_edges)
        .field("truncated", resp.truncated)
        .field("betti", betti);
    let mut shipped = 0u64;
    if diagram {
        let total: usize = (0..=d.max_dim()).map(|dim| d.points(dim).len()).sum();
        if max_diagram_points > 0 && total > max_diagram_points {
            return Err(DoryError::Request(format!(
                "diagram has {total} points, above the server's max-diagram-points \
                 cap of {max_diagram_points}; query a smaller tau or drop 'diagram'"
            )));
        }
        let mut dims = Json::arr();
        for dim in 0..=d.max_dim() {
            let mut pts = Json::arr();
            for p in d.points(dim) {
                let mut pair = Json::arr();
                pair.push(p.birth);
                pair.push(p.death);
                pts.push(pair);
            }
            shipped += d.points(dim).len() as u64;
            dims.push(Json::obj().field("dim", dim).field("points", pts));
        }
        obj = obj.field("diagram", dims);
    }
    if let Some(fo) = &resp.features {
        obj = obj
            .field("features", fo.to_json())
            .field("feature_stats", fo.stats.to_json());
    }
    Ok((obj, shipped))
}

/// Content fingerprint of an ingest: the dataset value's canonical
/// rendering plus the τ bits, FxHash-mixed into a 64-bit key. Two
/// tenants posting the same dataset at the same τ share one handle.
/// `path` datasets additionally fold in the file's size and mtime, so
/// re-ingesting a changed file under the same path misses the cache
/// (across tenants too) instead of serving the stale handle. FxHash is
/// not collision-resistant against crafted inputs — tenants of one
/// server share a process and are trusted to that extent.
fn fingerprint(dataset: &Json, tau: f64) -> Result<String, DoryError> {
    let mut h = FxHasher::default();
    h.write(dataset.render().as_bytes());
    h.write_u64(tau.to_bits());
    if let Some(p) = dataset.get("path").and_then(|p| p.as_str()) {
        let path = std::path::Path::new(p);
        let meta = std::fs::metadata(path).map_err(|e| DoryError::io(path, e))?;
        h.write_u64(meta.len());
        if let Ok(mtime) = meta.modified() {
            if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
                h.write_u64(d.as_secs());
                h.write_u64(d.subsec_nanos() as u64);
            }
        }
    }
    Ok(format!("h{:016x}", h.finish()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn server() -> Server {
        Server::new(
            EngineOptions {
                threads: 2,
                ..Default::default()
            },
            64 << 20,
        )
    }

    fn drive(srv: &Server, lines: &str) -> Vec<Json> {
        let mut out = Vec::new();
        srv.serve(Cursor::new(lines.to_string()), &mut out).unwrap();
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect()
    }

    #[test]
    fn ingest_query_roundtrip_with_cache_hit() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let lines = concat!(
            r#"{"id":1,"tenant":"a","method":"ingest","tau":1e999,"dataset":{"kind":"circle","n":48,"seed":7}}"#,
            "\n",
            r#"{"id":2,"tenant":"b","method":"ingest","tau":1e999,"dataset":{"kind":"circle","n":48,"seed":7}}"#,
            "\n",
        );
        let out = drive(&srv, lines);
        let h1 = out[0].get("ok").unwrap();
        let h2 = out[1].get("ok").unwrap();
        assert_eq!(h1.get("cached").unwrap().as_bool(), Some(false));
        assert_eq!(h2.get("cached").unwrap().as_bool(), Some(true));
        let key = h1.get("handle").unwrap().as_str().unwrap().to_string();
        assert_eq!(h2.get("handle").unwrap().as_str().unwrap(), key);

        let q = format!(
            "{{\"id\":3,\"tenant\":\"a\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}\n"
        );
        let out = drive(&srv, &q);
        let ok = out[0].get("ok").unwrap();
        assert_eq!(ok.get("truncated").unwrap().as_bool(), Some(true));
        let betti = ok.get("betti").unwrap().as_arr().unwrap();
        assert_eq!(betti[0].get("dim").unwrap().as_usize(), Some(0));
        // One filtration build served both tenants' ingests.
        let summary = out.last().unwrap().get("summary").unwrap();
        let session = summary.get("session").unwrap();
        assert_eq!(session.get("filtration_builds").unwrap().as_usize(), Some(1));
        let tenants = summary.get("tenants").unwrap();
        assert_eq!(
            tenants
                .get("b")
                .unwrap()
                .get("cache_hits")
                .unwrap()
                .as_usize(),
            Some(1)
        );
    }

    #[test]
    fn typed_errors_cross_the_wire() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let lines = concat!(
            r#"{"id":1,"method":"ingest","dataset":{"n":3,"edges":[[0,0,0.5]]}}"#,
            "\n",
            r#"{"id":2,"method":"query","handle":"hdeadbeef00000000","tau":0.5}"#,
            "\n",
            r#"{"id":3,"method":"nope"}"#,
            "\n",
            r#"this is not json"#,
            "\n",
        );
        let out = drive(&srv, lines);
        let e1 = out[0].get("error").unwrap();
        assert_eq!(e1.get("kind").unwrap().as_str(), Some("InvalidInput"));
        assert!(e1.get("message").unwrap().as_str().unwrap().contains("self-loop"));
        let e2 = out[1].get("error").unwrap();
        assert_eq!(e2.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e2.get("message").unwrap().as_str().unwrap().contains("evicted"));
        let e3 = out[2].get("error").unwrap();
        assert!(e3.get("message").unwrap().as_str().unwrap().contains("unknown method"));
        let e4 = out[3].get("error").unwrap();
        assert!(e4.get("message").unwrap().as_str().unwrap().contains("parse"));
        // Errors were counted against the (default) tenant.
        let summary = out.last().unwrap().get("summary").unwrap();
        let t = summary.get("tenants").unwrap().get("default").unwrap();
        assert_eq!(t.get("errors").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn negative_tau_refused_on_the_wire() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let out = drive(
            &srv,
            concat!(
                r#"{"id":1,"method":"ingest","dataset":{"kind":"circle","n":32,"seed":1}}"#,
                "\n",
                r#"{"id":2,"method":"ingest","tau":-1.0,"dataset":{"kind":"circle","n":32,"seed":1}}"#,
                "\n",
            ),
        );
        let key = out[0]
            .get("ok")
            .unwrap()
            .get("handle")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let e = out[1].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        // Negative τ on a query: typed refusal from the session guard.
        let q = format!(
            "{{\"id\":3,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":-0.25}}\n"
        );
        let out = drive(&srv, &q);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("non-negative"));
    }

    #[test]
    fn batch_is_concurrent_and_order_preserving() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let out = drive(
            &srv,
            concat!(
                r#"{"id":1,"method":"ingest","dataset":{"kind":"torus4","n":40,"seed":3}}"#,
                "\n",
            ),
        );
        let key = out[0]
            .get("ok")
            .unwrap()
            .get("handle")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let taus = [0.5, 0.8, 1.1, 1.4];
        let queries: Vec<String> = taus
            .iter()
            .map(|t| format!("{{\"tau\":{t},\"max_dim\":1,\"label\":\"t{t}\"}}"))
            .collect();
        let batch = format!(
            "{{\"id\":2,\"tenant\":\"c\",\"method\":\"batch\",\"handle\":\"{key}\",\"queries\":[{}]}}\n",
            queries.join(",")
        );
        let out = drive(&srv, &batch);
        let resps = out[0]
            .get("ok")
            .unwrap()
            .get("responses")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(resps.len(), taus.len());
        for (r, t) in resps.iter().zip(taus) {
            assert_eq!(r.get("tau").unwrap().as_f64(), Some(t));
            assert_eq!(r.get("label").unwrap().as_str(), Some(format!("t{t}").as_str()));
        }
        // Batch results match issuing the same queries serially.
        let h = srv.lookup(&Json::parse(&format!("{{\"handle\":\"{key}\"}}")).unwrap()).unwrap();
        for (r, t) in resps.iter().zip(taus) {
            let serial = srv
                .session
                .query(&h, &PhRequest {
                    tau: t,
                    max_dim: Some(1),
                    ..Default::default()
                })
                .unwrap();
            let betti = r.get("betti").unwrap().as_arr().unwrap();
            for dim in 0..=1usize {
                assert_eq!(
                    betti[dim].get("finite").unwrap().as_usize(),
                    Some(serial.result.diagram.finite(dim).len())
                );
                assert_eq!(
                    betti[dim].get("essential").unwrap().as_usize(),
                    Some(serial.result.diagram.essential_count(dim))
                );
            }
        }
    }

    #[test]
    fn bounded_batch_handles_more_queries_than_workers() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        // 12 queries on a threads:2 server: the bounded crew (2 workers)
        // must drain the whole batch in request order — the old
        // thread-per-query fan-out is gone.
        let srv = server();
        let out = drive(
            &srv,
            concat!(
                r#"{"id":1,"method":"ingest","dataset":{"kind":"circle","n":40,"seed":5}}"#,
                "\n",
            ),
        );
        let key = out[0]
            .get("ok")
            .unwrap()
            .get("handle")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let taus: Vec<f64> = (1..=12).map(|i| 0.1 * i as f64).collect();
        let queries: Vec<String> = taus
            .iter()
            .map(|t| format!("{{\"tau\":{t},\"max_dim\":1}}"))
            .collect();
        let batch = format!(
            "{{\"id\":2,\"tenant\":\"w\",\"method\":\"batch\",\"handle\":\"{key}\",\"queries\":[{}]}}\n",
            queries.join(",")
        );
        let out = drive(&srv, &batch);
        let resps = out[0]
            .get("ok")
            .unwrap()
            .get("responses")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(resps.len(), taus.len());
        for (r, t) in resps.iter().zip(&taus) {
            assert_eq!(r.get("tau").unwrap().as_f64(), Some(*t));
        }
        let summary = out.last().unwrap().get("summary").unwrap();
        let t = summary.get("tenants").unwrap().get("w").unwrap();
        assert_eq!(t.get("queries").unwrap().as_usize(), Some(12));
    }

    #[test]
    fn dataset_by_path_stream_ingests_on_the_wire() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let dir = std::env::temp_dir().join("dory-serve-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wire.coo");
        // A 4-cycle: one H1 class at τ ≥ 1.
        std::fs::write(&path, "0 1 1.0\n1 2 1.0\n2 3 1.0\n0 3 1.0\n").unwrap();
        let srv = server();
        let p = path.display();
        let out = drive(
            &srv,
            &format!(
                "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{p}\",\"edge_budget_mb\":1}}}}\n"
            ),
        );
        let ok = out[0].get("ok").unwrap();
        assert_eq!(ok.get("n_points").unwrap().as_usize(), Some(4));
        assert_eq!(ok.get("n_edges").unwrap().as_usize(), Some(4));
        let key = ok.get("handle").unwrap().as_str().unwrap().to_string();
        let out = drive(
            &srv,
            &format!("{{\"id\":2,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":1e999,\"max_dim\":1}}\n"),
        );
        let betti = out[0]
            .get("ok")
            .unwrap()
            .get("betti")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(betti[1].get("essential").unwrap().as_usize(), Some(1));
        // A malformed file is a typed InvalidInput on the wire.
        let bad = dir.join("wire-bad.coo");
        std::fs::write(&bad, "0 0 1.0\n").unwrap();
        let pb = bad.display();
        let out = drive(
            &srv,
            &format!("{{\"id\":3,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{pb}\"}}}}\n"),
        );
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("InvalidInput"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("self-loop"));
    }

    #[test]
    fn points_with_budget_stream_through_the_spill_store() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        // A unit square at τ=∞: identical topology from the in-memory
        // and the budgeted dense-stream ingests.
        let pts = r#"[[0,0],[1,0],[0,1],[1,1],[0.5,0.5],[0.2,0.8]]"#;
        let lines = format!(
            concat!(
                "{{\"id\":1,\"method\":\"ingest\",\"tau\":1e999,\"dataset\":{{\"points\":{p}}}}}\n",
                "{{\"id\":2,\"method\":\"ingest\",\"tau\":1e999,\"dataset\":{{\"points\":{p},\"edge_budget_mb\":1}}}}\n",
            ),
            p = pts
        );
        let out = drive(&srv, &lines);
        let inmem = out[0].get("ok").unwrap();
        let streamed = out[1].get("ok").unwrap();
        assert_eq!(inmem.get("edge_source").unwrap().as_str(), Some("native"));
        assert_eq!(
            streamed.get("edge_source").unwrap().as_str(),
            Some("dense-stream")
        );
        // Different fingerprints (the knob is part of the dataset JSON),
        // same edge set.
        assert_eq!(streamed.get("cached").unwrap().as_bool(), Some(false));
        assert_eq!(
            streamed.get("n_edges").unwrap().as_usize(),
            inmem.get("n_edges").unwrap().as_usize()
        );
        let k = streamed.get("dist_kernel").unwrap().as_str().unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&k), "{k}");
        // The summary's frontend block reports the selected kernel.
        let summary = out.last().unwrap().get("summary").unwrap();
        let fe = summary.get("frontend").unwrap();
        assert_eq!(fe.get("dist_kernel").unwrap().as_str(), Some(k));
        assert!(fe.get("dense_spilled_runs").is_some());
        assert!(fe.get("dense_staging_peak_bytes").is_some());
    }

    #[test]
    fn path_reingest_sees_file_changes() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let dir = std::env::temp_dir().join("dory-serve-stale");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stale.coo");
        std::fs::write(&path, "0 1 1.0\n").unwrap();
        let srv = server();
        let p = path.display();
        let line = format!("{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{p}\"}}}}\n");
        let out = drive(&srv, &line);
        let h1 = out[0].get("ok").unwrap().clone();
        assert_eq!(h1.get("n_edges").unwrap().as_usize(), Some(1));
        // Rewrite the file (different size): the same request line must
        // miss the cache and serve the new content, not the stale handle.
        std::fs::write(&path, "0 1 1.0\n1 2 1.0\n2 3 1.0\n").unwrap();
        let out = drive(&srv, &line);
        let h2 = out[0].get("ok").unwrap();
        assert_eq!(h2.get("cached").unwrap().as_bool(), Some(false));
        assert_eq!(h2.get("n_edges").unwrap().as_usize(), Some(3));
        assert_ne!(
            h1.get("handle").unwrap().as_str(),
            h2.get("handle").unwrap().as_str()
        );
    }

    #[test]
    fn oversized_edge_budget_is_a_typed_error() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let dir = std::env::temp_dir().join("dory-serve-budget");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.coo");
        std::fs::write(&path, "0 1 1.0\n").unwrap();
        let srv = server();
        let p = path.display();
        // 2^50 MiB would wrap usize when shifted to bytes — must be a
        // typed refusal, not a silently tiny (or unbounded) budget.
        let huge = 1u64 << 50;
        let out = drive(
            &srv,
            &format!(
                "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{p}\",\"edge_budget_mb\":{huge}}}}}\n"
            ),
        );
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("overflows"));
    }

    #[test]
    fn data_root_confines_path_ingest() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let root = std::env::temp_dir().join("dory-serve-root");
        std::fs::create_dir_all(&root).unwrap();
        let inside = root.join("in.coo");
        std::fs::write(&inside, "0 1 1.0\n").unwrap();
        let outside_dir = std::env::temp_dir().join("dory-serve-outside");
        std::fs::create_dir_all(&outside_dir).unwrap();
        let outside = outside_dir.join("out.coo");
        std::fs::write(&outside, "0 1 1.0\n").unwrap();
        let srv = Server::new(
            EngineOptions {
                threads: 2,
                ..Default::default()
            },
            64 << 20,
        )
        .with_data_root(root.clone());
        let pi = inside.display();
        let out = drive(
            &srv,
            &format!("{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{pi}\"}}}}\n"),
        );
        assert!(out[0].get("ok").is_some(), "{}", out[0].render());
        let po = outside.display();
        let out = drive(
            &srv,
            &format!("{{\"id\":2,\"method\":\"ingest\",\"dataset\":{{\"path\":\"{po}\"}}}}\n"),
        );
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("data root"));
    }

    /// Ingest a small circle and return its handle key.
    fn ingest_circle(srv: &Server, n: usize) -> String {
        let out = drive(
            srv,
            &format!(
                "{{\"id\":1,\"method\":\"ingest\",\"dataset\":{{\"kind\":\"circle\",\"n\":{n},\"seed\":7}}}}\n"
            ),
        );
        out[0]
            .get("ok")
            .unwrap()
            .get("handle")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string()
    }

    #[test]
    fn features_ride_the_wire_with_tenant_accounting() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let key = ingest_circle(&srv, 48);
        let q = format!(
            "{{\"id\":2,\"tenant\":\"f\",\"method\":\"query\",\"handle\":\"{key}\",\
             \"tau\":1e999,\"max_dim\":1,\"features\":[\"betti:8\",\"entropy\",\"representatives\"]}}\n"
        );
        let out = drive(&srv, &q);
        let ok = out[0].get("ok").unwrap();
        let feats = ok.get("features").unwrap();
        let items = feats.get("items").unwrap().as_arr().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].get("spec").unwrap().as_str(), Some("betti:8"));
        // betti:8 samples 9 points per dimension, dims 0..=1.
        let dims = items[0].get("dims").unwrap().as_arr().unwrap();
        assert_eq!(dims.len(), 2);
        assert_eq!(dims[0].as_arr().unwrap().len(), 9);
        // The circle yields at least one representative loop with
        // vertex and anchor payloads.
        let cycles = items[2].get("cycles").unwrap().as_arr().unwrap();
        assert!(!cycles.is_empty());
        assert!(cycles[0].get("vertices").unwrap().as_arr().unwrap().len() >= 3);
        let fs = ok.get("feature_stats").unwrap();
        assert_eq!(fs.get("specs").unwrap().as_usize(), Some(3));
        // Tenant accounting.
        let summary = out.last().unwrap().get("summary").unwrap();
        let t = summary.get("tenants").unwrap().get("f").unwrap();
        assert_eq!(t.get("feature_queries").unwrap().as_usize(), Some(1));
        assert_eq!(t.get("feature_specs").unwrap().as_usize(), Some(3));
        // A bad spec is a typed Request refusal.
        let bad = format!(
            "{{\"id\":3,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":1e999,\
             \"features\":[\"warp\"]}}\n"
        );
        let out = drive(&srv, &bad);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("unknown feature"));
    }

    #[test]
    fn diagram_flag_ships_points_and_cap_refuses_typed() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let key = ingest_circle(&srv, 48);
        let q = format!(
            "{{\"id\":2,\"tenant\":\"d\",\"method\":\"query\",\"handle\":\"{key}\",\
             \"tau\":1e999,\"max_dim\":1,\"diagram\":true}}\n"
        );
        let out = drive(&srv, &q);
        let ok = out[0].get("ok").unwrap();
        let dims = ok.get("diagram").unwrap().as_arr().unwrap();
        assert_eq!(dims.len(), 2);
        let mut total = 0usize;
        for (dim, entry) in dims.iter().enumerate() {
            assert_eq!(entry.get("dim").unwrap().as_usize(), Some(dim));
            let pts = entry.get("points").unwrap().as_arr().unwrap();
            total += pts.len();
            for p in pts {
                let pair = p.as_arr().unwrap();
                assert_eq!(pair.len(), 2);
                let b = pair[0].as_f64().unwrap();
                let d = pair[1].as_f64().unwrap();
                assert!(b.is_finite());
                assert!(d > b, "death must exceed birth: {b} {d}");
            }
        }
        // The essential H0 class crossed the wire as an infinite death.
        let h0 = dims[0].get("points").unwrap().as_arr().unwrap();
        assert!(h0
            .iter()
            .any(|p| p.as_arr().unwrap()[1].as_f64() == Some(f64::INFINITY)));
        assert!(total > 0);
        // diagram points are charged to the tenant.
        let summary = out.last().unwrap().get("summary").unwrap();
        let t = summary.get("tenants").unwrap().get("d").unwrap();
        assert_eq!(t.get("diagram_points").unwrap().as_usize(), Some(total));
        // Without the flag, no diagram field rides along.
        let q2 = format!(
            "{{\"id\":3,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":1e999,\"max_dim\":1}}\n"
        );
        let out = drive(&srv, &q2);
        assert!(out[0].get("ok").unwrap().get("diagram").is_none());
        // A capped server refuses the same payload with a typed error.
        let capped = Server::new(
            EngineOptions {
                threads: 2,
                ..Default::default()
            },
            64 << 20,
        )
        .with_max_diagram_points(2);
        let key = ingest_circle(&capped, 48);
        let q3 = format!(
            "{{\"id\":4,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":1e999,\
             \"max_dim\":1,\"diagram\":true}}\n"
        );
        let out = drive(&capped, &q3);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Request"));
        assert!(e
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("max-diagram-points"));
    }

    #[test]
    fn batch_queries_carry_features_and_diagram_flags() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let key = ingest_circle(&srv, 40);
        let batch = format!(
            "{{\"id\":2,\"tenant\":\"bf\",\"method\":\"batch\",\"handle\":\"{key}\",\"queries\":[\
             {{\"tau\":1e999,\"max_dim\":1,\"features\":[\"entropy\"]}},\
             {{\"tau\":1e999,\"max_dim\":1,\"diagram\":true}},\
             {{\"tau\":1e999,\"max_dim\":1}}]}}\n"
        );
        let out = drive(&srv, &batch);
        let resps = out[0]
            .get("ok")
            .unwrap()
            .get("responses")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(resps.len(), 3);
        assert!(resps[0].get("features").is_some());
        assert!(resps[0].get("diagram").is_none());
        assert!(resps[1].get("diagram").is_some());
        assert!(resps[1].get("features").is_none());
        assert!(resps[2].get("diagram").is_none());
        assert!(resps[2].get("features").is_none());
        let summary = out.last().unwrap().get("summary").unwrap();
        let t = summary.get("tenants").unwrap().get("bf").unwrap();
        assert_eq!(t.get("feature_queries").unwrap().as_usize(), Some(1));
        assert!(t.get("diagram_points").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn injected_query_panic_is_typed_internal_and_server_survives() {
        let _guard = failpoint::test_lock();
        failpoint::clear();
        let srv = server();
        let key = ingest_circle(&srv, 40);
        let q = format!("{{\"id\":9,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}\n");
        // Baseline betti, then the same query with a panic injected.
        let base = drive(&srv, &q);
        let want = base[0].get("ok").unwrap().get("betti").unwrap().render();
        failpoint::arm(failpoint::SERVE_QUERY_PANIC, failpoint::Trigger::Nth(1));
        let out = drive(&srv, &q);
        failpoint::clear();
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Internal"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("panicked"));
        // The server keeps serving the same handle, bit-identically.
        let again = drive(&srv, &q);
        let got = again[0].get("ok").unwrap().get("betti").unwrap().render();
        assert_eq!(got, want);
        let summary = again.last().unwrap().get("summary").unwrap();
        let rc = summary.get("resilience").unwrap();
        assert_eq!(rc.get("panics").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn overload_gate_sheds_typed_and_recovers_when_capacity_frees() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = Server::new(
            EngineOptions {
                threads: 2,
                ..Default::default()
            },
            64 << 20,
        )
        .with_overload(1, 1);
        let key = ingest_circle(&srv, 32);
        let q = format!("{{\"id\":5,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}\n");
        // Occupy the single slot, then try to serve: typed shed.
        let permit = srv.gate.admit("elsewhere").unwrap();
        let out = drive(&srv, &q);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Overloaded"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("capacity"));
        drop(permit);
        // Capacity freed: the same request now succeeds, and the shed
        // was counted (ingest + admit = the permit path works).
        let out = drive(&srv, &q);
        assert!(out[0].get("ok").is_some(), "{}", out[0].render());
        let summary = out.last().unwrap().get("summary").unwrap();
        let rc = summary.get("resilience").unwrap();
        assert_eq!(rc.get("shed").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn tenant_quota_sheds_one_tenant_without_starving_another() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = Server::new(
            EngineOptions {
                threads: 2,
                ..Default::default()
            },
            64 << 20,
        )
        .with_overload(8, 1);
        let key = ingest_circle(&srv, 32);
        // Tenant "a" holds its one slot; more "a" load sheds, "b" serves.
        let permit = srv.gate.admit("a").unwrap();
        let qa = format!("{{\"id\":6,\"tenant\":\"a\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}\n");
        let qb = format!("{{\"id\":7,\"tenant\":\"b\",\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4}}\n");
        let out = drive(&srv, &qa);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("Overloaded"));
        assert!(e.get("message").unwrap().as_str().unwrap().contains("tenant 'a'"));
        let out = drive(&srv, &qb);
        assert!(out[0].get("ok").is_some(), "{}", out[0].render());
        drop(permit);
    }

    #[test]
    fn zero_timeout_query_is_typed_deadline_and_handle_survives() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let key = ingest_circle(&srv, 40);
        let q = format!("{{\"id\":3,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1}}\n");
        let base = drive(&srv, &q);
        let want = base[0].get("ok").unwrap().get("betti").unwrap().render();
        let qt = format!(
            "{{\"id\":4,\"method\":\"query\",\"handle\":\"{key}\",\"tau\":0.4,\"max_dim\":1,\"timeout_ms\":0}}\n"
        );
        let out = drive(&srv, &qt);
        let e = out[0].get("error").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("DeadlineExceeded"));
        // The expired request left the handle fully serviceable.
        let again = drive(&srv, &q);
        let got = again[0].get("ok").unwrap().get("betti").unwrap().render();
        assert_eq!(got, want);
    }

    #[test]
    fn injected_response_write_fault_is_retried_transparently() {
        let _guard = failpoint::test_lock();
        failpoint::clear();
        let srv = server();
        failpoint::arm(failpoint::SERVE_WRITE, failpoint::Trigger::Nth(1));
        let out = drive(&srv, "{\"id\":1,\"method\":\"stats\"}\n");
        failpoint::clear();
        // Both the response and the trailer arrived despite the fault.
        assert!(out[0].get("ok").is_some());
        let summary = out.last().unwrap().get("summary").unwrap();
        let rc = summary.get("resilience").unwrap();
        assert!(rc.get("write_retries").unwrap().as_usize().unwrap() >= 1);
    }

    #[test]
    fn shutdown_stops_and_summarizes() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let srv = server();
        let out = drive(
            &srv,
            concat!(
                r#"{"id":1,"method":"shutdown"}"#,
                "\n",
                r#"{"id":2,"method":"stats"}"#,
                "\n",
            ),
        );
        // The post-shutdown request was never served.
        assert_eq!(out.len(), 2);
        assert_eq!(
            out[0]
                .get("ok")
                .unwrap()
                .get("stopping")
                .unwrap()
                .as_bool(),
            Some(true)
        );
        assert!(out[1].get("summary").is_some());
    }
}
