//! Streaming ingestion: chunked parse / tiled distance kernel, budgeted
//! spill store, k-way merge.
//!
//! Two producers feed the same spill machinery: the sparse-COO file
//! reader ([`stream_sparse_file`]) and the dense row-band front-end
//! ([`stream_dense_build`]), which routes the filtration tiles of an
//! in-memory point cloud or distance matrix straight into the
//! [`SpillStore`] so the full edge set never materializes in memory.
//!
//! The in-memory reader ([`super::read_sparse_coo`]) materializes every
//! entry before the front-end repacks and sorts them — three full-size
//! transients (entry vector, key vector, final arrays) that cap n long
//! before the reduction does. This module reads the file in line chunks,
//! validates each entry with the same typed rules as the in-memory path,
//! and bit-packs the `u128` filtration sort key per chunk. Keys stage
//! into a byte-budgeted [`SpillStore`]: once the in-memory run fills,
//! it is sorted (on the pool) and spilled to a temp file; at EOF the
//! sorted runs are k-way merged through small read buffers straight
//! into the final filtration arrays. Because edge keys are strictly
//! unique, the merged sequence is the globally sorted sequence no matter
//! how lines were chunked or runs were cut — the streamed filtration is
//! byte-identical to the in-memory one, so diagrams match at tol 0.
//!
//! A second (u64) spill store carries packed `(a, b)` vertex pairs for
//! out-of-core duplicate detection: value order does not make equal
//! pairs adjacent, so pairs get their own sorted merge, mirroring the
//! separate pair sort in `try_from_weighted_edges*`.
//!
//! Resident staging is `O(budget + chunk)`: the two run buffers are
//! allocated at their budget share and never grow, the line chunk is a
//! fixed-capacity scratch vector, and the spill-write / merge-read
//! buffers are scaled so their sum tracks the budget even when a small
//! budget cuts many runs. The final filtration arrays (the output
//! itself) are the only full-size allocation. Run filenames embed a
//! process-global store id, so concurrent ingests (multi-tenant
//! serving, parallel tests) sharing one temp dir never collide; stores
//! dropped on an error path remove their own run files.
//!
//! ## Fault tolerance
//!
//! Every spill-file operation is gated on a named failpoint
//! ([`crate::util::failpoint`]) and wrapped in a bounded
//! retry-with-backoff: run writes restart from a fresh file (the
//! partial file is removed between attempts), run re-opens retry
//! whole, and per-key merge reads retry only *injected* faults (a real
//! partial read loses the stream position). When a run write exhausts
//! its retries, a non-strict store **degrades** instead of failing: the
//! sorted run stays resident, further spilling stops, and the ingest
//! completes from memory with [`StreamStats::degraded`] set — the
//! merged key sequence (and thus every diagram) is bit-identical to the
//! fault-free run, only the staging profile changes. Strict mode
//! ([`StreamOptions::strict`]) surfaces the typed error instead.
//! [`sweep_orphaned_spills`] lets a server startup clear `dory-spill-*`
//! files abandoned by dead processes.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::DoryError;
use crate::util::failpoint::{self, RetryPolicy};
use crate::filtration::simd::{sq_prefilter_bound, Dist};
use crate::filtration::{
    edge_key, effective_tile, enclosing_radius_rowmax, sort_run_u128, unpack_edge_key,
    EdgeFiltration, FiltrationStats, FrontendOptions,
};
use crate::geometry::MetricData;
use crate::reduction::pool::ThreadPool;

use super::{duplicate_error, invalid, open, parse_coo_line, self_loop_error};

type Result<T> = std::result::Result<T, DoryError>;

/// Default lines parsed per chunk when `chunk_lines` is 0.
const DEFAULT_CHUNK_LINES: usize = 65_536;
/// Floor on keys per spilled run so pathological budgets still make
/// progress (and tests can force spills with tiny budgets).
const MIN_RUN_KEYS: usize = 64;
/// Ceiling on the buffered-I/O bytes per spill writer / merge reader.
const IO_BUF_MAX: usize = 64 << 10;
/// Floor on the same — below this, syscall-per-key I/O stops making
/// progress in any reasonable time.
const IO_BUF_MIN: usize = 256;

/// Process-global id source for [`SpillStore`] instances. Run filenames
/// embed it so two concurrent streamed ingests in one process (the
/// serving model is multi-tenant `&self`, and tests spill in parallel
/// within one binary) can never create, truncate, or delete each
/// other's run files.
static STORE_UID: AtomicU64 = AtomicU64::new(0);

/// Knobs for [`stream_sparse_file`] / `Session::ingest_sparse_file`.
#[derive(Clone, Debug, Default)]
pub struct StreamOptions {
    /// Lines parsed + packed per chunk (0 = 65536). Output is invariant
    /// to this; it only bounds the parse scratch buffer.
    pub chunk_lines: usize,
    /// Staging budget in bytes across both spill stores (0 = unbounded:
    /// everything stays in memory and nothing touches disk).
    pub budget_bytes: usize,
    /// Directory for spilled runs (`None` = `std::env::temp_dir()`).
    pub spill_dir: Option<PathBuf>,
    /// Refuse the degraded in-memory fallback: a spill write that fails
    /// after its bounded retries surfaces as a typed
    /// [`DoryError::Io`] instead of completing from memory. For callers
    /// whose byte budget is a hard isolation boundary.
    pub strict: bool,
}

/// Counters from one streamed ingest, for benches and budget asserts.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Non-blank, non-comment data lines parsed.
    pub lines: u64,
    /// Line chunks staged.
    pub chunks: u64,
    /// Validated entries (all of them, including those above τ).
    pub entries: u64,
    /// Entries with `d <= τ` that became filtration keys.
    pub kept: u64,
    /// Sorted runs spilled to disk (both stores).
    pub spilled_runs: u64,
    /// Bytes written to spill files.
    pub spilled_bytes: u64,
    /// Peak resident staging in bytes: run buffers, spill-write and
    /// k-way-merge read buffers (scaled to the budget so their sum
    /// stays within it), and the chunk scratch. Tracks `budget_bytes`
    /// (plus the chunk scratch), not the input size.
    pub staging_peak_bytes: usize,
    /// Transient spill I/O operations that were retried (writes
    /// restarted, injected read/open faults re-issued) before
    /// succeeding. Nonzero retries with `degraded == false` mean the
    /// backoff absorbed the faults entirely.
    pub io_retries: u64,
    /// The ingest fell back to in-memory staging after a spill write
    /// exhausted its retries (non-strict mode only). Output is
    /// bit-identical to the fault-free run; the byte budget was
    /// exceeded to keep the data.
    pub degraded: bool,
}

/// Fixed-width sortable key a [`SpillStore`] can stage and serialize.
pub(crate) trait SpillKey: Copy + Ord + Send {
    const BYTES: usize;
    fn encode(self) -> [u8; 16];
    fn decode(buf: &[u8]) -> Self;
    /// Sort one sealed run. The u128 edge-key impl rides the pooled
    /// front-end sort; order is what matters, and it is total.
    fn sort_run(keys: Vec<Self>, pool: Option<&ThreadPool>) -> Vec<Self>;
}

impl SpillKey for u64 {
    const BYTES: usize = 8;
    fn encode(self) -> [u8; 16] {
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&self.to_le_bytes());
        out
    }
    fn decode(buf: &[u8]) -> Self {
        u64::from_le_bytes(buf[..8].try_into().unwrap())
    }
    fn sort_run(mut keys: Vec<Self>, _pool: Option<&ThreadPool>) -> Vec<Self> {
        keys.sort_unstable();
        keys
    }
}

impl SpillKey for u128 {
    const BYTES: usize = 16;
    fn encode(self) -> [u8; 16] {
        self.to_le_bytes()
    }
    fn decode(buf: &[u8]) -> Self {
        u128::from_le_bytes(buf[..16].try_into().unwrap())
    }
    fn sort_run(keys: Vec<Self>, pool: Option<&ThreadPool>) -> Vec<Self> {
        sort_run_u128(keys, pool)
    }
}

/// Byte-budgeted staging area for sortable keys: buffer up to
/// `run_capacity` keys, then sort the run and spill it to a temp file.
/// [`SpillStore::finish`] hands back an iterator over the globally
/// sorted key sequence (pure in-memory when nothing spilled, a k-way
/// heap merge over buffered run readers otherwise).
pub(crate) struct SpillStore<K: SpillKey> {
    buf: Vec<K>,
    run_capacity: usize,
    budget_bytes: usize,
    dir: PathBuf,
    tag: &'static str,
    /// Process-unique instance id, part of every run filename.
    uid: u64,
    runs: Vec<PathBuf>,
    seq: usize,
    pub spilled_runs: u64,
    pub spilled_bytes: u64,
    pub peak_buf_bytes: usize,
    /// Refuse degradation: surface spill-write failures typed.
    strict: bool,
    /// A spill write failed past its retries and the store switched to
    /// resident staging (no further spill attempts).
    degraded: bool,
    /// Transient-I/O retry count, shared with the merge readers the
    /// store hands out (so read-side retries land in the same total).
    retries: Arc<AtomicU64>,
    policy: RetryPolicy,
}

impl<K: SpillKey> SpillStore<K> {
    /// `budget_bytes == 0` means unbounded (no spilling).
    pub fn new(budget_bytes: usize, dir: PathBuf, tag: &'static str) -> Self {
        let run_capacity = if budget_bytes == 0 {
            usize::MAX
        } else {
            (budget_bytes / K::BYTES).max(MIN_RUN_KEYS)
        };
        // Pre-size the budgeted buffer so pushes never reallocate past
        // the budget (Vec doubling would overshoot it by up to 2x).
        let buf = if budget_bytes == 0 {
            Vec::new()
        } else {
            Vec::with_capacity(run_capacity)
        };
        Self {
            buf,
            run_capacity,
            budget_bytes,
            dir,
            tag,
            uid: STORE_UID.fetch_add(1, Ordering::Relaxed),
            runs: Vec::new(),
            seq: 0,
            spilled_runs: 0,
            spilled_bytes: 0,
            peak_buf_bytes: 0,
            strict: false,
            degraded: false,
            retries: Arc::new(AtomicU64::new(0)),
            policy: RetryPolicy::default(),
        }
    }

    /// Configure failure handling: `strict` refuses the in-memory
    /// fallback, and `retries` (shared across the ingest's stores)
    /// accumulates every transient-I/O retry for [`StreamStats`].
    pub fn with_resilience(mut self, strict: bool, retries: Arc<AtomicU64>) -> Self {
        self.strict = strict;
        self.retries = retries;
        self
    }

    /// Buffered-I/O bytes per spill writer / merge reader, scaled so
    /// `parts` of them together stay within the store's byte budget
    /// (modulo the [`IO_BUF_MIN`] progress floor). Unbounded stores
    /// never spill, so their nominal buffer size is moot.
    fn io_buf_bytes(&self, parts: usize) -> usize {
        if self.budget_bytes == 0 {
            IO_BUF_MAX
        } else {
            (self.budget_bytes / parts.max(1)).clamp(IO_BUF_MIN, IO_BUF_MAX)
        }
    }

    pub fn push(&mut self, k: K, pool: Option<&ThreadPool>) -> Result<()> {
        self.buf.push(k);
        if self.buf.len() >= self.run_capacity {
            self.spill_run(pool)?;
        }
        Ok(())
    }

    fn note_peak(&mut self) {
        self.peak_buf_bytes = self.peak_buf_bytes.max(self.buf.len() * K::BYTES);
    }

    fn spill_run(&mut self, pool: Option<&ThreadPool>) -> Result<()> {
        // Resident while writing: the full run buffer plus the write
        // buffer — count both, so the reported staging peak is honest.
        let wcap = self.io_buf_bytes(4);
        self.peak_buf_bytes = self
            .peak_buf_bytes
            .max(self.buf.len() * K::BYTES + wcap);
        let fresh = if self.run_capacity == usize::MAX {
            Vec::new()
        } else {
            Vec::with_capacity(self.run_capacity)
        };
        let run = std::mem::replace(&mut self.buf, fresh);
        let sorted = K::sort_run(run, pool);
        let path = self.dir.join(format!(
            "dory-spill-{}-{}-{}-{}.run",
            self.tag,
            std::process::id(),
            self.uid,
            self.seq
        ));
        self.seq += 1;
        // Each write attempt starts from a fresh file (the cleanup hook
        // removes the partial one), so a retry is a clean rewrite of the
        // same sorted run — transient EIO/ENOSPC is absorbed without
        // changing a single output byte.
        let wrote = self.policy.run(
            &self.retries,
            || {
                failpoint::check(failpoint::SPILL_WRITE)?;
                let file = File::create(&path)?;
                let mut w = BufWriter::with_capacity(wcap, file);
                for &k in &sorted {
                    w.write_all(&k.encode()[..K::BYTES])?;
                }
                w.flush()
            },
            || {
                let _ = std::fs::remove_file(&path);
            },
        );
        match wrote {
            Ok(()) => {
                self.spilled_bytes += (sorted.len() * K::BYTES) as u64;
                self.spilled_runs += 1;
                self.runs.push(path);
                Ok(())
            }
            Err(e) => {
                let _ = std::fs::remove_file(&path);
                if self.strict {
                    return Err(DoryError::io(&path, e));
                }
                // Graceful degradation: keep the sorted run resident and
                // stop trying the disk. Later pushes append to the same
                // buffer (finish re-sorts it whole), so the merged key
                // sequence is unchanged — only the budget is exceeded.
                self.degraded = true;
                self.run_capacity = usize::MAX;
                self.buf = sorted;
                Ok(())
            }
        }
    }

    /// Seal the store, fold its spill counters into `totals`, and
    /// return the globally sorted key stream.
    pub fn finish(mut self, pool: Option<&ThreadPool>, totals: &mut RunTotals) -> Result<SpillIter<K>> {
        self.note_peak();
        totals.degraded |= self.degraded;
        if self.runs.is_empty() {
            totals.peak_buf_bytes += self.peak_buf_bytes;
            let sorted = K::sort_run(std::mem::take(&mut self.buf), pool);
            return Ok(SpillIter::Mem(sorted.into_iter()));
        }
        if !self.buf.is_empty() && !self.degraded {
            self.spill_run(pool)?;
            // The flush itself may have degraded; fall through with the
            // residual buffer as the resident side of the merge.
            totals.degraded |= self.degraded;
        }
        // A degraded store merges its resident (re-sorted) buffer
        // alongside whatever runs reached disk before the fault.
        let mem: Option<std::vec::IntoIter<K>> = if self.buf.is_empty() {
            None
        } else {
            Some(K::sort_run(std::mem::take(&mut self.buf), pool).into_iter())
        };
        totals.spilled_runs += self.spilled_runs;
        totals.spilled_bytes += self.spilled_bytes;
        // Merge residency is one read buffer per run (the run buffers
        // are already freed); report whichever phase peaked higher.
        let rcap = self.io_buf_bytes(self.runs.len());
        totals.peak_buf_bytes += self.peak_buf_bytes.max(self.runs.len() * rcap);
        let mut readers = Vec::with_capacity(self.runs.len());
        let mut heap = BinaryHeap::with_capacity(self.runs.len() + 1);
        for (i, path) in self.runs.iter().enumerate() {
            // Re-opening a freshly written run is side-effect free, so
            // transient open faults retry whole. Past the retries the
            // data on disk is unreachable — no degradation is possible,
            // the typed error propagates (Drop removes every run).
            let mut r = RunReader::<K>::open(path, rcap, Arc::clone(&self.retries), &self.policy)?;
            if let Some(k) = r.next()? {
                heap.push(Reverse((k, i)));
            }
            readers.push(r);
        }
        let mut merge = KWayMerge {
            readers,
            heap,
            mem,
            files: std::mem::take(&mut self.runs),
        };
        if let Some(it) = merge.mem.as_mut() {
            let mem_idx = merge.readers.len();
            if let Some(k) = it.next() {
                merge.heap.push(Reverse((k, mem_idx)));
            }
        }
        Ok(SpillIter::Merge(merge))
    }
}

impl<K: SpillKey> Drop for SpillStore<K> {
    /// Error paths (duplicate-pair detection, a failed merge open) drop
    /// the store without `finish` handing its runs to a [`KWayMerge`];
    /// remove whatever run files are still ours so nothing leaks into
    /// the temp dir. `finish` takes the runs out with `mem::take`, so a
    /// cleanly handed-off store drops with an empty list.
    fn drop(&mut self) {
        for p in &self.runs {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Spill counters accumulated across the stores of one streamed ingest.
#[derive(Default)]
pub(crate) struct RunTotals {
    pub spilled_runs: u64,
    pub spilled_bytes: u64,
    pub peak_buf_bytes: usize,
    /// Any store fell back to resident staging after a spill-write
    /// failure.
    pub degraded: bool,
}

struct RunReader<K: SpillKey> {
    r: BufReader<File>,
    path: PathBuf,
    retries: Arc<AtomicU64>,
    attempts: u32,
    _k: std::marker::PhantomData<K>,
}

impl<K: SpillKey> RunReader<K> {
    fn open(
        path: &Path,
        buf_bytes: usize,
        retries: Arc<AtomicU64>,
        policy: &RetryPolicy,
    ) -> Result<Self> {
        let file = policy
            .run(
                &retries,
                || {
                    failpoint::check(failpoint::MERGE_OPEN)?;
                    File::open(path)
                },
                || {},
            )
            .map_err(|e| DoryError::io(path, e))?;
        Ok(Self {
            r: BufReader::with_capacity(buf_bytes, file),
            path: path.to_path_buf(),
            retries,
            attempts: policy.attempts,
            _k: std::marker::PhantomData,
        })
    }

    fn next(&mut self) -> Result<Option<K>> {
        let mut buf = [0u8; 16];
        let slot = &mut buf[..K::BYTES];
        // Only *injected* faults are retried here: they fire before any
        // byte moves, so the stream position is intact and the read can
        // simply be re-issued. A real partial read has consumed an
        // unknown prefix — retrying would silently skip keys — so it
        // propagates typed immediately.
        let mut tries = 0u32;
        loop {
            if let Err(e) = failpoint::check(failpoint::SPILL_READ) {
                tries += 1;
                if tries < self.attempts.max(1) {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                return Err(DoryError::io(&self.path, e));
            }
            return match self.r.read_exact(slot) {
                Ok(()) => Ok(Some(K::decode(slot))),
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
                Err(e) => Err(DoryError::io(&self.path, e)),
            };
        }
    }
}

/// Sorted key stream out of a [`SpillStore`]: in-memory when nothing
/// spilled, a binary-heap k-way merge over run files otherwise. Run
/// files are deleted on drop.
pub(crate) enum SpillIter<K: SpillKey> {
    Mem(std::vec::IntoIter<K>),
    Merge(KWayMerge<K>),
}

impl<K: SpillKey> SpillIter<K> {
    pub fn next(&mut self) -> Result<Option<K>> {
        match self {
            SpillIter::Mem(it) => Ok(it.next()),
            SpillIter::Merge(m) => m.next(),
        }
    }
}

pub(crate) struct KWayMerge<K: SpillKey> {
    readers: Vec<RunReader<K>>,
    heap: BinaryHeap<Reverse<(K, usize)>>,
    /// Resident sorted run of a degraded store, merged as the source at
    /// heap index `readers.len()`.
    mem: Option<std::vec::IntoIter<K>>,
    files: Vec<PathBuf>,
}

impl<K: SpillKey> KWayMerge<K> {
    fn next(&mut self) -> Result<Option<K>> {
        let Some(Reverse((k, i))) = self.heap.pop() else {
            return Ok(None);
        };
        let refill = if i < self.readers.len() {
            self.readers[i].next()?
        } else {
            self.mem.as_mut().and_then(|it| it.next())
        };
        if let Some(nk) = refill {
            self.heap.push(Reverse((nk, i)));
        }
        Ok(Some(k))
    }
}

impl<K: SpillKey> Drop for KWayMerge<K> {
    fn drop(&mut self) {
        for p in &self.files {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Stream a sparse `i j d` file into an [`EdgeFiltration`] at threshold
/// `tau`, staging at most `opts.budget_bytes` (+ one line chunk) in
/// memory. Validation matches [`super::read_sparse_coo`] exactly —
/// malformed lines, NaN distances, self-loops, and duplicate pairs in
/// either orientation are typed [`DoryError::InvalidInput`] — and the
/// resulting filtration is byte-identical to the in-memory path's, so
/// downstream diagrams match at tol 0.
pub fn stream_sparse_file(
    path: &Path,
    tau: f64,
    opts: &StreamOptions,
    pool: Option<&ThreadPool>,
    fstats: &mut FiltrationStats,
) -> Result<(EdgeFiltration, StreamStats)> {
    let chunk_lines = if opts.chunk_lines == 0 {
        DEFAULT_CHUNK_LINES
    } else {
        opts.chunk_lines
    };
    let dir = opts
        .spill_dir
        .clone()
        .unwrap_or_else(std::env::temp_dir);
    // Budget split mirrors the per-entry byte ratio: 16B value key vs
    // 8B pair key.
    let (val_budget, pair_budget) = if opts.budget_bytes == 0 {
        (0, 0)
    } else {
        let vb = opts.budget_bytes * 2 / 3;
        (vb.max(1), (opts.budget_bytes - vb).max(1))
    };
    let retries = Arc::new(AtomicU64::new(0));
    let mut vals = SpillStore::<u128>::new(val_budget, dir.clone(), "keys")
        .with_resilience(opts.strict, Arc::clone(&retries));
    let mut pairs = SpillStore::<u64>::new(pair_budget, dir, "pairs")
        .with_resilience(opts.strict, Arc::clone(&retries));
    let mut st = StreamStats::default();

    let t_parse = Instant::now();
    let file = open(path)?;
    let mut r = BufReader::new(file);
    let mut line = String::new();
    let mut chunk: Vec<(u32, u32, f64)> = Vec::with_capacity(chunk_lines);
    let mut lineno = 0usize;
    let mut n = 0usize;

    let mut flush_chunk = |chunk: &mut Vec<(u32, u32, f64)>,
                           vals: &mut SpillStore<u128>,
                           pairs: &mut SpillStore<u64>,
                           st: &mut StreamStats|
     -> Result<()> {
        if chunk.is_empty() {
            return Ok(());
        }
        st.chunks += 1;
        for &(u, v, d) in chunk.iter() {
            pairs.push(((u as u64) << 32) | v as u64, pool)?;
            if d <= tau {
                vals.push(edge_key(d, u, v), pool)?;
                st.kept += 1;
            }
        }
        chunk.clear();
        Ok(())
    };

    loop {
        line.clear();
        // Injected read faults fire before any bytes move, so the
        // reader position is intact and a bounded re-issue is safe —
        // the same rule as the merge readers.
        let mut tries = 0u32;
        let read = loop {
            if let Err(e) = failpoint::check(failpoint::STREAM_READ) {
                tries += 1;
                if tries < RetryPolicy::default().attempts.max(1) {
                    retries.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                return Err(DoryError::io(path, e));
            }
            break r.read_line(&mut line).map_err(|e| DoryError::io(path, e))?;
        };
        if read == 0 {
            break;
        }
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        st.lines += 1;
        let (i, j, d) = parse_coo_line(t)
            .ok_or_else(|| invalid(path, format!("line {lineno}: expected `i j d`")))?;
        if d.is_nan() {
            return Err(invalid(
                path,
                format!("line {lineno}: sparse entry ({i}, {j}) is NaN"),
            ));
        }
        if i == j {
            return Err(self_loop_error(path, lineno, i));
        }
        let (u, v) = (i.min(j), i.max(j));
        n = n.max(v as usize + 1);
        st.entries += 1;
        chunk.push((u, v, d));
        if chunk.len() >= chunk_lines {
            flush_chunk(&mut chunk, &mut vals, &mut pairs, &mut st)?;
        }
    }
    flush_chunk(&mut chunk, &mut vals, &mut pairs, &mut st)?;
    if n > u32::MAX as usize {
        return Err(invalid(path, format!("vertex count {n} exceeds u32 range")));
    }
    fstats.dist_ns += t_parse.elapsed().as_nanos() as u64;

    let chunk_bytes = chunk.capacity() * std::mem::size_of::<(u32, u32, f64)>();
    drop(chunk);

    // Out-of-core duplicate detection: merged pair keys are globally
    // sorted, so a repeated pair (either orientation — entries were
    // normalized to u < v) shows up adjacent.
    let t_sort = Instant::now();
    let mut totals = RunTotals::default();
    let mut pit = pairs.finish(pool, &mut totals)?;
    let mut prev: Option<u64> = None;
    while let Some(k) = pit.next()? {
        if prev == Some(k) {
            return Err(duplicate_error(path, (k >> 32) as u32, k as u32));
        }
        prev = Some(k);
    }
    drop(pit);

    // Merge the value keys straight into the final filtration arrays —
    // the full sorted key vector is never materialized.
    let mut edges = Vec::with_capacity(st.kept as usize);
    let mut values = Vec::with_capacity(st.kept as usize);
    {
        let mut vit = vals.finish(pool, &mut totals)?;
        while let Some(k) = vit.next()? {
            let (d, a, b) = unpack_edge_key(k);
            edges.push((a, b));
            values.push(d);
        }
    }
    st.spilled_runs = totals.spilled_runs;
    st.spilled_bytes = totals.spilled_bytes;
    st.staging_peak_bytes = totals.peak_buf_bytes + chunk_bytes;
    st.io_retries = retries.load(Ordering::Relaxed);
    st.degraded = totals.degraded;
    fstats.sort_ns += t_sort.elapsed().as_nanos() as u64;
    fstats.f1_builds += 1;
    fstats.edges_considered += st.entries;
    fstats.edges_kept += edges.len() as u64;

    let f = EdgeFiltration {
        n: n as u32,
        edges,
        values,
        tau_max: tau,
    };
    Ok((f, st))
}

/// Build F1 for an in-memory dense input (point cloud or distance
/// matrix) with the row-band tiles streaming straight into a budgeted
/// [`SpillStore`], so resident staging is `O(budget + tile scratch)`
/// instead of the full kept edge set. Tiles are computed in waves of
/// ~`threads` on the pool (the same SIMD kernels as the in-memory
/// build), drained into the store as produced, and the k-way merge
/// unpacks straight into the final filtration arrays. Edge keys are
/// strictly unique, so the merged sequence is the globally sorted
/// sequence for every tile size and budget — the streamed filtration is
/// **byte-identical** to [`EdgeFiltration::build_pooled`] on the same
/// input, including the enclosing-radius truncation, which runs as a
/// standalone O(n)-memory row-max sweep before the thresholded pass.
pub fn stream_dense_build(
    data: &MetricData,
    tau_max: f64,
    opts: &StreamOptions,
    pool: Option<&ThreadPool>,
    fe: &FrontendOptions,
    fstats: &mut FiltrationStats,
) -> Result<(EdgeFiltration, StreamStats)> {
    if matches!(data, MetricData::Sparse(_)) {
        return Err(DoryError::InvalidInput(
            "dense streaming takes a point cloud or distance matrix; sparse files stream \
             through stream_sparse_file"
                .into(),
        ));
    }
    let n = data.n();
    if n >= u32::MAX as usize {
        return Err(DoryError::InvalidInput(format!(
            "vertex count {n} exceeds u32 range"
        )));
    }
    fstats.f1_builds += 1;
    let mut st = StreamStats::default();
    let t0 = Instant::now();
    // The enclosing radius must be known before tiles can be
    // thresholded into the store (the in-memory build fuses the sweep
    // with key emission, but provisional keys above r_enc would inflate
    // the spill volume here), so it runs as its own O(n)-memory pass.
    let r_enc = if fe.enclosing && tau_max == f64::INFINITY && n >= 2 {
        enclosing_radius_rowmax(data, pool, fe, fstats)
    } else {
        f64::INFINITY
    };
    fstats.enclosing_radius = r_enc;
    let tau_eff = if r_enc.is_finite() { r_enc } else { tau_max };

    let dist = Dist::new(data, fe.simd);
    fstats.dist_kernel = dist.kernel_name();
    let bound = sq_prefilter_bound(tau_eff);
    let dir = opts.spill_dir.clone().unwrap_or_else(std::env::temp_dir);
    let retries = Arc::new(AtomicU64::new(0));
    let mut store = SpillStore::<u128>::new(opts.budget_bytes, dir, "dense")
        .with_resilience(opts.strict, Arc::clone(&retries));

    let threads = pool.map_or(1, |p| p.threads());
    let tile = effective_tile(n, fe.tile, threads);
    let n_tiles = if n == 0 { 0 } else { n.div_ceil(tile) };
    let scratch_bytes = n * std::mem::size_of::<f64>();
    let mut wave_peak = 0usize;
    match pool {
        Some(pool) if pool.threads() > 1 && n >= 2 => {
            let wave = pool.threads();
            let mut w0 = 0usize;
            while w0 < n_tiles {
                let w1 = (w0 + wave).min(n_tiles);
                let slots: Vec<Mutex<Vec<u128>>> =
                    (w0..w1).map(|_| Mutex::new(Vec::new())).collect();
                {
                    let (dist, slots) = (&dist, &slots);
                    pool.run_stealing(w1 - w0, 1, |_tid, range| {
                        let mut scratch = vec![0f64; n];
                        for s in range {
                            let t = w0 + s;
                            let mut buf = Vec::new();
                            for i in t * tile..((t + 1) * tile).min(n) {
                                dist.fill_row(i, n, tau_eff, bound, &mut buf, &mut scratch);
                            }
                            *slots[s].lock().unwrap() = buf;
                        }
                    });
                }
                let mut wave_bytes = threads * scratch_bytes;
                for slot in slots {
                    let buf = slot.into_inner().unwrap();
                    wave_bytes += buf.capacity() * std::mem::size_of::<u128>();
                    for k in buf {
                        store.push(k, Some(pool))?;
                    }
                }
                wave_peak = wave_peak.max(wave_bytes);
                w0 = w1;
            }
            fstats.tiles += n_tiles as u64;
        }
        _ => {
            let mut scratch = vec![0f64; n];
            let mut buf: Vec<u128> = Vec::new();
            for t in 0..n_tiles {
                buf.clear();
                for i in t * tile..((t + 1) * tile).min(n) {
                    dist.fill_row(i, n, tau_eff, bound, &mut buf, &mut scratch);
                }
                wave_peak = wave_peak
                    .max(buf.capacity() * std::mem::size_of::<u128>() + scratch_bytes);
                for &k in &buf {
                    store.push(k, pool)?;
                }
            }
        }
    }
    st.chunks = n_tiles as u64;
    if n >= 2 {
        st.entries = (n * (n - 1) / 2) as u64;
    }
    fstats.dist_ns += t0.elapsed().as_nanos() as u64;

    // Merge the (unique) keys straight into the final filtration
    // arrays — the full sorted key vector is never materialized.
    let t_sort = Instant::now();
    let mut totals = RunTotals::default();
    let mut edges = Vec::new();
    let mut values = Vec::new();
    {
        let mut it = store.finish(pool, &mut totals)?;
        while let Some(k) = it.next()? {
            let (d, a, b) = unpack_edge_key(k);
            edges.push((a, b));
            values.push(d);
        }
    }
    fstats.sort_ns += t_sort.elapsed().as_nanos() as u64;
    st.kept = edges.len() as u64;
    st.spilled_runs = totals.spilled_runs;
    st.spilled_bytes = totals.spilled_bytes;
    st.staging_peak_bytes = totals.peak_buf_bytes + wave_peak;
    st.io_retries = retries.load(Ordering::Relaxed);
    st.degraded = totals.degraded;
    fstats.edges_considered += st.entries;
    fstats.edges_kept += st.kept;
    if r_enc.is_finite() {
        fstats.edges_pruned += st.entries - st.kept;
    }
    fstats.dense_spilled_runs += totals.spilled_runs;
    fstats.dense_spilled_bytes += totals.spilled_bytes;
    fstats.dense_staging_peak_bytes = fstats
        .dense_staging_peak_bytes
        .max(st.staging_peak_bytes as u64);

    let f = EdgeFiltration {
        n: n as u32,
        edges,
        values,
        tau_max: tau_eff,
    };
    Ok((f, st))
}

/// Remove `dory-spill-*.run` files abandoned in `dir` by processes that
/// no longer exist (a crashed ingest never runs its `Drop` cleanup).
/// Returns how many files were removed.
///
/// Conservative by construction: only filenames matching the exact run
/// pattern are considered, files whose embedded pid is this process or
/// a pid that is still alive (per `/proc`) are kept, and on platforms
/// without `/proc` liveness is unknowable so nothing is removed. Live
/// ingests by other processes are therefore never disturbed.
pub fn sweep_orphaned_spills(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    let proc_root = Path::new("/proc");
    if !proc_root.is_dir() {
        return 0;
    }
    let me = std::process::id();
    let mut removed = 0usize;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(body) = name
            .strip_prefix("dory-spill-")
            .and_then(|s| s.strip_suffix(".run"))
        else {
            continue;
        };
        // body = {tag}-{pid}-{uid}-{seq}; the numeric fields are the
        // last three (tags never contain '-').
        let mut fields = body.rsplitn(4, '-');
        let seq_ok = fields.next().is_some_and(|s| s.parse::<u64>().is_ok());
        let uid_ok = fields.next().is_some_and(|s| s.parse::<u64>().is_ok());
        let Some(pid) = fields.next().and_then(|s| s.parse::<u32>().ok()) else {
            continue;
        };
        if !seq_ok || !uid_ok || pid == me {
            continue;
        }
        if proc_root.join(pid.to_string()).exists() {
            continue; // owner is alive; its Drop will clean up
        }
        if std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dory-stream-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn spill_store_roundtrips_sorted_across_budgets() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        // 1000 pseudo-random unique u64 keys pushed unsorted; every
        // budget (including ones that force many tiny runs) must yield
        // the same sorted stream.
        let keys: Vec<u64> = (0..1000u64).map(|i| i.wrapping_mul(0x9E3779B97F4A7C15)).collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        for budget in [0usize, 512, 8 << 10, 1 << 20] {
            let mut store = SpillStore::<u64>::new(budget, tmp(""), "test");
            for &k in &keys {
                store.push(k, None).unwrap();
            }
            let mut totals = RunTotals::default();
            let mut it = store.finish(None, &mut totals).unwrap();
            let mut got = Vec::new();
            while let Some(k) = it.next().unwrap() {
                got.push(k);
            }
            assert_eq!(got, expect, "budget {budget}");
            if budget > 0 && budget < 1000 * 8 {
                assert!(totals.spilled_runs > 0, "budget {budget} should spill");
            }
        }
    }

    #[test]
    fn concurrent_spilling_stores_do_not_collide() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        // Four stores spilling the same tag into the same dir at the
        // same time: run filenames embed the store uid, so none may
        // truncate or delete another's runs — every merge must yield
        // the full sorted stream.
        let dir = std::env::temp_dir().join("dory-stream-concurrent");
        std::fs::create_dir_all(&dir).unwrap();
        let keys: Vec<u64> = (0..4000u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (keys, expect, dir) = (&keys, &expect, &dir);
                s.spawn(move || {
                    let mut store = SpillStore::<u64>::new(1024, dir.clone(), "race");
                    for &k in keys {
                        store.push(k, None).unwrap();
                    }
                    let mut totals = RunTotals::default();
                    let mut it = store.finish(None, &mut totals).unwrap();
                    let mut got = Vec::with_capacity(keys.len());
                    while let Some(k) = it.next().unwrap() {
                        got.push(k);
                    }
                    assert!(totals.spilled_runs > 0);
                    assert_eq!(&got, expect);
                });
            }
        });
    }

    #[test]
    fn error_paths_leave_no_spill_files_behind() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        // A duplicate pair detected mid-merge aborts the ingest while
        // the value store still holds spilled runs: its Drop (and the
        // pair merge's) must clear every run file from the spill dir.
        let dir = std::env::temp_dir().join("dory-stream-droptest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = tmp("drop-err.coo");
        let mut text = String::new();
        for i in 0..300u32 {
            text.push_str(&format!("{} {} 1.0\n", i, i + 1000));
        }
        text.push_str("0 1000 2.0\n");
        std::fs::write(&p, text).unwrap();
        let opts = StreamOptions {
            chunk_lines: 16,
            budget_bytes: 1024,
            spill_dir: Some(dir.clone()),
            strict: false,
        };
        let mut fs = FiltrationStats::default();
        let e = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap_err();
        assert!(e.to_string().contains("duplicate entry"), "{e}");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|d| d.unwrap().path())
            .collect();
        assert!(leftovers.is_empty(), "leaked spill files: {leftovers:?}");
    }

    /// Arm a failpoint for the scope of one test body, holding the
    /// crate-wide failpoint lock; disarms on drop (including panic).
    struct Armed(std::sync::MutexGuard<'static, ()>);

    fn armed(name: &str, trigger: failpoint::Trigger) -> Armed {
        let g = failpoint::test_lock();
        failpoint::clear();
        failpoint::arm(name, trigger);
        Armed(g)
    }

    impl Drop for Armed {
        fn drop(&mut self) {
            failpoint::clear();
        }
    }

    fn fault_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dory-stream-fault-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_coo(name: &str, n: u32) -> PathBuf {
        let p = tmp(name);
        let mut text = String::new();
        for i in 0..n {
            for j in (i + 1)..n {
                text.push_str(&format!("{} {} {}.5\n", i, j, (i + j) % 7 + 1));
            }
        }
        std::fs::write(&p, text).unwrap();
        p
    }

    fn assert_empty(dir: &Path) {
        let left: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .map(|d| d.unwrap().path())
            .collect();
        assert!(left.is_empty(), "leaked spill files: {left:?}");
    }

    #[test]
    fn spill_write_retry_then_succeed_is_bit_identical() {
        let p = write_coo("fault-retry.coo", 24);
        let dir = fault_dir("retry");
        let opts = StreamOptions {
            chunk_lines: 16,
            budget_bytes: 2048,
            spill_dir: Some(dir.clone()),
            strict: false,
        };
        let mut fs0 = FiltrationStats::default();
        let (want, base) =
            stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs0).unwrap();
        assert!(base.spilled_runs > 0, "budget must force spills");
        assert_empty(&dir);

        // The first write attempt fails, its retry succeeds: output
        // must be byte-identical with the fault fully absorbed.
        let _fp = armed(failpoint::SPILL_WRITE, failpoint::Trigger::Nth(1));
        let mut fs = FiltrationStats::default();
        let (got, st) = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap();
        assert!(st.io_retries >= 1, "the absorbed fault must be counted");
        assert!(!st.degraded);
        assert_eq!(st.spilled_runs, base.spilled_runs);
        assert_eq!(got.edges, want.edges);
        assert_eq!(
            got.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_empty(&dir);
    }

    #[test]
    fn unwritable_spill_degrades_to_memory_bit_identically() {
        let p = write_coo("fault-degrade.coo", 24);
        let dir = fault_dir("degrade");
        let opts = StreamOptions {
            chunk_lines: 16,
            budget_bytes: 2048,
            spill_dir: Some(dir.clone()),
            strict: false,
        };
        let mut fs0 = FiltrationStats::default();
        let (want, _) = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs0).unwrap();

        // Every write attempt fails: both stores must fall back to
        // resident staging and still produce the exact filtration.
        let _fp = armed(failpoint::SPILL_WRITE, failpoint::Trigger::Always);
        let mut fs = FiltrationStats::default();
        let (got, st) = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap();
        assert!(st.degraded, "exhausted retries must degrade, not fail");
        assert!(st.io_retries >= 1);
        assert_eq!(st.spilled_runs, 0, "nothing may reach disk");
        assert_eq!(got.edges, want.edges);
        assert_eq!(
            got.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_empty(&dir);
    }

    #[test]
    fn partial_spill_then_degrade_merges_disk_and_memory() {
        // Let a few runs reach disk, then cut the disk off mid-ingest:
        // the hybrid merge (surviving disk runs + the resident tail)
        // must still yield the exact sorted stream.
        let dir = fault_dir("hybrid");
        let _fp = armed(failpoint::SPILL_WRITE, failpoint::Trigger::Off);
        let keys: Vec<u64> = (0..4000u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        let retries = Arc::new(AtomicU64::new(0));
        let mut store = SpillStore::<u64>::new(1024, dir.clone(), "hybrid")
            .with_resilience(false, Arc::clone(&retries));
        let half = keys.len() / 2;
        for &k in &keys[..half] {
            store.push(k, None).unwrap();
        }
        assert!(store.spilled_runs >= 2, "first half must spill some runs");
        // From here every write fails: the remaining keys stay resident.
        failpoint::clear();
        failpoint::arm(failpoint::SPILL_WRITE, failpoint::Trigger::Always);
        for &k in &keys[half..] {
            store.push(k, None).unwrap();
        }
        let disk_runs = store.spilled_runs;
        let mut totals = RunTotals::default();
        let mut it = store.finish(None, &mut totals).unwrap();
        let mut got = Vec::with_capacity(keys.len());
        while let Some(k) = it.next().unwrap() {
            got.push(k);
        }
        drop(it);
        assert_eq!(got, expect, "hybrid disk+memory merge must be exact");
        assert!(totals.degraded);
        assert_eq!(totals.spilled_runs, disk_runs);
        assert!(disk_runs >= 2);
        assert!(retries.load(Ordering::Relaxed) >= 1);
        assert_empty(&dir);
    }

    #[test]
    fn strict_mode_refuses_degradation_typed() {
        let p = write_coo("fault-strict.coo", 24);
        let dir = fault_dir("strict");
        let opts = StreamOptions {
            chunk_lines: 16,
            budget_bytes: 2048,
            spill_dir: Some(dir.clone()),
            strict: true,
        };
        let _fp = armed(failpoint::SPILL_WRITE, failpoint::Trigger::Always);
        let mut fs = FiltrationStats::default();
        let e = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap_err();
        assert!(matches!(e, DoryError::Io(_)), "{e}");
        assert!(e.to_string().contains("failpoint"), "{e}");
        assert_empty(&dir);
    }

    #[test]
    fn merge_open_failure_is_typed_and_leaves_no_files() {
        let p = write_coo("fault-open.coo", 24);
        let dir = fault_dir("open");
        let opts = StreamOptions {
            chunk_lines: 16,
            budget_bytes: 2048,
            spill_dir: Some(dir.clone()),
            strict: false,
        };
        let _fp = armed(failpoint::MERGE_OPEN, failpoint::Trigger::Always);
        let mut fs = FiltrationStats::default();
        let e = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap_err();
        assert!(matches!(e, DoryError::Io(_)), "{e}");
        assert_empty(&dir);
    }

    #[test]
    fn stream_read_fault_retries_then_propagates() {
        let p = write_coo("fault-read.coo", 8);
        let dir = fault_dir("read");
        let opts = StreamOptions {
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        // One injected line-read fault: absorbed by the bounded retry.
        {
            let _fp = armed(failpoint::STREAM_READ, failpoint::Trigger::Nth(1));
            let mut fs = FiltrationStats::default();
            let (_, st) =
                stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap();
            assert!(st.io_retries >= 1);
        }
        // A persistent fault exhausts the retries and surfaces typed.
        {
            let _fp = armed(failpoint::STREAM_READ, failpoint::Trigger::Always);
            let mut fs = FiltrationStats::default();
            let e = stream_sparse_file(&p, f64::INFINITY, &opts, None, &mut fs).unwrap_err();
            assert!(matches!(e, DoryError::Io(_)), "{e}");
        }
        assert_empty(&dir);
    }

    #[test]
    fn sweep_removes_only_dead_process_runs() {
        if !Path::new("/proc").is_dir() {
            return; // liveness is unknowable here; the sweep is a no-op
        }
        let dir = fault_dir("sweep");
        let me = std::process::id();
        // A pid that cannot exist (beyond every Linux pid_max).
        let dead = u32::MAX;
        let orphan = dir.join(format!("dory-spill-keys-{dead}-0-0.run"));
        let mine = dir.join(format!("dory-spill-keys-{me}-1-0.run"));
        let odd = dir.join("dory-spill-keys-notapid-2-0.run");
        let other = dir.join("other-file.run");
        for f in [&orphan, &mine, &odd, &other] {
            std::fs::write(f, b"x").unwrap();
        }
        let removed = sweep_orphaned_spills(&dir);
        assert_eq!(removed, 1, "exactly the dead process's run goes");
        assert!(!orphan.exists());
        assert!(mine.exists(), "a live owner's runs are untouchable");
        assert!(odd.exists(), "unparseable names are left alone");
        assert!(other.exists(), "non-spill files are left alone");
        assert_eq!(sweep_orphaned_spills(&dir), 0, "sweep is idempotent");
    }

    #[test]
    fn streamed_validation_matches_reader() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let p = tmp("val.coo");
        std::fs::write(&p, "0 1 1.0\n3 3 2.0\n").unwrap();
        let mut fs = FiltrationStats::default();
        let e = stream_sparse_file(&p, f64::INFINITY, &StreamOptions::default(), None, &mut fs)
            .unwrap_err();
        assert!(e.to_string().contains("self-loop"), "{e}");

        std::fs::write(&p, "0 1 1.0\n1 2 2.0\n1 0 3.0\n").unwrap();
        let e = stream_sparse_file(&p, f64::INFINITY, &StreamOptions::default(), None, &mut fs)
            .unwrap_err();
        assert!(e.to_string().contains("duplicate entry (0, 1)"), "{e}");

        std::fs::write(&p, "0 1 NaN\n").unwrap();
        let e = stream_sparse_file(&p, f64::INFINITY, &StreamOptions::default(), None, &mut fs)
            .unwrap_err();
        assert!(e.to_string().contains("NaN"), "{e}");

        std::fs::write(&p, "0 oops 1.0\n").unwrap();
        let e = stream_sparse_file(&p, f64::INFINITY, &StreamOptions::default(), None, &mut fs)
            .unwrap_err();
        assert!(e.to_string().contains("expected `i j d`"), "{e}");
    }

    #[test]
    fn dense_streaming_is_bit_identical_across_budgets_and_tiles() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0xDE5E);
        let pc = crate::geometry::PointCloud::new(
            3,
            (0..60 * 3).map(|_| rng.next_f64()).collect(),
        );
        let md = MetricData::Points(pc);
        let pool = ThreadPool::new(4);
        for tau in [0.6, f64::INFINITY] {
            let mut want_stats = FiltrationStats::default();
            let want = EdgeFiltration::build_pooled(
                &md,
                tau,
                Some(&pool),
                &FrontendOptions::default(),
                &mut want_stats,
            );
            for budget in [0usize, 2048, 1 << 20] {
                for tile in [0usize, 1, 7] {
                    let opts = StreamOptions {
                        budget_bytes: budget,
                        spill_dir: Some(tmp("")),
                        ..Default::default()
                    };
                    let fe = FrontendOptions {
                        tile,
                        ..Default::default()
                    };
                    let mut fs = FiltrationStats::default();
                    for p in [None, Some(&pool)] {
                        let (f, st) =
                            stream_dense_build(&md, tau, &opts, p, &fe, &mut fs).unwrap();
                        assert_eq!(f.edges, want.edges, "tau={tau} budget={budget} tile={tile}");
                        let wb: Vec<u64> = want.values.iter().map(|v| v.to_bits()).collect();
                        let fb: Vec<u64> = f.values.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(wb, fb);
                        assert_eq!(f.tau_max.to_bits(), want.tau_max.to_bits());
                        assert_eq!(
                            fs.enclosing_radius.to_bits(),
                            want_stats.enclosing_radius.to_bits()
                        );
                        if budget == 2048 {
                            assert!(st.spilled_runs > 0, "2 KiB budget must spill");
                            assert!(fs.dense_spilled_runs > 0);
                        }
                        assert!(st.kept as usize == f.n_edges());
                        assert!(!fs.dist_kernel.is_empty());
                    }
                }
            }
        }
    }

    #[test]
    fn dense_streaming_rejects_sparse_inputs() {
        let sd = MetricData::Sparse(crate::geometry::SparseDistances {
            n: 3,
            entries: vec![(0, 1, 1.0)],
        });
        let mut fs = FiltrationStats::default();
        let e = stream_dense_build(
            &sd,
            f64::INFINITY,
            &StreamOptions::default(),
            None,
            &FrontendOptions::default(),
            &mut fs,
        )
        .unwrap_err();
        assert!(e.to_string().contains("dense streaming"), "{e}");
    }

    #[test]
    fn tau_filter_applies_at_the_reader() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = failpoint::test_lock();
        let p = tmp("tau.coo");
        std::fs::write(&p, "0 1 1.0\n1 2 5.0\n0 2 2.0\n").unwrap();
        let mut fs = FiltrationStats::default();
        let (f, st) =
            stream_sparse_file(&p, 3.0, &StreamOptions::default(), None, &mut fs).unwrap();
        assert_eq!(st.entries, 3);
        assert_eq!(st.kept, 2);
        assert_eq!(f.n_edges(), 2);
        assert_eq!(f.edges, vec![(0, 1), (0, 2)]);
        assert_eq!(f.values, vec![1.0, 2.0]);
    }
}
