//! Loaders and writers: point clouds, distance matrices, sparse distance
//! lists, persistence diagrams (CSV/JSON).
//!
//! Formats match the ecosystem the paper benchmarks against: whitespace/
//! comma-separated point files (Ripser's `point-cloud` input),
//! lower-triangular distance matrices (`lower-distance`), and `i j d`
//! sparse COO lists (the Hi-C inputs).
//!
//! Every reader/writer returns a typed [`DoryError`] — [`DoryError::Io`]
//! for filesystem failures (tagged with the path),
//! [`DoryError::InvalidInput`] for malformed or NaN content — so a
//! service can branch on the failure class instead of parsing panic
//! text.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::DoryError;
use crate::geometry::{DenseDistances, MetricData, PointCloud, SparseDistances};
use crate::homology::diagram::Diagram;
use crate::util::json::Json;

pub mod stream;

type Result<T> = std::result::Result<T, DoryError>;

fn open(path: &Path) -> Result<std::fs::File> {
    std::fs::File::open(path).map_err(|e| DoryError::io(path, e))
}

fn invalid(path: &Path, msg: impl std::fmt::Display) -> DoryError {
    DoryError::InvalidInput(format!("{path:?}: {msg}"))
}

/// Load a point cloud: one point per line, comma/space separated floats.
pub fn read_points(path: &Path) -> Result<MetricData> {
    let file = open(path)?;
    let mut coords = Vec::new();
    let mut dim = 0usize;
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line.map_err(|e| DoryError::io(path, e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let row: Vec<f64> = t
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|e| invalid(path, format!("line {}: {e}", lineno + 1)))
            })
            .collect::<Result<_>>()?;
        if dim == 0 {
            dim = row.len();
        } else if row.len() != dim {
            return Err(invalid(
                path,
                format!(
                    "line {}: expected {dim} coordinates, got {}",
                    lineno + 1,
                    row.len()
                ),
            ));
        }
        coords.extend(row);
    }
    if dim == 0 {
        return Err(invalid(path, "no points"));
    }
    validated(MetricData::Points(PointCloud::new(dim, coords)), path)
}

/// Reject bad metric inputs (NaN, malformed sparse entries) at
/// ingestion with a typed error naming the offending entry — the
/// front-end either panics opaquely or silently drops them otherwise.
fn validated(data: MetricData, path: &Path) -> Result<MetricData> {
    match data.validate() {
        Ok(()) => Ok(data),
        Err(e) => Err(invalid(path, format!("invalid metric input: {e}"))),
    }
}

/// Load a lower-triangular distance matrix: row i has i entries
/// (d(i,0) .. d(i,i-1)), comma/space separated; blank/comment lines skipped.
pub fn read_lower_distance(path: &Path) -> Result<MetricData> {
    let file = open(path)?;
    let mut tri = Vec::new();
    // Row 0 is implicit (zero entries); the k-th data line holds the k+1
    // distances d(k+1, 0..=k).
    let mut rows = 1usize;
    for line in std::io::BufReader::new(file).lines() {
        let line = line.map_err(|e| DoryError::io(path, e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let row: Vec<f64> = t
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|e| invalid(path, format!("data line {rows}: {e}")))
            })
            .collect::<Result<_>>()?;
        if row.len() != rows {
            return Err(invalid(
                path,
                format!("data line {rows} must have {rows} entries, got {}", row.len()),
            ));
        }
        tri.extend(row);
        rows += 1;
    }
    validated(MetricData::Dense(DenseDistances::new(rows, tri)), path)
}

/// Parse one `i j d` sparse-COO data line (extra trailing tokens
/// ignored, matching the historical reader). Shared with the streaming
/// reader so both front doors accept the identical grammar.
pub(crate) fn parse_coo_line(t: &str) -> Option<(u32, u32, f64)> {
    let mut it = t.split_whitespace();
    Some((
        it.next()?.parse().ok()?,
        it.next()?.parse().ok()?,
        it.next()?.parse().ok()?,
    ))
}

/// Typed rejection for a self-loop `i i d` line — the same contract
/// `from_weighted_edges*` enforces for API ingestion, so file and wire
/// inputs agree instead of the file path silently dropping the entry.
pub(crate) fn self_loop_error(path: &Path, lineno: usize, i: u32) -> DoryError {
    invalid(
        path,
        format!("line {lineno}: self-loop entry ({i}, {i}); Rips edges join distinct vertices"),
    )
}

/// Typed rejection for a vertex pair seen twice (in either orientation).
pub(crate) fn duplicate_error(path: &Path, a: u32, b: u32) -> DoryError {
    invalid(
        path,
        format!("duplicate entry ({a}, {b}); pairs must be unique up to orientation"),
    )
}

/// Find a repeated pair among packed `(a << 32) | b` keys. Sorts in
/// place; duplicates become adjacent because keys are unique per pair.
pub(crate) fn find_duplicate_pair(pairs: &mut [u64]) -> Option<(u32, u32)> {
    pairs.sort_unstable();
    pairs
        .windows(2)
        .find(|w| w[0] == w[1])
        .map(|w| ((w[0] >> 32) as u32, w[0] as u32))
}

/// Load a sparse COO distance list: `i j d` per line (0-based).
///
/// Self-loops and duplicate pairs (in either orientation) are refused
/// with typed [`DoryError::InvalidInput`] — the same validation
/// `from_weighted_edges*` applies to API ingestion. A duplicate pair
/// would otherwise corrupt the CSR degree counts downstream.
pub fn read_sparse_coo(path: &Path) -> Result<MetricData> {
    let file = open(path)?;
    let mut entries = Vec::new();
    let mut n = 0usize;
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line.map_err(|e| DoryError::io(path, e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let (i, j, d) = parse_coo_line(t)
            .ok_or_else(|| invalid(path, format!("line {}: expected `i j d`", lineno + 1)))?;
        if i == j {
            return Err(self_loop_error(path, lineno + 1, i));
        }
        let (u, v) = (i.min(j), i.max(j));
        n = n.max(v as usize + 1);
        entries.push((u, v, d));
    }
    let mut pairs: Vec<u64> = entries
        .iter()
        .map(|&(u, v, _)| ((u as u64) << 32) | v as u64)
        .collect();
    if let Some((a, b)) = find_duplicate_pair(&mut pairs) {
        return Err(duplicate_error(path, a, b));
    }
    validated(MetricData::Sparse(SparseDistances { n, entries }), path)
}

/// Write a point cloud (for round-trips and dataset export).
pub fn write_points(path: &Path, pc: &PointCloud) -> Result<()> {
    let file = std::fs::File::create(path).map_err(|e| DoryError::io(path, e))?;
    let mut w = BufWriter::new(file);
    for i in 0..pc.n() {
        let row: Vec<String> = pc.point(i).iter().map(|x| format!("{x}")).collect();
        writeln!(w, "{}", row.join(" ")).map_err(|e| DoryError::io(path, e))?;
    }
    Ok(())
}

/// Write a sparse distance list (`i j d`).
pub fn write_sparse_coo(path: &Path, sd: &SparseDistances) -> Result<()> {
    let file = std::fs::File::create(path).map_err(|e| DoryError::io(path, e))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# n={}", sd.n).map_err(|e| DoryError::io(path, e))?;
    for &(i, j, d) in &sd.entries {
        writeln!(w, "{i} {j} {d}").map_err(|e| DoryError::io(path, e))?;
    }
    Ok(())
}

/// Persistence diagram as CSV: `dim,birth,death` (death `inf` for
/// essential classes) — the format the plotting scripts consume.
pub fn write_diagram_csv(path: &Path, d: &Diagram) -> Result<()> {
    let file = std::fs::File::create(path).map_err(|e| DoryError::io(path, e))?;
    let mut w = BufWriter::new(file);
    let werr = |e: std::io::Error| DoryError::io(path, e);
    writeln!(w, "dim,birth,death").map_err(werr)?;
    for dim in 0..=d.max_dim() {
        for p in d.points(dim) {
            if p.is_essential() {
                writeln!(w, "{dim},{},inf", p.birth).map_err(werr)?;
            } else {
                writeln!(w, "{dim},{},{}", p.birth, p.death).map_err(werr)?;
            }
        }
    }
    Ok(())
}

/// Persistence diagram as JSON (per-dim arrays of [birth, death]).
pub fn diagram_to_json(d: &Diagram) -> Json {
    let mut obj = Json::obj();
    for dim in 0..=d.max_dim() {
        let mut arr = Json::arr();
        for p in d.points(dim) {
            let mut pt = Json::arr();
            pt.push(p.birth);
            pt.push(p.death);
            arr.push(pt);
        }
        obj = obj.field(&format!("H{dim}"), arr);
    }
    obj
}

pub fn write_diagram_json(path: &Path, d: &Diagram) -> Result<()> {
    std::fs::write(path, diagram_to_json(d).render()).map_err(|e| DoryError::io(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dory-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn points_roundtrip() {
        let pc = PointCloud::new(3, vec![1.0, 2.0, 3.0, 4.5, 5.5, 6.5]);
        let p = tmp("pts.xyz");
        write_points(&p, &pc).unwrap();
        match read_points(&p).unwrap() {
            MetricData::Points(q) => {
                assert_eq!(q.dim, 3);
                assert_eq!(q.coords, pc.coords);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lower_distance_parses() {
        let p = tmp("ldm.txt");
        std::fs::write(&p, "\n1.0\n2.0 3.0\n").unwrap();
        match read_lower_distance(&p).unwrap() {
            MetricData::Dense(d) => {
                assert_eq!(d.n, 3);
                assert_eq!(d.get(1, 0), 1.0);
                assert_eq!(d.get(2, 0), 2.0);
                assert_eq!(d.get(2, 1), 3.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn sparse_roundtrip() {
        let sd = SparseDistances {
            n: 5,
            entries: vec![(0, 3, 1.25), (1, 4, 2.5)],
        };
        let p = tmp("coo.txt");
        write_sparse_coo(&p, &sd).unwrap();
        match read_sparse_coo(&p).unwrap() {
            MetricData::Sparse(q) => {
                assert_eq!(q.n, 5);
                assert_eq!(q.entries, sd.entries);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn malformed_inputs_are_typed_invalid_input() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "1.0 2.0\n3.0\n").unwrap();
        assert!(matches!(
            read_points(&p).unwrap_err(),
            DoryError::InvalidInput(_)
        ));
        std::fs::write(&p, "not a number\n").unwrap();
        assert!(matches!(
            read_points(&p).unwrap_err(),
            DoryError::InvalidInput(_)
        ));
        // Missing files are Io, not InvalidInput.
        assert!(matches!(
            read_points(std::path::Path::new("/definitely/not/here.xyz")).unwrap_err(),
            DoryError::Io(_)
        ));
    }

    #[test]
    fn nan_inputs_rejected_at_ingestion() {
        let p = tmp("nan-pts.txt");
        std::fs::write(&p, "0.0 0.0\nNaN 1.0\n").unwrap();
        let e = read_points(&p).unwrap_err();
        assert!(matches!(e, DoryError::InvalidInput(_)), "{e}");
        assert!(e.to_string().contains("NaN"), "{e}");
        let p = tmp("nan-ldm.txt");
        std::fs::write(&p, "1.0\nNaN 2.0\n").unwrap();
        assert!(read_lower_distance(&p).unwrap_err().to_string().contains("NaN"));
        let p = tmp("nan-coo.txt");
        std::fs::write(&p, "0 1 NaN\n").unwrap();
        assert!(read_sparse_coo(&p).unwrap_err().to_string().contains("NaN"));
    }

    #[test]
    fn sparse_self_loops_and_duplicates_rejected() {
        // Regression: the reader used to `continue` past self-loops and
        // accept duplicate pairs that the weighted-edge API refuses.
        let p = tmp("loop-coo.txt");
        std::fs::write(&p, "0 1 1.0\n2 2 0.5\n").unwrap();
        let e = read_sparse_coo(&p).unwrap_err();
        assert!(matches!(e, DoryError::InvalidInput(_)), "{e}");
        assert!(e.to_string().contains("self-loop"), "{e}");
        assert!(e.to_string().contains("line 2"), "{e}");

        // Reversed orientation of the same pair is still a duplicate.
        let p = tmp("dup-coo.txt");
        std::fs::write(&p, "0 1 1.0\n2 3 2.0\n1 0 1.5\n").unwrap();
        let e = read_sparse_coo(&p).unwrap_err();
        assert!(matches!(e, DoryError::InvalidInput(_)), "{e}");
        assert!(e.to_string().contains("duplicate entry (0, 1)"), "{e}");
    }

    #[test]
    fn diagram_csv_format() {
        let mut d = Diagram::new(1);
        d.push(0, 0.0, 1.5);
        d.push(1, 0.5, f64::INFINITY);
        let p = tmp("pd.csv");
        write_diagram_csv(&p, &d).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("0,0,1.5"));
        assert!(s.contains("1,0.5,inf"));
    }
}
