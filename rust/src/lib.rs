//! # Dory — scalable persistent homology for Vietoris–Rips filtrations
//!
//! A reproduction of *"Dory: Overcoming Barriers to Computing Persistent
//! Homology"* (Aggarwal & Periwal, 2021) as a three-layer Rust + JAX/Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: paired-indexing,
//!   on-the-fly coboundary cursors, the fast implicit column reduction,
//!   trivial-pair shortcuts, clearing, and the serial–parallel batch
//!   scheduler over a persistent thread pool.
//! * **Layer 2/1 (`python/compile`)** — JAX + Pallas kernels (pairwise
//!   distances, persistence images) AOT-lowered to HLO text, executed from
//!   Rust through PJRT (`runtime`). Python never runs on the request path.
//!
//! Entry points: [`homology::engine`] for the full pipeline,
//! [`coordinator`] for config-driven runs, `examples/` for walkthroughs.

pub mod baselines;
pub mod bench_support;
pub mod coboundary;
pub mod coordinator;
pub mod datasets;
pub mod filtration;
pub mod geometry;
pub mod hic;
pub mod io;
pub mod homology;
pub mod reduction;
pub mod runtime;
pub mod util;

use util::memtrack::CountingAlloc;

/// Heap accounting is part of the deliverable (the paper reports peak
/// memory per run); the counting allocator backs every binary and test.
#[global_allocator]
static GLOBAL_ALLOC: CountingAlloc = CountingAlloc;
