//! # Dory — scalable persistent homology for Vietoris–Rips filtrations
//!
//! A reproduction of *"Dory: Overcoming Barriers to Computing Persistent
//! Homology"* (Aggarwal & Periwal, 2021) as a three-layer Rust + JAX/Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: paired-indexing,
//!   on-the-fly coboundary cursors, the fast implicit column reduction,
//!   trivial-pair shortcuts, clearing, and a **pipelined work-stealing
//!   serial–parallel scheduler** over a persistent thread pool.
//! * **Layer 2/1 (`python/compile`)** — JAX + Pallas kernels (pairwise
//!   distances, persistence images) AOT-lowered to HLO text, executed from
//!   Rust through PJRT (`runtime`, behind the `pjrt` cargo feature; the
//!   default build ships a graceful native-fallback stub). Python never
//!   runs on the request path.
//!
//! ## The three-stage pipelined scheduler
//!
//! The hot path — reduction of the coboundary columns — runs on
//! [`reduction::serial_parallel`], which rebuilt the paper's §4.4
//! batched scheduler around three ideas:
//!
//! * **work stealing** ([`reduction::pool::ThreadPool`]): a batch is
//!   split into small tasks dealt into per-worker deques; idle workers
//!   steal from the back of a victim's deque, so one slow column no
//!   longer stalls the pool the way fixed chunks did;
//! * **phase pipelining**: while the scheduler thread serially commits
//!   batch *k* (into a delta overlaid on a frozen base state), the pool
//!   is already pushing batch *k+1* against that base. The committed
//!   pivot maps are insert-only, so stale reads either return final
//!   entries or miss — and a miss just means the serial phase resumes
//!   that column against the full state;
//! * **sharded column enumeration**: H1*/H2* columns are not listed up
//!   front on the scheduler thread. The descending diameter-edge range
//!   is tiled into shards ([`reduction::shard_plan`]) and workers
//!   enumerate shard *k+2* — driving the coboundary cursors and
//!   `triangles_with_diameter` — **in the same pool generation** that
//!   pushes batch *k+1*, while batch *k* commits. Shard buffers splice
//!   back in shard order, so the reduction consumes a stream identical
//!   to the sequential enumeration.
//!
//! Output is therefore **bit-identical to the sequential reduction**
//! for every batch size, shard plan, thread count and steal schedule.
//! The pool is owned by a persistent [`homology::Engine`] and reused
//! across the H1*/H2* phases and across repeated runs.
//!
//! On top of the pipeline sits the **enumeration-time apparent-pair
//! shortcut** (`EngineOptions::shortcut`, on by default): most
//! surviving columns form zero-persistence apparent pairs whose pivot
//! is determined by one cofacet/facet round-trip, and the shard fills
//! resolve those *while enumerating* on the pool workers — the columns
//! never enter the stream, a `BucketTable`, or the batch pipeline
//! (shortcut + clearing, Ripser-style, atop the paper's trivial-pair
//! machinery).
//!
//! ## The parallel filtration front-end
//!
//! Everything *before* the reduction — the O(n²) distance pass, the
//! edge sort, and the `Neighborhoods` CSR fill — also runs on the same
//! persistent pool ([`filtration::EdgeFiltration::build_pooled`],
//! [`filtration::Neighborhoods::build_pooled`]): row-band distance
//! tiles spliced in canonical order, a monotone bit-packed key sort
//! (order-preserving f64→u64 bits, ties by the packed `(a, b)` — no
//! `partial_cmp().unwrap()` anywhere hot) chunk-sorted on the pool and
//! merged, and a two-pass counting/scatter CSR build. Output is
//! byte-identical to the serial front-end for every tile plan, pool
//! width and steal schedule. When no finite `τ` is requested, the
//! **enclosing-radius truncation** (`enclosing`, on by default) cuts
//! the filtration at `r_enc = min_i max_j d(i, j)` — the VR complex is
//! a cone beyond `r_enc`, so diagrams are bit-unchanged while the edge
//! set (and everything downstream) shrinks;
//! [`filtration::FiltrationStats`] reports the per-stage times and the
//! considered/kept/pruned counters end to end.
//!
//! Config knobs (via [`homology::EngineOptions`], the TOML config, or
//! CLI flags): `batch_size` (initial batch), `adaptive_batch` (walk the
//! batch size toward the serial≈push equilibrium; on by default),
//! `batch_min`/`batch_max` (adaptation bounds), `adapt_low`/`adapt_high`
//! (serial-fraction thresholds steering the adaptation; defaults
//! 0.25/0.75), `steal_grain` (columns per steal task; 0 = auto),
//! `enum_shards`/`enum_grain` (enumeration shard plan; 0 = auto),
//! `shortcut` (apparent-pair skip; `--no-shortcut` for the exact
//! fallback), `f1_tile` (front-end distance tile rows; 0 = auto),
//! `enclosing` (enclosing-radius truncation; `--no-enclosing` for the
//! exact full filtration). `EngineStats::{h1_sched, h2_sched}` report batches,
//! steals, worker utilization, serial/push overlap, residual barrier
//! idle, the enumeration span (shards, columns, worker busy time,
//! scheduler time blocked on enumeration) and the shortcut skip rate
//! per phase; `PhaseTimer` samples the max-RSS high-water mark at every
//! phase boundary for the per-phase memory claim.
//!
//! The exactness guarantee is enforced by a differential test harness
//! (`rust/tests/differential.rs`: scheduler vs the explicit
//! boundary-matrix oracle across shard-count × batch-size ×
//! thread-count sweeps, a 40-seed byte-identity property for the
//! sharded enumeration stream, structural pair-level comparison against
//! the sequential engine, and a 20-round pool-reuse stress test) and
//! golden persistence-diagram fixtures with bit-exact expected values
//! at multiple shard counts (`rust/tests/golden_pd.rs`).
//!
//! ## The session service API
//!
//! The service surface is **session-oriented** ([`homology::Session`]):
//! a session owns the persistent engine + pool,
//! [`homology::Session::ingest`]s a dataset **once** into a
//! [`homology::FiltrationHandle`] (sorted edge set + `Neighborhoods`
//! CSR + optional DoryNS table, all built pooled), and answers a stream
//! of typed [`homology::PhRequest`]s
//! ([`homology::Session::query`] / [`homology::Session::run_batch`]).
//! A sub-τ request never rebuilds anything: the sorted edge set is
//! **prefix-truncated** ([`filtration::EdgeFiltration::prefix`]) and
//! the shared CSR is viewed through an edge-order cap
//! ([`filtration::Neighborhoods::truncated`], `Arc`-shared arrays), so
//! the reduction consumes exactly the stream a fresh build at that τ
//! would produce — diagrams are **bit-identical** to independent
//! one-shot runs (`rust/tests/session.rs` pins this over τ × threads ×
//! shortcut sweeps, and `SessionStats`/`FiltrationStats::f1_builds`
//! prove the build ran once). Fallible entry points — ingestion, the
//! `io` readers, the [`coordinator`] — return typed
//! [`error::DoryError`]s (`InvalidInput`, `TauExceedsIngest`,
//! `Overflow`, `Config`, …) instead of panicking; the one-shot wrappers
//! `homology::compute_ph*` remain as deprecated shims over the session
//! layer so existing fixtures pin behavior.
//!
//! The [`coordinator`] exposes the same batching end to end: a TOML
//! config may carry a `[[query]]` array (or the CLI repeated `--tau`
//! flags), and [`coordinator::run_batch`] serves every query from one
//! ingest, emitting a single summary JSON with a per-query `queries`
//! array plus the session amortization counters.
//!
//! Since the concurrent-serving revision every session entry point
//! takes `&self`: N threads may ingest and query one session (even one
//! handle) simultaneously, and the pool's multi-generation scheduler
//! interleaves their task generations fairly — a large tenant cannot
//! starve a small one, and every concurrent schedule stays
//! bit-identical to serial execution. The [`serve`] module builds the
//! multi-tenant front on top: a byte-budgeted LRU cache of
//! `FiltrationHandle`s keyed by dataset content hash, a line-delimited
//! JSON-RPC loop (`dory serve`), typed [`error::DoryError`]s on the
//! wire, and per-tenant counters in the summary.
//!
//! Entry points: [`homology::Session`] for services, [`serve::Server`]
//! for the multi-tenant wire front,
//! [`homology::Engine`] / [`homology::engine`] for the bare pipeline,
//! [`coordinator`] for config-driven runs, `examples/` for
//! walkthroughs (`examples/service_batch.rs` is the session tour).

pub mod baselines;
pub mod bench_support;
pub mod coboundary;
pub mod coordinator;
pub mod datasets;
pub mod error;
pub mod features;
pub mod filtration;
pub mod geometry;
pub mod hic;
pub mod io;
pub mod homology;
pub mod reduction;
pub mod runtime;
pub mod serve;
pub mod util;

pub use error::DoryError;

use util::memtrack::CountingAlloc;

/// Heap accounting is part of the deliverable (the paper reports peak
/// memory per run); the counting allocator backs every binary and test.
#[global_allocator]
static GLOBAL_ALLOC: CountingAlloc = CountingAlloc;
