//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers the L2 model (which calls the L1 Pallas
//! kernels) to **HLO text** at a fixed menu of shapes. The [`pjrt`]
//! backend scans `artifacts/`, compiles each module once on the PJRT CPU
//! client, and serves two operations on the request path — with Python
//! long gone:
//!
//! * `distance_matrix(points)` — the tiled Pallas pairwise-distance
//!   kernel. Inputs are padded to the smallest artifact shape that fits
//!   (padding points parked at `PAD_COORD`, far beyond any τ_m).
//! * `persistence_image(points…)` — Gaussian rasterization of a PD into a
//!   persistence image (padding entries get weight 0).
//!
//! The PJRT execution path needs the external `xla` crate, which the
//! offline vendor set does not include; it is therefore gated behind the
//! `pjrt` cargo feature. The default build ships [`stub::Runtime`] with
//! the identical API: loading succeeds, no kernels are reported, and
//! every execution request returns an error — so callers exercise the
//! same graceful-degradation path they already handle (falling back to
//! the native Rust distance computation in `EdgeFiltration::build`).

use std::path::PathBuf;

/// Coordinate assigned to padding points: pairwise distances to and among
/// padding points exceed every real τ_m by construction. Spread along the
/// first axis so padding–padding distances are non-zero too (they are
/// filtered by index anyway; this keeps the matrix sane for debugging).
pub const PAD_COORD: f32 = 1.0e7;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Parse `prefix{A}x{B}.hlo.txt` into (A, B).
pub fn parse_name(name: &str, prefix: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix(prefix)?.strip_suffix(".hlo.txt")?;
    let (a, b) = rest.split_once('x')?;
    Some((a.parse().ok()?, b.parse().ok()?))
}

/// Default artifact directory: `$DORY_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DORY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_artifact_names() {
        assert_eq!(parse_name("dist_1024x3.hlo.txt", "dist_"), Some((1024, 3)));
        assert_eq!(parse_name("pimage_256x32.hlo.txt", "pimage_"), Some((256, 32)));
        assert_eq!(parse_name("dist_1024x3.hlo", "dist_"), None);
        assert_eq!(parse_name("other.hlo.txt", "dist_"), None);
    }

    #[test]
    fn empty_dir_is_fine() {
        let dir = std::env::temp_dir().join("dory-empty-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert!(!rt.has_distance_kernel());
        assert!(!rt.has_pimage_kernel());
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn stubbed_execution_requests_fail_gracefully() {
        let dir = std::env::temp_dir().join("dory-empty-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::load(&dir).unwrap();
        // Without artifacts (or without the pjrt feature at all) the
        // request-path operations must return errors, never panic.
        use crate::geometry::PointCloud;
        let pc = PointCloud::new(2, vec![0.0, 0.0, 1.0, 0.0]);
        assert!(rt.distance_matrix(&pc).is_err());
        assert!(rt.distance_edges(&pc, 1.0).is_err());
        assert!(rt.persistence_image(&[(0.1, 0.2, 1.0)], 1.0).is_err());
    }
}
