//! PJRT-backed runtime (requires the `pjrt` feature and the external
//! `xla` crate). See the module docs in [`super`] for the artifact
//! contract; [`super::stub`] mirrors this API for offline builds.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::{parse_name, PAD_COORD};
use crate::geometry::PointCloud;

struct DistExec {
    rows: usize,
    cols: usize,
    exe: xla::PjRtLoadedExecutable,
}

struct PImageExec {
    max_pairs: usize,
    grid: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Artifact registry + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dist: Vec<DistExec>,
    pimage: Vec<PImageExec>,
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Scan `dir` for `dist_{N}x{D}.hlo.txt` / `pimage_{K}x{G}.hlo.txt`
    /// and compile everything found. An empty dir yields a usable (if
    /// artifact-less) runtime.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut rt = Runtime {
            client,
            dist: Vec::new(),
            pimage: Vec::new(),
            artifact_dir: dir.to_path_buf(),
        };
        if dir.is_dir() {
            let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .collect();
            paths.sort();
            for p in paths {
                let name = match p.file_name().and_then(|s| s.to_str()) {
                    Some(n) => n,
                    None => continue,
                };
                if let Some(shape) = parse_name(name, "dist_") {
                    let exe = rt.compile(&p).with_context(|| format!("compile {name}"))?;
                    rt.dist.push(DistExec {
                        rows: shape.0,
                        cols: shape.1,
                        exe,
                    });
                } else if let Some(shape) = parse_name(name, "pimage_") {
                    let exe = rt.compile(&p).with_context(|| format!("compile {name}"))?;
                    rt.pimage.push(PImageExec {
                        max_pairs: shape.0,
                        grid: shape.1,
                        exe,
                    });
                }
            }
        }
        rt.dist.sort_by_key(|d| d.rows);
        rt.pimage.sort_by_key(|p| p.max_pairs);
        Ok(rt)
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile: {e}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_distance_kernel(&self) -> bool {
        !self.dist.is_empty()
    }

    pub fn has_pimage_kernel(&self) -> bool {
        !self.pimage.is_empty()
    }

    pub fn dist_shapes(&self) -> Vec<(usize, usize)> {
        self.dist.iter().map(|d| (d.rows, d.cols)).collect()
    }

    /// Full pairwise distance matrix of `pc` through the Pallas kernel,
    /// returned as the strict upper triangle entries (i < j) of the real
    /// (unpadded) points: `(i, j, d)`.
    pub fn distance_matrix(&self, pc: &PointCloud) -> Result<Vec<f32>> {
        let n = pc.n();
        let exec = self
            .dist
            .iter()
            .find(|d| d.rows >= n && d.cols >= pc.dim)
            .ok_or_else(|| {
                anyhow!(
                    "no distance artifact fits n={n} dim={} (have {:?})",
                    pc.dim,
                    self.dist_shapes()
                )
            })?;
        let padded = pc.to_f32_padded(exec.rows, exec.cols, PAD_COORD);
        let lit = xla::Literal::vec1(&padded)
            .reshape(&[exec.rows as i64, exec.cols as i64])
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = exec
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let full: Vec<f32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e}"))?;
        // Slice the real n×n block out of the padded rows×rows matrix.
        let mut sliced = vec![0f32; n * n];
        for i in 0..n {
            sliced[i * n..(i + 1) * n]
                .copy_from_slice(&full[i * exec.rows..i * exec.rows + n]);
        }
        Ok(sliced)
    }

    /// Edge list `(d, i, j)` with `d <= tau` via the distance kernel.
    pub fn distance_edges(&self, pc: &PointCloud, tau: f64) -> Result<Vec<(f64, u32, u32)>> {
        let n = pc.n();
        let m = self.distance_matrix(pc)?;
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let d = m[i * n + j] as f64;
                if d <= tau {
                    edges.push((d, i as u32, j as u32));
                }
            }
        }
        Ok(edges)
    }

    /// Persistence image of `(birth, persistence, weight)` triples on the
    /// kernel's `grid×grid` raster over `[0, span]²` with bandwidth sigma
    /// baked into the artifact. Returns (grid, pixels).
    pub fn persistence_image(&self, pairs: &[(f32, f32, f32)], span: f32) -> Result<(usize, Vec<f32>)> {
        let exec = self
            .pimage
            .iter()
            .find(|p| p.max_pairs >= pairs.len())
            .or_else(|| self.pimage.last())
            .ok_or_else(|| anyhow!("no persistence-image artifact loaded"))?;
        // Truncate lowest-weight pairs if over capacity, pad with w=0.
        let mut data = vec![0f32; exec.max_pairs * 3];
        let mut use_pairs: Vec<&(f32, f32, f32)> = pairs.iter().collect();
        if use_pairs.len() > exec.max_pairs {
            use_pairs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
            use_pairs.truncate(exec.max_pairs);
        }
        for (k, p) in use_pairs.iter().enumerate() {
            data[k * 3] = p.0;
            data[k * 3 + 1] = p.1;
            data[k * 3 + 2] = p.2;
        }
        let lit = xla::Literal::vec1(&data)
            .reshape(&[exec.max_pairs as i64, 3])
            .map_err(|e| anyhow!("reshape pairs: {e}"))?;
        let span_lit = xla::Literal::vec1(&[span]).reshape(&[]).map_err(|e| anyhow!("{e}"))?;
        let result = exec
            .exe
            .execute::<xla::Literal>(&[lit, span_lit])
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let img: Vec<f32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e}"))?;
        Ok((exec.grid, img))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// End-to-end vs native distances — runs only when artifacts exist
    /// (`make artifacts` first; CI does).
    #[test]
    fn kernel_distances_match_native_when_artifacts_present() {
        let dir = super::super::default_artifact_dir();
        let rt = match Runtime::load(&dir) {
            Ok(rt) if rt.has_distance_kernel() => rt,
            _ => {
                eprintln!("skipping: no artifacts in {dir:?}");
                return;
            }
        };
        let mut rng = Pcg32::new(7);
        let n = 100;
        let pc = PointCloud::new(3, (0..n * 3).map(|_| rng.next_f64()).collect());
        let m = rt.distance_matrix(&pc).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = pc.dist(i, j) as f32;
                let got = m[i * n + j];
                // Gram-trick cancellation bounds the absolute error by
                // ~sqrt(eps)·scale (see python/tests/test_kernels.py).
                assert!(
                    (got - want).abs() <= 6e-3 + 1e-4 * want,
                    "({i},{j}): {got} vs {want}"
                );
            }
        }
    }
}
