//! Default (no-`pjrt`-feature) runtime: the same API surface as the PJRT
//! backend, with every execution request reporting "no kernel". Callers
//! already degrade gracefully (native distance path, skipped persistence
//! images), so a stub runtime keeps the whole pipeline usable offline.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::geometry::PointCloud;

/// Artifact registry placeholder for builds without the `pjrt` feature.
pub struct Runtime {
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Always succeeds; records the directory but compiles nothing.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Ok(Runtime {
            artifact_dir: dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        "native-stub (built without the `pjrt` feature)".to_string()
    }

    pub fn has_distance_kernel(&self) -> bool {
        false
    }

    pub fn has_pimage_kernel(&self) -> bool {
        false
    }

    pub fn dist_shapes(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }

    pub fn distance_matrix(&self, _pc: &PointCloud) -> Result<Vec<f32>> {
        Err(anyhow!("PJRT backend not compiled in (enable feature `pjrt`)"))
    }

    pub fn distance_edges(&self, _pc: &PointCloud, _tau: f64) -> Result<Vec<(f64, u32, u32)>> {
        Err(anyhow!("PJRT backend not compiled in (enable feature `pjrt`)"))
    }

    pub fn persistence_image(
        &self,
        _pairs: &[(f32, f32, f32)],
        _span: f32,
    ) -> Result<(usize, Vec<f32>)> {
        Err(anyhow!("PJRT backend not compiled in (enable feature `pjrt`)"))
    }
}
