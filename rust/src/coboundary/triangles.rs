//! Tetrahedron cursors over the coboundary of a triangle (paper §4.2.2,
//! App. C).
//!
//! For a column triangle `t = ⟨kp, c⟩` with `{a,b} = f1⁻¹(kp)`, the
//! simplices of `δt` are the tetrahedra `{a,b,c,v}` over common neighbors
//! `v` of all three vertices:
//!
//! * **Case 1** (`f = 0`) — diameter is `kp` itself (all three new edges
//!   smaller): keys `⟨kp, order({c,v})⟩`, produced by walking `E^c`
//!   ascending while its orders stay < `kp`;
//! * **Case 2** (`f = 1|2|3`) — the diameter is the largest new edge,
//!   found in `E^a`/`E^b`/`E^c`: keys `⟨o, opposite-edge-order⟩` where the
//!   opposite edge is one of the triangle's own edges (`{b,c}`, `{a,c}`,
//!   `{a,b}` respectively), produced by a 3-way sorted merge.

use crate::filtration::{Key, Neighborhoods};

/// φ-representation of a position inside `δt` (paper Figure 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TetCursor {
    /// The column triangle ⟨kp, c⟩.
    pub t: Key,
    pub a: u32,
    pub b: u32,
    pub c: u32,
    /// Orders of the triangle's own edges {a,c} and {b,c} ({a,b} = t.p).
    pub oac: u32,
    pub obc: u32,
    /// Stream indices into E^a, E^b, E^c.
    pub ia: u32,
    pub ib: u32,
    pub ic: u32,
    /// 0 = case 1; 1/2/3 = case 2 with diameter from E^a/E^b/E^c.
    pub f: u8,
    /// Current tetrahedron key; `Key::NONE` when exhausted.
    pub cur: Key,
}

impl TetCursor {
    fn new(nb: &Neighborhoods, f1: &crate::filtration::EdgeFiltration, t: Key) -> TetCursor {
        let (a, b) = f1.edges[t.p as usize];
        let c = t.s;
        let oac = nb.edge_order(a, c).expect("triangle edge {a,c} must exist");
        let obc = nb.edge_order(b, c).expect("triangle edge {b,c} must exist");
        TetCursor {
            t,
            a,
            b,
            c,
            oac,
            obc,
            ia: 0,
            ib: 0,
            ic: 0,
            f: 0,
            cur: Key::NONE,
        }
    }

    /// `FindSmallesth` (paper alg. 13).
    pub fn find_smallest(
        nb: &Neighborhoods,
        f1: &crate::filtration::EdgeFiltration,
        t: Key,
    ) -> TetCursor {
        let mut cur = Self::new(nb, f1, t);
        if !cur.run_case1(nb) {
            cur.enter_case2(nb, cur.t.p + 1);
            cur.run_case2(nb, Key::new(0, 0));
        }
        cur
    }

    /// `FindNexth` (paper alg. 14).
    pub fn find_next(&mut self, nb: &Neighborhoods) {
        debug_assert!(!self.cur.is_none());
        match self.f {
            0 => {
                self.ic += 1;
                if self.run_case1(nb) {
                    return;
                }
                self.enter_case2(nb, self.t.p + 1);
                self.run_case2(nb, Key::new(0, 0));
            }
            1 => {
                self.ia += 1;
                self.run_case2(nb, Key::new(0, 0));
            }
            2 => {
                self.ib += 1;
                self.run_case2(nb, Key::new(0, 0));
            }
            3 => {
                self.ic += 1;
                self.run_case2(nb, Key::new(0, 0));
            }
            _ => unreachable!("find_next on exhausted cursor"),
        }
    }

    /// `FindGEQh` (paper alg. 15): least tetrahedron of `δt` >= `target`.
    pub fn find_geq(
        nb: &Neighborhoods,
        f1: &crate::filtration::EdgeFiltration,
        t: Key,
        target: Key,
    ) -> TetCursor {
        if target.p < t.p {
            return Self::find_smallest(nb, f1, t);
        }
        let mut cur = Self::new(nb, f1, t);
        if target.p == t.p {
            // Case 1 from the first E^c entry with order >= target.s.
            cur.ic = nb.en_lower_bound(cur.c, target.s);
            if cur.run_case1(nb) {
                return cur;
            }
            cur.enter_case2(nb, t.p + 1);
            cur.run_case2(nb, Key::new(0, 0));
        } else {
            cur.enter_case2(nb, target.p);
            cur.run_case2(nb, target);
        }
        cur
    }

    fn enter_case2(&mut self, nb: &Neighborhoods, min_ord: u32) {
        self.f = 4; // sentinel: in case 2, no current stream
        self.ia = nb.en_lower_bound(self.a, min_ord);
        self.ib = nb.en_lower_bound(self.b, min_ord);
        self.ic = nb.en_lower_bound(self.c, min_ord);
    }

    /// Walk E^c (orders < kp) for tetrahedra with diameter kp.
    /// Returns true when positioned on a valid tetrahedron.
    fn run_case1(&mut self, nb: &Neighborhoods) -> bool {
        let kp = self.t.p;
        let (ec_ord, ec_vtx) = nb.en(self.c);
        let mut ic = self.ic as usize;
        while ic < ec_ord.len() && ec_ord[ic] < kp {
            let v = ec_vtx[ic];
            if v != self.a && v != self.b {
                let ok = match (nb.edge_order(self.a, v), nb.edge_order(self.b, v)) {
                    (Some(oav), Some(obv)) => oav < kp && obv < kp,
                    _ => false,
                };
                if ok {
                    self.ic = ic as u32;
                    self.f = 0;
                    self.cur = Key::new(kp, ec_ord[ic]);
                    return true;
                }
            }
            ic += 1;
        }
        self.ic = ic as u32;
        self.cur = Key::NONE;
        false
    }

    /// 3-way merge of E^a, E^b, E^c (orders > kp) for the diameter edge.
    /// Only accepts keys >= `min_key` (the FindGEQh guard).
    fn run_case2(&mut self, nb: &Neighborhoods, min_key: Key) {
        let (ea_ord, ea_vtx) = nb.en(self.a);
        let (eb_ord, eb_vtx) = nb.en(self.b);
        let (ec_ord, ec_vtx) = nb.en(self.c);
        let (mut ia, mut ib, mut ic) = (self.ia as usize, self.ib as usize, self.ic as usize);
        loop {
            let ha = if ia < ea_ord.len() { ea_ord[ia] } else { u32::MAX };
            let hb = if ib < eb_ord.len() { eb_ord[ib] } else { u32::MAX };
            let hc = if ic < ec_ord.len() { ec_ord[ic] } else { u32::MAX };
            let o = ha.min(hb).min(hc);
            if o == u32::MAX {
                self.ia = ia as u32;
                self.ib = ib as u32;
                self.ic = ic as u32;
                self.f = 4;
                self.cur = Key::NONE;
                return;
            }
            // Identify the producing stream; orders are unique so no ties.
            let (stream, v, u1, u2, opp) = if o == ha {
                (1u8, ea_vtx[ia], self.b, self.c, self.obc)
            } else if o == hb {
                (2u8, eb_vtx[ib], self.a, self.c, self.oac)
            } else {
                (3u8, ec_vtx[ic], self.a, self.b, self.t.p)
            };
            // v must be a new vertex adjacent to the other two with smaller
            // edge orders (o is then the tetrahedron's diameter).
            let valid = v != self.a
                && v != self.b
                && v != self.c
                && match (nb.edge_order(u1, v), nb.edge_order(u2, v)) {
                    (Some(o1), Some(o2)) => o1 < o && o2 < o,
                    _ => false,
                };
            if valid {
                let key = Key::new(o, opp);
                if key >= min_key {
                    self.ia = ia as u32;
                    self.ib = ib as u32;
                    self.ic = ic as u32;
                    self.f = stream;
                    self.cur = key;
                    return;
                }
            }
            match stream {
                1 => ia += 1,
                2 => ib += 1,
                _ => ic += 1,
            }
        }
    }
}

/// Brute-force enumeration of `δt` in key order. Test oracle.
pub fn brute_force_coboundary(
    nb: &Neighborhoods,
    f1: &crate::filtration::EdgeFiltration,
    t: Key,
) -> Vec<Key> {
    let (a, b) = f1.edges[t.p as usize];
    let c = t.s;
    let mut out = Vec::new();
    for v in 0..f1.n {
        if v == a || v == b || v == c {
            continue;
        }
        let (oav, obv, ocv) = match (
            nb.edge_order(a, v),
            nb.edge_order(b, v),
            nb.edge_order(c, v),
        ) {
            (Some(x), Some(y), Some(z)) => (x, y, z),
            _ => continue,
        };
        // Diameter of {a,b,c,v}: max over all six edges; the triangle's own
        // edges are all <= t.p, so the max is over {t.p, oav, obv, ocv}.
        let m = t.p.max(oav).max(obv).max(ocv);
        let key = if m == t.p {
            Key::new(t.p, ocv)
        } else if m == oav {
            Key::new(oav, nb.edge_order(b, c).unwrap())
        } else if m == obv {
            Key::new(obv, nb.edge_order(a, c).unwrap())
        } else {
            Key::new(ocv, t.p)
        };
        out.push(key);
    }
    out.sort_unstable();
    out
}

/// Greatest facet of tetrahedron `h = ⟨kp, ks⟩` that shares its
/// diameter edge: the triangle `⟨kp, max(c, d)⟩` with `{c,d} =
/// f1⁻¹(ks)` (paper §4.3.5 — every other facet either has a smaller
/// diameter or a smaller opposite vertex). This is the facet half of
/// the apparent-pair round-trip; by construction its key shares `h`'s
/// primary, i.e. the pair has equal diameter and zero persistence.
#[inline]
pub fn max_equal_facet_of_tet(f1: &crate::filtration::EdgeFiltration, h: Key) -> Key {
    let (c, d) = f1.edges[h.s as usize];
    Key::new(h.p, c.max(d))
}

/// Apparent-pair probe for a triangle column `t`: find its minimal
/// cofacet `h` with the `FindSmallesth` cursor machinery; `(t, h)` is an
/// apparent (trivial, zero-persistence) pair iff `h` shares `t`'s
/// diameter edge and its greatest equal-diameter facet
/// ([`max_equal_facet_of_tet`]) round-trips back to `t`. Returns the
/// paired tetrahedron when apparent.
///
/// This is exactly the condition the reduction's first-`find_low`
/// trivial test applies (`is_self_trivial_first` on the smallest
/// coboundary simplex), hoisted to enumeration time so apparent columns
/// can be resolved inside the shard fills on pool workers and never
/// enter a `BucketTable` — see the in-shard shortcut in
/// `homology::engine`.
pub fn apparent_cofacet(
    nb: &Neighborhoods,
    f1: &crate::filtration::EdgeFiltration,
    t: Key,
) -> Option<Key> {
    let h = TetCursor::find_smallest(nb, f1, t).cur;
    if !h.is_none() && max_equal_facet_of_tet(f1, h) == t {
        Some(h)
    } else {
        None
    }
}

/// Visit, in canonical reverse-filtration order, every triangle whose
/// diameter edge lies in `range`: diameter edges walked descending,
/// secondaries descending within each edge — exactly the order the H2\*
/// engine feeds its reduction. `visit` returning `false` drops the
/// triangle from the stream (clearing) without breaking the walk.
///
/// This is the per-shard enumeration primitive of the sharded H2\*
/// pipeline: tiling `0..n_e` with ranges (descending) and concatenating
/// the shards' outputs reproduces the full sequential enumeration
/// byte for byte (pinned by `rust/tests/differential.rs`).
pub fn triangles_with_diameter_in_range(
    nb: &Neighborhoods,
    f1: &crate::filtration::EdgeFiltration,
    range: std::ops::Range<u32>,
    mut visit: impl FnMut(Key) -> bool,
    out: &mut Vec<u64>,
) {
    for e in range.rev() {
        let (a, b) = f1.edges[e as usize];
        let tris = triangles_with_diameter(nb, e, a, b);
        for &v in tris.iter().rev() {
            let t = Key::new(e, v);
            if visit(t) {
                out.push(t.pack());
            }
        }
    }
}

/// All case-1 triangles of edge `e` (diameter = e), i.e. all triangles with
/// primary key `e`, as secondary keys sorted ascending. Used by the engine
/// to enumerate triangle columns grouped by diameter edge.
pub fn triangles_with_diameter(nb: &Neighborhoods, e: u32, a: u32, b: u32) -> Vec<u32> {
    let (va, oa) = nb.vn(a);
    let (vb, ob) = nb.vn(b);
    let (mut ia, mut ib) = (0usize, 0usize);
    let mut out = Vec::new();
    while ia < va.len() && ib < vb.len() {
        let (x, y) = (va[ia], vb[ib]);
        if x < y {
            ia += 1;
        } else if y < x {
            ib += 1;
        } else {
            if oa[ia] < e && ob[ib] < e {
                out.push(x);
            }
            ia += 1;
            ib += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::EdgeFiltration;
    use crate::geometry::{MetricData, PointCloud};
    use crate::util::rng::Pcg32;

    fn random_filtration(n: usize, dim: usize, tau: f64, seed: u64) -> EdgeFiltration {
        let mut rng = Pcg32::new(seed);
        let coords = (0..n * dim).map(|_| rng.next_f64()).collect();
        EdgeFiltration::build(&MetricData::Points(PointCloud::new(dim, coords)), tau)
    }

    fn all_triangles(nb: &Neighborhoods, f: &EdgeFiltration) -> Vec<Key> {
        let mut out = Vec::new();
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            for v in triangles_with_diameter(nb, e, a, b) {
                out.push(Key::new(e, v));
            }
        }
        out
    }

    fn enumerate_with_cursor(nb: &Neighborhoods, f: &EdgeFiltration, t: Key) -> Vec<Key> {
        let mut c = TetCursor::find_smallest(nb, f, t);
        let mut out = Vec::new();
        while !c.cur.is_none() {
            out.push(c.cur);
            c.find_next(nb);
        }
        out
    }

    #[test]
    fn cursor_matches_brute_force() {
        for seed in 0..6 {
            let f = random_filtration(18, 3, 0.9, seed);
            for dense in [false, true] {
                let nb = Neighborhoods::build(&f, dense);
                for t in all_triangles(&nb, &f) {
                    let got = enumerate_with_cursor(&nb, &f, t);
                    let want = brute_force_coboundary(&nb, &f, t);
                    assert_eq!(got, want, "seed={seed} t={t} dense={dense}");
                }
            }
        }
    }

    #[test]
    fn enumeration_strictly_increasing() {
        let f = random_filtration(16, 2, 1.2, 42);
        let nb = Neighborhoods::build(&f, false);
        for t in all_triangles(&nb, &f) {
            let keys = enumerate_with_cursor(&nb, &f, t);
            for w in keys.windows(2) {
                assert!(w[0] < w[1], "t={t}");
            }
        }
    }

    #[test]
    fn find_geq_agrees_with_linear_scan() {
        let f = random_filtration(14, 3, 1.0, 11);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges() as u32;
        let mut rng = Pcg32::new(77);
        for t in all_triangles(&nb, &f) {
            let all = brute_force_coboundary(&nb, &f, t);
            let mut targets: Vec<Key> = all.clone();
            targets.push(Key::new(0, 0));
            for _ in 0..8 {
                targets.push(Key::new(rng.gen_range(ne), rng.gen_range(ne)));
            }
            for tgt in targets {
                let c = TetCursor::find_geq(&nb, &f, t, tgt);
                let want = all.iter().copied().find(|&k| k >= tgt).unwrap_or(Key::NONE);
                assert_eq!(c.cur, want, "t={t} target={tgt}");
            }
        }
    }

    #[test]
    fn geq_state_canonical() {
        let f = random_filtration(15, 3, 1.0, 3);
        let nb = Neighborhoods::build(&f, false);
        for t in all_triangles(&nb, &f) {
            let mut c = TetCursor::find_smallest(&nb, &f, t);
            while !c.cur.is_none() {
                let fresh = TetCursor::find_geq(&nb, &f, t, c.cur);
                // In case 1 the stream states must agree exactly; in case 2
                // the merge is canonical as for edges.
                assert_eq!(c.cur, fresh.cur);
                assert_eq!(
                    (c.ia, c.ib, c.ic, c.f),
                    (fresh.ia, fresh.ib, fresh.ic, fresh.f),
                    "state must be canonical at {} (t={t})",
                    c.cur
                );
                c.find_next(&nb);
            }
        }
    }

    #[test]
    fn range_enumeration_tiles_to_full_sequence() {
        // Concatenating descending shard ranges must reproduce the full
        // descending enumeration byte for byte, for every tiling.
        let f = random_filtration(18, 3, 0.9, 21);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges() as u32;
        let mut want: Vec<u64> = Vec::new();
        triangles_with_diameter_in_range(&nb, &f, 0..ne, |_| true, &mut want);
        for grain in [1u32, 2, 5, ne.max(1)] {
            let mut got: Vec<u64> = Vec::new();
            let mut hi = ne;
            while hi > 0 {
                let lo = hi.saturating_sub(grain);
                triangles_with_diameter_in_range(&nb, &f, lo..hi, |_| true, &mut got);
                hi = lo;
            }
            assert_eq!(got, want, "grain={grain}");
        }
        // The filter drops exactly the rejected keys, preserving order.
        let mut filtered: Vec<u64> = Vec::new();
        triangles_with_diameter_in_range(&nb, &f, 0..ne, |t| t.s % 2 == 0, &mut filtered);
        let expect: Vec<u64> = want
            .iter()
            .copied()
            .filter(|&p| Key::unpack(p).s % 2 == 0)
            .collect();
        assert_eq!(filtered, expect);
    }

    #[test]
    fn apparent_cofacet_matches_reduction_trivial_probe() {
        // The enumeration-time shortcut must fire on exactly the columns
        // the reduction's own machinery would resolve as self-trivial:
        // (t, h) apparent ⟺ trivial_owner(h) == t with h the smallest
        // simplex of δt. Also pins the zero-persistence property (equal
        // primaries ⇒ equal diameters, bit for bit).
        use crate::reduction::{ColumnSpace, TriangleColumns};
        for seed in 0..4 {
            let f = random_filtration(16, 3, 0.95, 100 + seed);
            let nb = Neighborhoods::build(&f, false);
            let space = TriangleColumns::new(&nb, &f);
            let mut apparent_seen = 0usize;
            for t in all_triangles(&nb, &f) {
                let h = TetCursor::find_smallest(&nb, &f, t).cur;
                let via_shortcut = apparent_cofacet(&nb, &f, t);
                let via_reduction = if !h.is_none()
                    && space.is_self_trivial_first(t.pack(), h)
                {
                    Some(h)
                } else {
                    None
                };
                assert_eq!(via_shortcut, via_reduction, "seed={seed} t={t}");
                if let Some(h) = via_shortcut {
                    apparent_seen += 1;
                    assert_eq!(h.p, t.p, "apparent pair must share the diameter edge");
                    assert_eq!(
                        f.key_value(h).to_bits(),
                        f.key_value(t).to_bits(),
                        "apparent pair must have zero persistence"
                    );
                    assert_eq!(max_equal_facet_of_tet(&f, h), t, "round-trip");
                    // And the trivial-owner probe agrees it is t's pivot.
                    assert_eq!(space.trivial_owner(h), Some(t.pack()), "seed={seed} t={t}");
                }
            }
            assert!(apparent_seen > 0, "seed={seed}: no apparent pairs found");
        }
    }

    #[test]
    fn triangles_with_diameter_partition_all_triangles() {
        // Every 3-clique appears under exactly one diameter edge.
        let f = random_filtration(20, 2, 1.5, 8);
        let nb = Neighborhoods::build(&f, false);
        let mut count = 0usize;
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            count += triangles_with_diameter(&nb, e, a, b).len();
        }
        let mut brute = 0usize;
        for i in 0..f.n {
            for j in (i + 1)..f.n {
                for k in (j + 1)..f.n {
                    if nb.edge_order(i, j).is_some()
                        && nb.edge_order(i, k).is_some()
                        && nb.edge_order(j, k).is_some()
                    {
                        brute += 1;
                    }
                }
            }
        }
        assert_eq!(count, brute);
    }
}
