//! Triangle cursors over the coboundary of an edge (paper §4.2.1, App. B).
//!
//! For a column edge `e = {a,b}` (order `e`), the simplices of `δe` are the
//! triangles `{a,b,v}` over common neighbors `v`, enumerated in key order:
//!
//! * **Case 1** — triangles whose diameter *is* `e` (both other edge
//!   orders < `e`): keys `⟨e, v⟩`, produced by a sorted merge of the
//!   vertex-neighborhoods `N^a`, `N^b`;
//! * **Case 2** — triangles with diameter > `e`: keys `⟨o, w⟩` where `o`
//!   is the diameter edge's order and `w` the opposite vertex, produced by
//!   a sorted merge of the edge-neighborhoods `E^a`, `E^b` restricted to
//!   orders > `e`, with one `edge_order` existence check per candidate.
//!
//! Cursor state at a given triangle is canonical (the merge consumes the
//! global minimum each step), so two cursors of the same edge at the same
//! triangle are bit-identical — the reduction relies on this to cancel
//! duplicate columns.

use crate::filtration::{Key, Neighborhoods};

/// φ-representation of a position inside `δe` (paper Figure 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TriCursor {
    /// Order of the column edge `{a,b}`.
    pub e: u32,
    pub a: u32,
    pub b: u32,
    /// Indices into `N^a`/`N^b` (case 1) or `E^a`/`E^b` (case 2).
    pub ia: u32,
    pub ib: u32,
    pub case2: bool,
    /// Current triangle key; `Key::NONE` when the coboundary is exhausted.
    pub cur: Key,
}

impl TriCursor {
    /// `FindSmallestt` (paper alg. 8): cursor at the least triangle of `δe`.
    pub fn find_smallest(nb: &Neighborhoods, e: u32, a: u32, b: u32) -> TriCursor {
        let mut c = TriCursor {
            e,
            a,
            b,
            ia: 0,
            ib: 0,
            case2: false,
            cur: Key::NONE,
        };
        if !c.run_case1(nb) {
            c.enter_case2(nb, e + 1);
            c.run_case2(nb, Key::new(0, 0));
        }
        c
    }

    /// `FindNextt` (paper alg. 9): advance to the next-greater triangle.
    pub fn find_next(&mut self, nb: &Neighborhoods) {
        debug_assert!(!self.cur.is_none());
        if !self.case2 {
            // Move past the current common neighbor in both N^a and N^b.
            self.ia += 1;
            self.ib += 1;
            if self.run_case1(nb) {
                return;
            }
            self.enter_case2(nb, self.e + 1);
            self.run_case2(nb, Key::new(0, 0));
        } else {
            // The stream that produced `cur` is identified by the secondary
            // key: s == b means the diameter edge came from E^a.
            if self.cur.s == self.b {
                self.ia += 1;
            } else {
                debug_assert_eq!(self.cur.s, self.a);
                self.ib += 1;
            }
            self.run_case2(nb, Key::new(0, 0));
        }
    }

    /// `FindGEQt` (paper alg. 10): cursor at the least triangle of `δe`
    /// that is >= `target`.
    pub fn find_geq(nb: &Neighborhoods, e: u32, a: u32, b: u32, target: Key) -> TriCursor {
        if target.p < e {
            return Self::find_smallest(nb, e, a, b);
        }
        let mut c = TriCursor {
            e,
            a,
            b,
            ia: 0,
            ib: 0,
            case2: false,
            cur: Key::NONE,
        };
        if target.p == e {
            // Case 1 from the first common neighbor >= target.s.
            c.ia = nb.vn_lower_bound(a, target.s);
            c.ib = nb.vn_lower_bound(b, target.s);
            if c.run_case1(nb) {
                return c;
            }
            c.enter_case2(nb, e + 1);
            c.run_case2(nb, Key::new(0, 0));
        } else {
            // Case 2 from the first candidate edge with order >= target.p;
            // run_case2's guard skips the (at most one) candidate whose key
            // shares target.p but has a smaller secondary.
            c.enter_case2(nb, target.p);
            c.run_case2(nb, target);
        }
        c
    }

    /// Enter case 2 with both pointers at the first edge order >= `min_ord`.
    fn enter_case2(&mut self, nb: &Neighborhoods, min_ord: u32) {
        self.case2 = true;
        self.ia = nb.en_lower_bound(self.a, min_ord);
        self.ib = nb.en_lower_bound(self.b, min_ord);
    }

    /// Merge N^a / N^b for common neighbors forming diameter-`e` triangles.
    /// Returns true when positioned on a valid triangle.
    fn run_case1(&mut self, nb: &Neighborhoods) -> bool {
        let (va, oa) = nb.vn(self.a);
        let (vb, ob) = nb.vn(self.b);
        let (mut ia, mut ib) = (self.ia as usize, self.ib as usize);
        while ia < va.len() && ib < vb.len() {
            let (x, y) = (va[ia], vb[ib]);
            if x < y {
                ia += 1;
            } else if y < x {
                ib += 1;
            } else {
                // Common neighbor (x can never be a or b: b ∉ N^b, a ∉ N^a).
                if oa[ia] < self.e && ob[ib] < self.e {
                    self.ia = ia as u32;
                    self.ib = ib as u32;
                    self.cur = Key::new(self.e, x);
                    return true;
                }
                // Diameter exceeds e: this triangle belongs to case 2.
                ia += 1;
                ib += 1;
            }
        }
        self.ia = ia as u32;
        self.ib = ib as u32;
        self.cur = Key::NONE;
        false
    }

    /// Merge E^a / E^b (orders > e) for diameter-carrying candidate edges.
    /// Only accepts keys >= `min_key` (the FindGEQt guard).
    fn run_case2(&mut self, nb: &Neighborhoods, min_key: Key) {
        let (ea_ord, ea_vtx) = nb.en(self.a);
        let (eb_ord, eb_vtx) = nb.en(self.b);
        let (mut ia, mut ib) = (self.ia as usize, self.ib as usize);
        loop {
            let ha = if ia < ea_ord.len() { ea_ord[ia] } else { u32::MAX };
            let hb = if ib < eb_ord.len() { eb_ord[ib] } else { u32::MAX };
            if ha == u32::MAX && hb == u32::MAX {
                self.ia = ia as u32;
                self.ib = ib as u32;
                self.cur = Key::NONE;
                return;
            }
            if ha < hb {
                // Candidate diameter edge {a,d}; triangle {a,b,d}, key ⟨ha, b⟩.
                let d = ea_vtx[ia];
                if d != self.b {
                    if let Some(obd) = nb.edge_order(self.b, d) {
                        if obd < ha {
                            let key = Key::new(ha, self.b);
                            if key >= min_key {
                                self.ia = ia as u32;
                                self.ib = ib as u32;
                                self.cur = key;
                                return;
                            }
                        }
                    }
                }
                ia += 1;
            } else {
                // Candidate diameter edge {b,d}; triangle {a,b,d}, key ⟨hb, a⟩.
                let d = eb_vtx[ib];
                if d != self.a {
                    if let Some(oad) = nb.edge_order(self.a, d) {
                        if oad < hb {
                            let key = Key::new(hb, self.a);
                            if key >= min_key {
                                self.ia = ia as u32;
                                self.ib = ib as u32;
                                self.cur = key;
                                return;
                            }
                        }
                    }
                }
                ib += 1;
            }
        }
    }
}

/// Append the H1\* column ids (edge orders, descending) of `range` that
/// survive dim-0 clearing (`negative[e]` edges killed a component and
/// are skipped). The per-shard primitive of the sharded H1\*
/// enumeration: tiling `0..n_e` with descending ranges and
/// concatenating the outputs reproduces the sequential
/// `(0..n_e).rev().filter(..)` stream exactly.
pub fn edge_columns_in_range(range: std::ops::Range<u32>, negative: &[bool], out: &mut Vec<u64>) {
    for e in range.rev() {
        if !negative[e as usize] {
            out.push(e as u64);
        }
    }
}

/// Apparent-pair test for an edge column given its precomputed smallest
/// cofacet triangle (paper §4.3.5). The maximal equal-diameter facet of
/// a case-1 triangle `⟨e, v⟩` is the diameter edge `e` itself (its two
/// other edges are strictly smaller by construction), so the
/// cofacet→facet round-trip degenerates to a primary-key comparison:
/// `(e, smallest_cofacet)` is an apparent (trivial, zero-persistence)
/// pair iff the smallest triangle of `δe` has diameter `e`.
#[inline]
pub fn is_apparent_edge_pair(e: u32, smallest_cofacet: Key) -> bool {
    !smallest_cofacet.is_none() && smallest_cofacet.p == e
}

/// [`edge_columns_in_range`] with the in-shard apparent-pair shortcut:
/// edges forming an apparent pair with their smallest cofacet (see
/// [`is_apparent_edge_pair`]; `smallest_tri[e]` is the precomputed
/// smallest triangle of `δe`) are resolved right here — counted, never
/// emitted into the column stream, never reduced. Dim-0 clearing is
/// checked first, exactly as the unshortcut stream would (a negative
/// edge is cleared before any trivial probe could see it). Returns the
/// number of shortcut columns in the range.
///
/// Exactness: an apparent column's reduction claims its own trivial
/// pivot at the very first `find_low` — it stores no pair, owns no
/// entry in p⊥/V⊥ (trivial pivots never enter the committed maps), and
/// other columns probe trivial owners against the *space*, not the
/// stream — so suppressing it leaves every other column's reduction,
/// and the output, bit-identical (`rust/tests/differential.rs`).
pub fn edge_columns_in_range_shortcut(
    range: std::ops::Range<u32>,
    negative: &[bool],
    smallest_tri: &[Key],
    out: &mut Vec<u64>,
) -> usize {
    let mut skipped = 0usize;
    for e in range.rev() {
        if negative[e as usize] {
            continue;
        }
        if is_apparent_edge_pair(e, smallest_tri[e as usize]) {
            skipped += 1;
        } else {
            out.push(e as u64);
        }
    }
    skipped
}

/// Reference enumeration of `δe` by brute force, in key order. Test oracle.
pub fn brute_force_coboundary(
    nb: &Neighborhoods,
    f: &crate::filtration::EdgeFiltration,
    e: u32,
) -> Vec<Key> {
    let (a, b) = f.edges[e as usize];
    let mut out = Vec::new();
    for v in 0..f.n {
        if v == a || v == b {
            continue;
        }
        let (oav, obv) = match (nb.edge_order(a, v), nb.edge_order(b, v)) {
            (Some(x), Some(y)) => (x, y),
            _ => continue,
        };
        // Key of {a,b,v}: primary = diameter edge order, secondary = vertex
        // opposite the diameter edge.
        let m = oav.max(obv).max(e);
        let key = if m == e {
            Key::new(e, v)
        } else if m == oav {
            Key::new(oav, b)
        } else {
            Key::new(obv, a)
        };
        out.push(key);
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filtration::EdgeFiltration;
    use crate::geometry::{MetricData, PointCloud};
    use crate::util::rng::Pcg32;

    fn random_cloud(n: usize, dim: usize, seed: u64) -> MetricData {
        let mut rng = Pcg32::new(seed);
        let coords = (0..n * dim).map(|_| rng.next_f64()).collect();
        MetricData::Points(PointCloud::new(dim, coords))
    }

    fn enumerate_with_cursor(nb: &Neighborhoods, f: &EdgeFiltration, e: u32) -> Vec<Key> {
        let (a, b) = f.edges[e as usize];
        let mut c = TriCursor::find_smallest(nb, e, a, b);
        let mut out = Vec::new();
        while !c.cur.is_none() {
            out.push(c.cur);
            c.find_next(nb);
        }
        out
    }

    #[test]
    fn cursor_matches_brute_force_on_random_clouds() {
        for seed in 0..8 {
            let data = random_cloud(24, 3, seed);
            let f = EdgeFiltration::build(&data, 0.8);
            for dense in [false, true] {
                let nb = Neighborhoods::build(&f, dense);
                for e in 0..f.n_edges() as u32 {
                    let got = enumerate_with_cursor(&nb, &f, e);
                    let want = brute_force_coboundary(&nb, &f, e);
                    assert_eq!(got, want, "seed={seed} e={e} dense={dense}");
                }
            }
        }
    }

    #[test]
    fn enumeration_is_strictly_increasing() {
        let data = random_cloud(30, 2, 99);
        let f = EdgeFiltration::build(&data, 0.7);
        let nb = Neighborhoods::build(&f, false);
        for e in 0..f.n_edges() as u32 {
            let keys = enumerate_with_cursor(&nb, &f, e);
            for w in keys.windows(2) {
                assert!(w[0] < w[1], "e={e}: {} !< {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn find_geq_agrees_with_linear_scan() {
        let data = random_cloud(20, 3, 7);
        let f = EdgeFiltration::build(&data, 1.0);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges() as u32;
        let mut rng = Pcg32::new(123);
        for e in 0..ne {
            let (a, b) = f.edges[e as usize];
            let all = brute_force_coboundary(&nb, &f, e);
            // Probe with every actual key, keys just above/below, and randoms.
            let mut targets: Vec<Key> = all.clone();
            targets.push(Key::new(0, 0));
            targets.push(Key::new(ne, 0));
            for _ in 0..10 {
                targets.push(Key::new(rng.gen_range(ne), rng.gen_range(f.n)));
            }
            for t in targets {
                let c = TriCursor::find_geq(&nb, e, a, b, t);
                let want = all.iter().copied().find(|&k| k >= t).unwrap_or(Key::NONE);
                assert_eq!(c.cur, want, "e={e} target={t}");
            }
        }
    }

    #[test]
    fn find_geq_matches_resumed_cursor_state() {
        // A cursor advanced step-by-step must equal a fresh find_geq cursor
        // at the same triangle — canonical state (cancellation relies on it).
        let data = random_cloud(22, 3, 5);
        let f = EdgeFiltration::build(&data, 0.9);
        let nb = Neighborhoods::build(&f, false);
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            let mut c = TriCursor::find_smallest(&nb, e, a, b);
            while !c.cur.is_none() {
                let fresh = TriCursor::find_geq(&nb, e, a, b, c.cur);
                assert_eq!(c, fresh, "state must be canonical at {}", c.cur);
                c.find_next(&nb);
            }
        }
    }

    #[test]
    fn edge_column_shards_tile_to_sequential_stream() {
        let mut rng = Pcg32::new(31);
        let ne = 57u32;
        let negative: Vec<bool> = (0..ne).map(|_| rng.next_f64() < 0.3).collect();
        let want: Vec<u64> = (0..ne as u64)
            .rev()
            .filter(|&e| !negative[e as usize])
            .collect();
        for grain in [1u32, 4, 13, ne] {
            let mut got = Vec::new();
            let mut hi = ne;
            while hi > 0 {
                let lo = hi.saturating_sub(grain);
                edge_columns_in_range(lo..hi, &negative, &mut got);
                hi = lo;
            }
            assert_eq!(got, want, "grain={grain}");
        }
    }

    #[test]
    fn shortcut_stream_drops_exactly_the_apparent_edges() {
        // Real filtration: the shortcut stream must equal the plain
        // stream minus the apparent-pair edges, for every tiling, with
        // skip counts adding up across shards.
        let data = random_cloud(26, 3, 17);
        let f = EdgeFiltration::build(&data, 0.9);
        let nb = Neighborhoods::build(&f, false);
        let ne = f.n_edges() as u32;
        let smallest: Vec<Key> = (0..ne)
            .map(|e| {
                let (a, b) = f.edges[e as usize];
                TriCursor::find_smallest(&nb, e, a, b).cur
            })
            .collect();
        let mut rng = Pcg32::new(5);
        let negative: Vec<bool> = (0..ne).map(|_| rng.next_f64() < 0.25).collect();
        let mut plain: Vec<u64> = Vec::new();
        edge_columns_in_range(0..ne, &negative, &mut plain);
        let want: Vec<u64> = plain
            .iter()
            .copied()
            .filter(|&e| !is_apparent_edge_pair(e as u32, smallest[e as usize]))
            .collect();
        let want_skipped = plain.len() - want.len();
        // Apparent pairs always exist on a dense-enough cloud; make the
        // test meaningful.
        assert!(want_skipped > 0, "need at least one apparent pair");
        for grain in [1u32, 4, 13, ne] {
            let mut got = Vec::new();
            let mut skipped = 0usize;
            let mut hi = ne;
            while hi > 0 {
                let lo = hi.saturating_sub(grain);
                skipped += edge_columns_in_range_shortcut(lo..hi, &negative, &smallest, &mut got);
                hi = lo;
            }
            assert_eq!(got, want, "grain={grain}");
            assert_eq!(skipped, want_skipped, "grain={grain}");
        }
        // An apparent edge pair has equal birth/death diameters by
        // construction (the cofacet's diameter IS the edge).
        for e in 0..ne {
            if is_apparent_edge_pair(e, smallest[e as usize]) {
                assert_eq!(
                    f.key_value(smallest[e as usize]).to_bits(),
                    f.values[e as usize].to_bits(),
                    "apparent pair must have zero persistence (e={e})"
                );
            }
        }
    }

    #[test]
    fn empty_coboundary() {
        // Two isolated edges -> no triangles at all.
        let pc = PointCloud::new(1, vec![0.0, 1.0, 10.0, 11.0]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 2.0);
        let nb = Neighborhoods::build(&f, false);
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            let c = TriCursor::find_smallest(&nb, e, a, b);
            assert!(c.cur.is_none());
        }
    }
}
