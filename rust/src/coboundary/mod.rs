//! On-the-fly coboundary enumeration (paper §4.2).
//!
//! The coboundary matrix is never stored. A *cursor* (the paper's
//! φ-representation) pins one simplex of a coboundary column and can move
//! to the next-greater simplex (`find_next`) or jump to the first simplex
//! ≥ a target key (`find_geq`) using only the sorted neighborhoods —
//! binary searches, no materialization.

pub mod edges;
pub mod triangles;

pub use edges::TriCursor;
pub use triangles::TetCursor;
