//! Run configuration: a small TOML-subset parser plus typed config.
//!
//! The offline vendor set has no serde/toml, so we parse the subset we
//! need: `[section]` headers, `key = value` with string / number / bool
//! values, `#` comments. Unknown keys are rejected (typo safety).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse the TOML subset into section -> key -> value.
pub fn parse_toml(text: &str) -> Result<HashMap<String, HashMap<String, Value>>> {
    let mut out: HashMap<String, HashMap<String, Value>> = HashMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed section header", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let val = parse_value(v.trim())
            .with_context(|| format!("line {}: bad value for {key}", lineno + 1))?;
        out.entry(section.clone()).or_default().insert(key, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        "inf" => return Ok(Value::Num(f64::INFINITY)),
        _ => {}
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Num(x));
    }
    bail!("cannot parse value: {s}")
}

/// Which data source a run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    Named {
        kind: String,
        n: usize,
        seed: u64,
    },
    Hic {
        n_bins: usize,
        condition: String,
        seed: u64,
    },
    PointsFile(PathBuf),
    LowerDistanceFile(PathBuf),
    SparseFile(PathBuf),
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetSpec,
    pub tau: f64,
    pub max_dim: usize,
    pub threads: usize,
    pub batch_size: usize,
    /// Pipelined scheduler: adapt the batch size to the observed
    /// serial/push time ratio (correctness is batch-size independent).
    pub adaptive_batch: bool,
    pub batch_min: usize,
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto.
    pub steal_grain: usize,
    /// Serial-fraction bounds for the batch adaptation (double below
    /// `adapt_low`, halve above `adapt_high`).
    pub adapt_low: f64,
    pub adapt_high: f64,
    /// Shards for the pooled H1*/H2* column enumeration; 0 = auto.
    pub enum_shards: usize,
    /// Diameter edges per enumeration shard; 0 = auto (wins over
    /// `enum_shards` when both are set).
    pub enum_grain: usize,
    /// Apparent-pair shortcut at enumeration time (on by default; off =
    /// exact fallback for differential testing).
    pub shortcut: bool,
    /// Point rows per front-end distance tile; 0 = auto.
    pub f1_tile: usize,
    /// Enclosing-radius truncation of the filtration when `tau` is
    /// infinite (on by default; diagrams are unchanged, the edge set
    /// shrinks). `--no-enclosing` = exact full-filtration fallback.
    pub enclosing: bool,
    pub dense_lookup: bool,
    pub algorithm: String,
    pub artifacts: PathBuf,
    pub use_pjrt: bool,
    pub pimage: bool,
    pub pimage_span: f64,
    pub diagram_csv: Option<PathBuf>,
    pub diagram_json: Option<PathBuf>,
    pub summary_json: Option<PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 200,
                seed: 1,
            },
            tau: f64::INFINITY,
            max_dim: 2,
            threads: 4,
            batch_size: 100,
            adaptive_batch: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
            adapt_low: 0.25,
            adapt_high: 0.75,
            enum_shards: 0,
            enum_grain: 0,
            shortcut: true,
            f1_tile: 0,
            enclosing: true,
            dense_lookup: false,
            algorithm: "fast-column".into(),
            artifacts: PathBuf::from("artifacts"),
            use_pjrt: true,
            pimage: false,
            pimage_span: 1.0,
            diagram_csv: None,
            diagram_json: None,
            summary_json: None,
        }
    }
}

impl RunConfig {
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = RunConfig::default();
        for (section, keys) in &doc {
            match section.as_str() {
                "dataset" => {
                    let kind = keys
                        .get("kind")
                        .and_then(Value::as_str)
                        .unwrap_or("circle")
                        .to_string();
                    let n = keys.get("n").and_then(Value::as_usize).unwrap_or(200);
                    let seed = keys
                        .get("seed")
                        .and_then(Value::as_usize)
                        .unwrap_or(1) as u64;
                    cfg.dataset = match kind.as_str() {
                        "hic" => DatasetSpec::Hic {
                            n_bins: n,
                            condition: keys
                                .get("condition")
                                .and_then(Value::as_str)
                                .unwrap_or("control")
                                .to_string(),
                            seed,
                        },
                        "points-file" => DatasetSpec::PointsFile(path_key(keys, "path")?),
                        "lower-distance-file" => {
                            DatasetSpec::LowerDistanceFile(path_key(keys, "path")?)
                        }
                        "sparse-file" => DatasetSpec::SparseFile(path_key(keys, "path")?),
                        _ => DatasetSpec::Named { kind, n, seed },
                    };
                    for k in keys.keys() {
                        if !["kind", "n", "seed", "condition", "path"].contains(&k.as_str()) {
                            bail!("unknown key dataset.{k}");
                        }
                    }
                }
                "engine" => {
                    for (k, v) in keys {
                        match k.as_str() {
                            "tau" => cfg.tau = v.as_f64().context("engine.tau")?,
                            "max_dim" => cfg.max_dim = v.as_usize().context("engine.max_dim")?,
                            "threads" => cfg.threads = v.as_usize().context("engine.threads")?,
                            "batch_size" => {
                                cfg.batch_size = v.as_usize().context("engine.batch_size")?
                            }
                            "adaptive_batch" => {
                                cfg.adaptive_batch =
                                    v.as_bool().context("engine.adaptive_batch")?
                            }
                            "batch_min" => {
                                cfg.batch_min = v.as_usize().context("engine.batch_min")?
                            }
                            "batch_max" => {
                                cfg.batch_max = v.as_usize().context("engine.batch_max")?
                            }
                            "steal_grain" => {
                                cfg.steal_grain = v.as_usize().context("engine.steal_grain")?
                            }
                            "adapt_low" => {
                                cfg.adapt_low = v.as_f64().context("engine.adapt_low")?
                            }
                            "adapt_high" => {
                                cfg.adapt_high = v.as_f64().context("engine.adapt_high")?
                            }
                            "enum_shards" => {
                                cfg.enum_shards = v.as_usize().context("engine.enum_shards")?
                            }
                            "enum_grain" => {
                                cfg.enum_grain = v.as_usize().context("engine.enum_grain")?
                            }
                            "shortcut" => {
                                cfg.shortcut = v.as_bool().context("engine.shortcut")?
                            }
                            "f1_tile" => {
                                cfg.f1_tile = v.as_usize().context("engine.f1_tile")?
                            }
                            "enclosing" => {
                                cfg.enclosing = v.as_bool().context("engine.enclosing")?
                            }
                            "dense_lookup" => {
                                cfg.dense_lookup = v.as_bool().context("engine.dense_lookup")?
                            }
                            "algorithm" => {
                                cfg.algorithm =
                                    v.as_str().context("engine.algorithm")?.to_string()
                            }
                            _ => bail!("unknown key engine.{k}"),
                        }
                    }
                }
                "runtime" => {
                    for (k, v) in keys {
                        match k.as_str() {
                            "artifacts" => {
                                cfg.artifacts =
                                    PathBuf::from(v.as_str().context("runtime.artifacts")?)
                            }
                            "use_pjrt" => {
                                cfg.use_pjrt = v.as_bool().context("runtime.use_pjrt")?
                            }
                            "pimage" => cfg.pimage = v.as_bool().context("runtime.pimage")?,
                            "pimage_span" => {
                                cfg.pimage_span = v.as_f64().context("runtime.pimage_span")?
                            }
                            _ => bail!("unknown key runtime.{k}"),
                        }
                    }
                }
                "output" => {
                    for (k, v) in keys {
                        let p = Some(PathBuf::from(v.as_str().context("output path")?));
                        match k.as_str() {
                            "diagram_csv" => cfg.diagram_csv = p,
                            "diagram_json" => cfg.diagram_json = p,
                            "summary_json" => cfg.summary_json = p,
                            _ => bail!("unknown key output.{k}"),
                        }
                    }
                }
                other => bail!("unknown section [{other}]"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_dim > 2 {
            bail!("max_dim must be <= 2 (paper scope)");
        }
        if !["fast-column", "implicit-row"].contains(&self.algorithm.as_str()) {
            bail!("algorithm must be fast-column or implicit-row");
        }
        if self.threads == 0 || self.batch_size == 0 {
            bail!("threads and batch_size must be >= 1");
        }
        if self.batch_min == 0 || self.batch_min > self.batch_max {
            bail!("batch_min must be >= 1 and <= batch_max");
        }
        if !(0.0..=1.0).contains(&self.adapt_low)
            || !(0.0..=1.0).contains(&self.adapt_high)
            || self.adapt_low > self.adapt_high
        {
            bail!("adapt_low/adapt_high must satisfy 0 <= adapt_low <= adapt_high <= 1");
        }
        Ok(())
    }
}

fn path_key(keys: &HashMap<String, Value>, k: &str) -> Result<PathBuf> {
    Ok(PathBuf::from(
        keys.get(k)
            .and_then(Value::as_str)
            .with_context(|| format!("dataset.{k} required"))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_str(
            r#"
# A full run config
[dataset]
kind = "torus4"
n = 5000
seed = 42

[engine]
tau = 0.15
max_dim = 2
threads = 4
batch_size = 100
dense_lookup = false
algorithm = "fast-column"

[runtime]
artifacts = "artifacts"
use_pjrt = true

[output]
diagram_csv = "out/pd.csv"
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Named {
                kind: "torus4".into(),
                n: 5000,
                seed: 42
            }
        );
        assert_eq!(cfg.tau, 0.15);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.diagram_csv, Some(PathBuf::from("out/pd.csv")));
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_str("[engine]\nbogus = 1\n").is_err());
        assert!(RunConfig::from_str("[bogus]\n").is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(RunConfig::from_str("[engine]\nmax_dim = 3\n").is_err());
        assert!(RunConfig::from_str("[engine]\nalgorithm = \"quantum\"\n").is_err());
        assert!(RunConfig::from_str("[engine]\nthreads = 0\n").is_err());
        assert!(RunConfig::from_str("[engine]\nbatch_min = 0\n").is_err());
        assert!(RunConfig::from_str("[engine]\nbatch_min = 64\nbatch_max = 8\n").is_err());
    }

    #[test]
    fn scheduler_knobs_parse() {
        let cfg = RunConfig::from_str(
            "[engine]\nadaptive_batch = false\nbatch_min = 4\nbatch_max = 256\nsteal_grain = 8\n",
        )
        .unwrap();
        assert!(!cfg.adaptive_batch);
        assert_eq!(cfg.batch_min, 4);
        assert_eq!(cfg.batch_max, 256);
        assert_eq!(cfg.steal_grain, 8);
    }

    #[test]
    fn enumeration_and_adaptation_knobs_parse() {
        let cfg = RunConfig::from_str(
            "[engine]\nenum_shards = 12\nenum_grain = 64\nadapt_low = 0.1\nadapt_high = 0.9\n",
        )
        .unwrap();
        assert_eq!(cfg.enum_shards, 12);
        assert_eq!(cfg.enum_grain, 64);
        assert_eq!(cfg.adapt_low, 0.1);
        assert_eq!(cfg.adapt_high, 0.9);
        // Defaults match the original hard-coded 25%/75% thresholds.
        let d = RunConfig::default();
        assert_eq!((d.adapt_low, d.adapt_high), (0.25, 0.75));
        assert_eq!((d.enum_shards, d.enum_grain), (0, 0));
    }

    #[test]
    fn frontend_knobs_parse_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.f1_tile, 0);
        assert!(d.enclosing);
        let cfg = RunConfig::from_str("[engine]\nf1_tile = 64\nenclosing = false\n").unwrap();
        assert_eq!(cfg.f1_tile, 64);
        assert!(!cfg.enclosing);
        assert!(RunConfig::from_str("[engine]\nenclosing = 1\n").is_err());
        assert!(RunConfig::from_str("[engine]\nf1_tile = -3\n").is_err());
    }

    #[test]
    fn shortcut_knob_parses_and_defaults_on() {
        assert!(RunConfig::default().shortcut);
        let cfg = RunConfig::from_str("[engine]\nshortcut = false\n").unwrap();
        assert!(!cfg.shortcut);
        let cfg = RunConfig::from_str("[engine]\nshortcut = true\n").unwrap();
        assert!(cfg.shortcut);
        assert!(RunConfig::from_str("[engine]\nshortcut = 1\n").is_err());
    }

    #[test]
    fn rejects_bad_adaptation_bounds() {
        assert!(RunConfig::from_str("[engine]\nadapt_low = 0.8\nadapt_high = 0.2\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_high = 1.5\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_low = -0.1\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_low = 0.5\nadapt_high = 0.5\n").is_ok());
    }

    #[test]
    fn inf_and_comments_and_bools() {
        let doc = parse_toml("a = inf # trailing\nb = true\nc = \"x # not comment\"\n").unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], Value::Num(f64::INFINITY));
        assert_eq!(root["b"], Value::Bool(true));
        assert_eq!(root["c"], Value::Str("x # not comment".into()));
    }

    #[test]
    fn hic_dataset_spec() {
        let cfg = RunConfig::from_str(
            "[dataset]\nkind = \"hic\"\nn = 10000\ncondition = \"auxin\"\n[engine]\ntau = 400\n",
        )
        .unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Hic {
                n_bins: 10000,
                condition: "auxin".into(),
                seed: 1
            }
        );
    }
}
