//! Run configuration: a small TOML-subset parser plus typed config.
//!
//! The offline vendor set has no serde/toml, so we parse the subset we
//! need: `[section]` headers, `[[array]]` array-of-tables headers,
//! `key = value` with string / number / bool values, `#` comments.
//! Unknown keys are rejected (typo safety). Every parse failure is a
//! typed [`DoryError::Config`].
//!
//! A config may carry a `[[query]]` array: each entry is one PH query
//! (τ plus optional per-query `max_dim`/`shortcut`/`enclosing`/`label`
//! overrides) and the coordinator serves the whole array from **one**
//! dataset ingest over the session layer ([`crate::coordinator::run_batch`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::DoryError;

type Result<T> = std::result::Result<T, DoryError>;

fn cfg_err(msg: impl std::fmt::Display) -> DoryError {
    DoryError::Config(msg.to_string())
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    /// A flat `[a, b, c]` array (no nesting — the subset the configs
    /// need, e.g. `features = ["betti:64", "entropy"]`).
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML-subset document: plain `[section]` tables plus
/// `[[name]]` array-of-tables entries in file order.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: HashMap<String, HashMap<String, Value>>,
    pub arrays: Vec<(String, HashMap<String, Value>)>,
}

/// Parse the TOML subset, including `[[array]]` headers.
pub fn parse_toml_doc(text: &str) -> Result<TomlDoc> {
    // Where the current `key = value` lines land: a named section map,
    // or the newest entry of a named array.
    enum Target {
        Section(String),
        Array(usize),
    }
    let mut doc = TomlDoc::default();
    let mut target = Target::Section(String::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| cfg_err(format!("line {}: malformed [[array]] header", lineno + 1)))?
                .trim()
                .to_string();
            if name.is_empty() {
                return Err(cfg_err(format!("line {}: empty [[array]] name", lineno + 1)));
            }
            doc.arrays.push((name, HashMap::new()));
            target = Target::Array(doc.arrays.len() - 1);
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(cfg_err(format!(
                    "line {}: malformed section header",
                    lineno + 1
                )));
            }
            let section = line[1..line.len() - 1].trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            target = Target::Section(section);
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| cfg_err(format!("line {}: expected key = value", lineno + 1)))?;
        let key = k.trim().to_string();
        let val = parse_value(v.trim())
            .ok_or_else(|| cfg_err(format!("line {}: bad value for {key}", lineno + 1)))?;
        match &target {
            Target::Section(s) => {
                doc.sections.entry(s.clone()).or_default().insert(key, val);
            }
            Target::Array(i) => {
                doc.arrays[*i].1.insert(key, val);
            }
        }
    }
    Ok(doc)
}

/// Parse the TOML subset into section -> key -> value (no arrays;
/// documents with `[[array]]` headers are rejected — use
/// [`parse_toml_doc`]).
pub fn parse_toml(text: &str) -> Result<HashMap<String, HashMap<String, Value>>> {
    let doc = parse_toml_doc(text)?;
    if let Some((name, _)) = doc.arrays.first() {
        return Err(cfg_err(format!(
            "[[{name}]] arrays are not supported here; use parse_toml_doc"
        )));
    }
    Ok(doc.sections)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Some(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        let inner = s.strip_prefix('[')?.strip_suffix(']')?.trim();
        let mut items = Vec::new();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                let part = part.trim();
                let v = parse_value(part)?;
                if matches!(v, Value::Arr(_)) {
                    return None; // no nested arrays in the subset
                }
                items.push(v);
            }
        }
        return Some(Value::Arr(items));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        "inf" => return Some(Value::Num(f64::INFINITY)),
        _ => {}
    }
    s.parse::<f64>().ok().map(Value::Num)
}

/// Split an array body on commas that sit outside string quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Decode a `features = [...]` value into typed specs; `where_` labels
/// the error ("engine.features" / "query.features").
fn feature_list(v: &Value, where_: &str) -> Result<Vec<crate::features::FeatureSpec>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| cfg_err(format!("{where_}: expected an array of strings")))?;
    let mut specs = Vec::with_capacity(arr.len());
    for item in arr {
        let s = item
            .as_str()
            .ok_or_else(|| cfg_err(format!("{where_}: expected an array of strings")))?;
        specs.push(
            crate::features::FeatureSpec::parse(s)
                .map_err(|e| cfg_err(format!("{where_}: {e}")))?,
        );
    }
    Ok(specs)
}

/// Which data source a run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    Named {
        kind: String,
        n: usize,
        seed: u64,
    },
    Hic {
        n_bins: usize,
        condition: String,
        seed: u64,
    },
    PointsFile(PathBuf),
    LowerDistanceFile(PathBuf),
    SparseFile(PathBuf),
}

/// One entry of the `[[query]]` array (or one repeated CLI `--tau`):
/// a τ plus optional per-query knob overrides. `None` inherits the
/// `[engine]` value.
#[derive(Clone, Debug, PartialEq)]
pub struct QuerySpec {
    pub tau: f64,
    pub max_dim: Option<usize>,
    pub shortcut: Option<bool>,
    pub enclosing: Option<bool>,
    pub label: Option<String>,
    /// Per-query deadline in milliseconds; `None` inherits the
    /// `[engine] timeout_ms` value (itself optional). An expired
    /// deadline aborts that query with a typed `DeadlineExceeded`
    /// without disturbing the shared ingest.
    pub timeout_ms: Option<u64>,
    /// Derived feature products to compute after the reduction
    /// (`features = ["betti:64", "entropy", ...]`). Empty inherits the
    /// `[engine] features` list.
    pub features: Vec<crate::features::FeatureSpec>,
}

impl QuerySpec {
    pub fn at(tau: f64) -> Self {
        Self {
            tau,
            max_dim: None,
            shortcut: None,
            enclosing: None,
            label: None,
            timeout_ms: None,
            features: Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetSpec,
    pub tau: f64,
    pub max_dim: usize,
    pub threads: usize,
    pub batch_size: usize,
    /// Pipelined scheduler: adapt the batch size to the observed
    /// serial/push time ratio (correctness is batch-size independent).
    pub adaptive_batch: bool,
    pub batch_min: usize,
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto.
    pub steal_grain: usize,
    /// Serial-fraction bounds for the batch adaptation (double below
    /// `adapt_low`, halve above `adapt_high`).
    pub adapt_low: f64,
    pub adapt_high: f64,
    /// Shards for the pooled H1*/H2* column enumeration; 0 = auto.
    pub enum_shards: usize,
    /// Diameter edges per enumeration shard; 0 = auto (wins over
    /// `enum_shards` when both are set).
    pub enum_grain: usize,
    /// Apparent-pair shortcut at enumeration time (on by default; off =
    /// exact fallback for differential testing).
    pub shortcut: bool,
    /// Point rows per front-end distance tile; 0 = auto.
    pub f1_tile: usize,
    /// Enclosing-radius truncation of the filtration when `tau` is
    /// infinite (on by default; diagrams are unchanged, the edge set
    /// shrinks). `--no-enclosing` = exact full-filtration fallback.
    pub enclosing: bool,
    /// Distance microkernel for the dense front-end: `auto` (default,
    /// runtime CPU probe), `scalar`, `avx2`, or `neon`. Forced vector
    /// modes degrade to scalar when the feature is absent; the emitted
    /// edge bits are identical for every choice.
    pub simd: String,
    /// Lines per chunk for the streaming sparse-file reader. Any
    /// nonzero value (or a nonzero `edge_budget_mb`) routes
    /// `sparse-file` datasets through the streaming ingest path;
    /// 0 + budget 0 = the in-memory reader. Output is bit-identical
    /// either way.
    pub stream_chunk: usize,
    /// Greedy-net k-NN front-end for point clouds: keep at most this
    /// many nearest kept neighbors per point (union-symmetrized),
    /// building edges from the cover graph instead of the dense O(n²)
    /// pass. 0 = off (exact dense pass). Approximate when it actually
    /// caps; composes with the net-based enclosing bound at τ = ∞.
    pub knn_k: usize,
    /// Staging budget (MiB) for the streaming ingest paths; sorted key
    /// runs spill to disk past it. On a `sparse-file` dataset it (or
    /// `stream_chunk`) routes through the streaming reader; on an
    /// in-memory point cloud or distance table (with `knn_k` off) it
    /// routes the dense front-end tiles through the spill store
    /// (`edge_source = "dense-stream"`, bit-identical output).
    /// 0 = unbounded in-memory staging.
    pub edge_budget_mb: usize,
    /// Refuse the in-memory degradation fallback when a spill write
    /// keeps failing: strict mode surfaces the typed I/O error instead
    /// of absorbing the fault into unbounded staging memory.
    pub strict_spill: bool,
    /// Default per-query deadline in milliseconds (`None` = no
    /// deadline). Individual `[[query]]` entries override it.
    pub timeout_ms: Option<u64>,
    /// Default derived feature products for every query (`[engine]
    /// features = [...]` or CLI `--features`). A `[[query]]` entry with
    /// its own non-empty `features` list overrides this.
    pub features: Vec<crate::features::FeatureSpec>,
    pub dense_lookup: bool,
    pub algorithm: String,
    pub artifacts: PathBuf,
    pub use_pjrt: bool,
    pub pimage: bool,
    pub pimage_span: f64,
    pub diagram_csv: Option<PathBuf>,
    pub diagram_json: Option<PathBuf>,
    pub summary_json: Option<PathBuf>,
    /// Batch mode: the `[[query]]` array (or repeated CLI `--tau`
    /// flags). Empty = one query at `tau`. All queries are served from
    /// **one** dataset ingest over the session layer, at the largest
    /// query τ ([`Self::ingest_tau`]); when the array is non-empty,
    /// `tau` only participates as the single-query fallback and is
    /// otherwise ignored.
    pub queries: Vec<QuerySpec>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 200,
                seed: 1,
            },
            tau: f64::INFINITY,
            max_dim: 2,
            threads: 4,
            batch_size: 100,
            adaptive_batch: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
            adapt_low: 0.25,
            adapt_high: 0.75,
            enum_shards: 0,
            enum_grain: 0,
            shortcut: true,
            f1_tile: 0,
            enclosing: true,
            simd: "auto".into(),
            stream_chunk: 0,
            knn_k: 0,
            edge_budget_mb: 0,
            strict_spill: false,
            timeout_ms: None,
            features: Vec::new(),
            dense_lookup: false,
            algorithm: "fast-column".into(),
            artifacts: PathBuf::from("artifacts"),
            use_pjrt: true,
            pimage: false,
            pimage_span: 1.0,
            diagram_csv: None,
            diagram_json: None,
            summary_json: None,
            queries: Vec::new(),
        }
    }
}

impl RunConfig {
    pub fn from_file(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).map_err(|e| DoryError::io(path, e))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<Self> {
        let doc = parse_toml_doc(text)?;
        let mut cfg = RunConfig::default();
        for (section, keys) in &doc.sections {
            match section.as_str() {
                "dataset" => {
                    let kind = keys
                        .get("kind")
                        .and_then(Value::as_str)
                        .unwrap_or("circle")
                        .to_string();
                    let n = keys.get("n").and_then(Value::as_usize).unwrap_or(200);
                    let seed = keys
                        .get("seed")
                        .and_then(Value::as_usize)
                        .unwrap_or(1) as u64;
                    cfg.dataset = match kind.as_str() {
                        "hic" => DatasetSpec::Hic {
                            n_bins: n,
                            condition: keys
                                .get("condition")
                                .and_then(Value::as_str)
                                .unwrap_or("control")
                                .to_string(),
                            seed,
                        },
                        "points-file" => DatasetSpec::PointsFile(path_key(keys, "path")?),
                        "lower-distance-file" => {
                            DatasetSpec::LowerDistanceFile(path_key(keys, "path")?)
                        }
                        "sparse-file" => DatasetSpec::SparseFile(path_key(keys, "path")?),
                        _ => DatasetSpec::Named { kind, n, seed },
                    };
                    for k in keys.keys() {
                        if !["kind", "n", "seed", "condition", "path"].contains(&k.as_str()) {
                            return Err(cfg_err(format!("unknown key dataset.{k}")));
                        }
                    }
                }
                "engine" => {
                    for (k, v) in keys {
                        let num = || {
                            v.as_f64()
                                .ok_or_else(|| cfg_err(format!("engine.{k}: expected a number")))
                        };
                        let uint = || {
                            v.as_usize()
                                .ok_or_else(|| cfg_err(format!("engine.{k}: expected a non-negative integer")))
                        };
                        let flag = || {
                            v.as_bool()
                                .ok_or_else(|| cfg_err(format!("engine.{k}: expected a bool")))
                        };
                        match k.as_str() {
                            "tau" => cfg.tau = num()?,
                            "max_dim" => cfg.max_dim = uint()?,
                            "threads" => cfg.threads = uint()?,
                            "batch_size" => cfg.batch_size = uint()?,
                            "adaptive_batch" => cfg.adaptive_batch = flag()?,
                            "batch_min" => cfg.batch_min = uint()?,
                            "batch_max" => cfg.batch_max = uint()?,
                            "steal_grain" => cfg.steal_grain = uint()?,
                            "adapt_low" => cfg.adapt_low = num()?,
                            "adapt_high" => cfg.adapt_high = num()?,
                            "enum_shards" => cfg.enum_shards = uint()?,
                            "enum_grain" => cfg.enum_grain = uint()?,
                            "shortcut" => cfg.shortcut = flag()?,
                            "f1_tile" => cfg.f1_tile = uint()?,
                            "enclosing" => cfg.enclosing = flag()?,
                            "simd" => {
                                cfg.simd = v
                                    .as_str()
                                    .ok_or_else(|| cfg_err("engine.simd: expected a string"))?
                                    .to_string()
                            }
                            "stream_chunk" => cfg.stream_chunk = uint()?,
                            "knn_k" => cfg.knn_k = uint()?,
                            "edge_budget_mb" => cfg.edge_budget_mb = uint()?,
                            "strict_spill" => cfg.strict_spill = flag()?,
                            "timeout_ms" => cfg.timeout_ms = Some(uint()? as u64),
                            "features" => cfg.features = feature_list(v, "engine.features")?,
                            "dense_lookup" => cfg.dense_lookup = flag()?,
                            "algorithm" => {
                                cfg.algorithm = v
                                    .as_str()
                                    .ok_or_else(|| cfg_err("engine.algorithm: expected a string"))?
                                    .to_string()
                            }
                            _ => return Err(cfg_err(format!("unknown key engine.{k}"))),
                        }
                    }
                }
                "runtime" => {
                    for (k, v) in keys {
                        match k.as_str() {
                            "artifacts" => {
                                cfg.artifacts = PathBuf::from(
                                    v.as_str().ok_or_else(|| {
                                        cfg_err("runtime.artifacts: expected a string")
                                    })?,
                                )
                            }
                            "use_pjrt" => {
                                cfg.use_pjrt = v
                                    .as_bool()
                                    .ok_or_else(|| cfg_err("runtime.use_pjrt: expected a bool"))?
                            }
                            "pimage" => {
                                cfg.pimage = v
                                    .as_bool()
                                    .ok_or_else(|| cfg_err("runtime.pimage: expected a bool"))?
                            }
                            "pimage_span" => {
                                cfg.pimage_span = v.as_f64().ok_or_else(|| {
                                    cfg_err("runtime.pimage_span: expected a number")
                                })?
                            }
                            _ => return Err(cfg_err(format!("unknown key runtime.{k}"))),
                        }
                    }
                }
                "output" => {
                    for (k, v) in keys {
                        let p = Some(PathBuf::from(
                            v.as_str()
                                .ok_or_else(|| cfg_err(format!("output.{k}: expected a path")))?,
                        ));
                        match k.as_str() {
                            "diagram_csv" => cfg.diagram_csv = p,
                            "diagram_json" => cfg.diagram_json = p,
                            "summary_json" => cfg.summary_json = p,
                            _ => return Err(cfg_err(format!("unknown key output.{k}"))),
                        }
                    }
                }
                other => return Err(cfg_err(format!("unknown section [{other}]"))),
            }
        }
        for (name, keys) in &doc.arrays {
            if name != "query" {
                return Err(cfg_err(format!("unknown array [[{name}]]")));
            }
            let mut q = QuerySpec::at(f64::NAN);
            let mut have_tau = false;
            for (k, v) in keys {
                match k.as_str() {
                    "tau" => {
                        q.tau = v
                            .as_f64()
                            .ok_or_else(|| cfg_err("query.tau: expected a number"))?;
                        have_tau = true;
                    }
                    "max_dim" => {
                        q.max_dim = Some(
                            v.as_usize()
                                .ok_or_else(|| cfg_err("query.max_dim: expected an integer"))?,
                        )
                    }
                    "shortcut" => {
                        q.shortcut = Some(
                            v.as_bool()
                                .ok_or_else(|| cfg_err("query.shortcut: expected a bool"))?,
                        )
                    }
                    "enclosing" => {
                        q.enclosing = Some(
                            v.as_bool()
                                .ok_or_else(|| cfg_err("query.enclosing: expected a bool"))?,
                        )
                    }
                    "label" => {
                        q.label = Some(
                            v.as_str()
                                .ok_or_else(|| cfg_err("query.label: expected a string"))?
                                .to_string(),
                        )
                    }
                    "timeout_ms" => {
                        q.timeout_ms = Some(
                            v.as_usize()
                                .ok_or_else(|| cfg_err("query.timeout_ms: expected an integer"))?
                                as u64,
                        )
                    }
                    "features" => q.features = feature_list(v, "query.features")?,
                    _ => return Err(cfg_err(format!("unknown key query.{k}"))),
                }
            }
            if !have_tau {
                return Err(cfg_err("[[query]] entries require a tau"));
            }
            cfg.queries.push(q);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The queries a run serves: the `[[query]]` array, or the single
    /// `[engine] tau` when the array is empty.
    pub fn effective_queries(&self) -> Vec<QuerySpec> {
        if self.queries.is_empty() {
            vec![QuerySpec::at(self.tau)]
        } else {
            self.queries.clone()
        }
    }

    /// The threshold the dataset must be ingested at to serve every
    /// query: the max over query τ and (in single-query mode) `tau`.
    pub fn ingest_tau(&self) -> f64 {
        self.effective_queries()
            .iter()
            .map(|q| q.tau)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_dim > 2 {
            return Err(cfg_err("max_dim must be <= 2 (paper scope)"));
        }
        if !["fast-column", "implicit-row"].contains(&self.algorithm.as_str()) {
            return Err(cfg_err("algorithm must be fast-column or implicit-row"));
        }
        if crate::filtration::SimdMode::parse(&self.simd).is_none() {
            return Err(cfg_err("simd must be auto, scalar, avx2 or neon"));
        }
        if self.threads == 0 || self.batch_size == 0 {
            return Err(cfg_err("threads and batch_size must be >= 1"));
        }
        if self.batch_min == 0 || self.batch_min > self.batch_max {
            return Err(cfg_err("batch_min must be >= 1 and <= batch_max"));
        }
        if !(0.0..=1.0).contains(&self.adapt_low)
            || !(0.0..=1.0).contains(&self.adapt_high)
            || self.adapt_low > self.adapt_high
        {
            return Err(cfg_err(
                "adapt_low/adapt_high must satisfy 0 <= adapt_low <= adapt_high <= 1",
            ));
        }
        if self.tau.is_nan() {
            return Err(cfg_err("tau must not be NaN"));
        }
        if self.tau < 0.0 {
            return Err(cfg_err("tau must be non-negative"));
        }
        for s in &self.features {
            s.validate().map_err(|e| cfg_err(format!("engine.features: {e}")))?;
        }
        for (i, q) in self.queries.iter().enumerate() {
            if q.tau.is_nan() {
                return Err(cfg_err(format!("query #{i}: tau must not be NaN")));
            }
            for s in &q.features {
                s.validate()
                    .map_err(|e| cfg_err(format!("query #{i}: features: {e}")))?;
            }
            if q.tau < 0.0 {
                return Err(cfg_err(format!("query #{i}: tau must be non-negative")));
            }
            if let Some(d) = q.max_dim {
                if d > 2 {
                    return Err(cfg_err(format!(
                        "query #{i}: max_dim must be <= 2 (paper scope)"
                    )));
                }
            }
        }
        Ok(())
    }
}

fn path_key(keys: &HashMap<String, Value>, k: &str) -> Result<PathBuf> {
    Ok(PathBuf::from(
        keys.get(k)
            .and_then(Value::as_str)
            .ok_or_else(|| cfg_err(format!("dataset.{k} required")))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_str(
            r#"
# A full run config
[dataset]
kind = "torus4"
n = 5000
seed = 42

[engine]
tau = 0.15
max_dim = 2
threads = 4
batch_size = 100
dense_lookup = false
algorithm = "fast-column"

[runtime]
artifacts = "artifacts"
use_pjrt = true

[output]
diagram_csv = "out/pd.csv"
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Named {
                kind: "torus4".into(),
                n: 5000,
                seed: 42
            }
        );
        assert_eq!(cfg.tau, 0.15);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.diagram_csv, Some(PathBuf::from("out/pd.csv")));
        assert!(cfg.queries.is_empty());
        assert_eq!(cfg.effective_queries(), vec![QuerySpec::at(0.15)]);
        assert_eq!(cfg.ingest_tau(), 0.15);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_str("[engine]\nbogus = 1\n").is_err());
        assert!(RunConfig::from_str("[bogus]\n").is_err());
        assert!(RunConfig::from_str("[[bogus]]\ntau = 1\n").is_err());
        assert!(RunConfig::from_str("[[query]]\ntau = 1\nbogus = 2\n").is_err());
    }

    #[test]
    fn rejects_invalid_values_with_typed_config_errors() {
        for bad in [
            "[engine]\nmax_dim = 3\n",
            "[engine]\nalgorithm = \"quantum\"\n",
            "[engine]\nthreads = 0\n",
            "[engine]\nbatch_min = 0\n",
            "[engine]\nbatch_min = 64\nbatch_max = 8\n",
            "[engine]\ntau = \"high\"\n",
            "[engine]\ntau = -0.5\n",
            "[engine]\ntau = nan\n",
            "[[query]]\nmax_dim = 1\n", // tau required
            "[[query]]\ntau = 0.5\nmax_dim = 7\n",
            "[[query]]\ntau = -1.0\n",
        ] {
            let e = RunConfig::from_str(bad).unwrap_err();
            assert!(matches!(e, DoryError::Config(_)), "{bad}: {e}");
        }
    }

    #[test]
    fn scheduler_knobs_parse() {
        let cfg = RunConfig::from_str(
            "[engine]\nadaptive_batch = false\nbatch_min = 4\nbatch_max = 256\nsteal_grain = 8\n",
        )
        .unwrap();
        assert!(!cfg.adaptive_batch);
        assert_eq!(cfg.batch_min, 4);
        assert_eq!(cfg.batch_max, 256);
        assert_eq!(cfg.steal_grain, 8);
    }

    #[test]
    fn enumeration_and_adaptation_knobs_parse() {
        let cfg = RunConfig::from_str(
            "[engine]\nenum_shards = 12\nenum_grain = 64\nadapt_low = 0.1\nadapt_high = 0.9\n",
        )
        .unwrap();
        assert_eq!(cfg.enum_shards, 12);
        assert_eq!(cfg.enum_grain, 64);
        assert_eq!(cfg.adapt_low, 0.1);
        assert_eq!(cfg.adapt_high, 0.9);
        // Defaults match the original hard-coded 25%/75% thresholds.
        let d = RunConfig::default();
        assert_eq!((d.adapt_low, d.adapt_high), (0.25, 0.75));
        assert_eq!((d.enum_shards, d.enum_grain), (0, 0));
    }

    #[test]
    fn frontend_knobs_parse_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.f1_tile, 0);
        assert!(d.enclosing);
        let cfg = RunConfig::from_str("[engine]\nf1_tile = 64\nenclosing = false\n").unwrap();
        assert_eq!(cfg.f1_tile, 64);
        assert!(!cfg.enclosing);
        assert!(RunConfig::from_str("[engine]\nenclosing = 1\n").is_err());
        assert!(RunConfig::from_str("[engine]\nf1_tile = -3\n").is_err());
    }

    #[test]
    fn simd_knob_parses_and_defaults_auto() {
        assert_eq!(RunConfig::default().simd, "auto");
        for mode in ["auto", "scalar", "avx2", "neon"] {
            let cfg = RunConfig::from_str(&format!("[engine]\nsimd = \"{mode}\"\n")).unwrap();
            assert_eq!(cfg.simd, mode);
        }
        assert!(RunConfig::from_str("[engine]\nsimd = \"sse9\"\n").is_err());
        assert!(RunConfig::from_str("[engine]\nsimd = true\n").is_err());
    }

    #[test]
    fn shortcut_knob_parses_and_defaults_on() {
        assert!(RunConfig::default().shortcut);
        let cfg = RunConfig::from_str("[engine]\nshortcut = false\n").unwrap();
        assert!(!cfg.shortcut);
        let cfg = RunConfig::from_str("[engine]\nshortcut = true\n").unwrap();
        assert!(cfg.shortcut);
        assert!(RunConfig::from_str("[engine]\nshortcut = 1\n").is_err());
    }

    #[test]
    fn streaming_knobs_parse_and_default_off() {
        let d = RunConfig::default();
        assert_eq!(d.stream_chunk, 0);
        assert_eq!(d.knn_k, 0);
        assert_eq!(d.edge_budget_mb, 0);
        let cfg = RunConfig::from_str(
            "[engine]\nstream_chunk = 4096\nknn_k = 12\nedge_budget_mb = 64\n",
        )
        .unwrap();
        assert_eq!(cfg.stream_chunk, 4096);
        assert_eq!(cfg.knn_k, 12);
        assert_eq!(cfg.edge_budget_mb, 64);
        assert!(RunConfig::from_str("[engine]\nstream_chunk = -1\n").is_err());
        assert!(RunConfig::from_str("[engine]\nknn_k = true\n").is_err());
        assert!(RunConfig::from_str("[engine]\nedge_budget_mb = \"big\"\n").is_err());
    }

    #[test]
    fn resilience_knobs_parse_and_default_off() {
        let d = RunConfig::default();
        assert!(!d.strict_spill);
        assert_eq!(d.timeout_ms, None);
        let cfg = RunConfig::from_str(
            "[engine]\nstrict_spill = true\ntimeout_ms = 2500\n\n[[query]]\ntau = 0.5\ntimeout_ms = 100\n",
        )
        .unwrap();
        assert!(cfg.strict_spill);
        assert_eq!(cfg.timeout_ms, Some(2500));
        assert_eq!(cfg.queries[0].timeout_ms, Some(100));
        assert!(RunConfig::from_str("[engine]\nstrict_spill = 1\n").is_err());
        assert!(RunConfig::from_str("[engine]\ntimeout_ms = -5\n").is_err());
        assert!(RunConfig::from_str("[[query]]\ntau = 1\ntimeout_ms = \"fast\"\n").is_err());
    }

    #[test]
    fn rejects_bad_adaptation_bounds() {
        assert!(RunConfig::from_str("[engine]\nadapt_low = 0.8\nadapt_high = 0.2\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_high = 1.5\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_low = -0.1\n").is_err());
        assert!(RunConfig::from_str("[engine]\nadapt_low = 0.5\nadapt_high = 0.5\n").is_ok());
    }

    #[test]
    fn inf_and_comments_and_bools() {
        let doc = parse_toml("a = inf # trailing\nb = true\nc = \"x # not comment\"\n").unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], Value::Num(f64::INFINITY));
        assert_eq!(root["b"], Value::Bool(true));
        assert_eq!(root["c"], Value::Str("x # not comment".into()));
    }

    #[test]
    fn query_array_parses_in_order() {
        let cfg = RunConfig::from_str(
            r#"
[dataset]
kind = "circle"
n = 64

[engine]
tau = 2.0
max_dim = 2

[[query]]
tau = 0.5
label = "coarse"
max_dim = 1

[[query]]
tau = 1.25
shortcut = false

[[query]]
tau = 2.0
enclosing = true
"#,
        )
        .unwrap();
        assert_eq!(cfg.queries.len(), 3);
        assert_eq!(cfg.queries[0].tau, 0.5);
        assert_eq!(cfg.queries[0].label.as_deref(), Some("coarse"));
        assert_eq!(cfg.queries[0].max_dim, Some(1));
        assert_eq!(cfg.queries[1].shortcut, Some(false));
        assert_eq!(cfg.queries[2].enclosing, Some(true));
        assert_eq!(cfg.effective_queries().len(), 3);
        assert_eq!(cfg.ingest_tau(), 2.0);
        // parse_toml (sections-only) refuses array documents.
        assert!(parse_toml("[[query]]\ntau = 1\n").is_err());
    }

    #[test]
    fn feature_lists_parse_and_inherit() {
        use crate::features::FeatureSpec;
        let cfg = RunConfig::from_str(
            r#"
[engine]
tau = 1.0
features = ["betti:16", "entropy"]

[[query]]
tau = 0.5

[[query]]
tau = 1.0
features = ["image:8", "representatives:0.1"]
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.features,
            vec![FeatureSpec::BettiCurve { grid: 16 }, FeatureSpec::Entropy]
        );
        assert!(cfg.queries[0].features.is_empty()); // inherits engine list
        assert_eq!(
            cfg.queries[1].features,
            vec![
                FeatureSpec::Image { grid: 8 },
                FeatureSpec::Representatives { min_persistence: 0.1 },
            ]
        );
    }

    #[test]
    fn feature_lists_reject_bad_specs() {
        for bad in [
            "[engine]\nfeatures = [\"warp\"]\n",
            "[engine]\nfeatures = [\"betti:0\"]\n",
            "[engine]\nfeatures = [1, 2]\n",
            "[engine]\nfeatures = \"betti\"\n",
            "[[query]]\ntau = 1\nfeatures = [\"landscape:0\"]\n",
        ] {
            let e = RunConfig::from_str(bad).unwrap_err();
            assert!(matches!(e, DoryError::Config(_)), "{bad}: {e}");
        }
    }

    #[test]
    fn array_values_parse() {
        assert_eq!(parse_value("[]"), Some(Value::Arr(vec![])));
        assert_eq!(
            parse_value("[\"a, b\", 2, true]"),
            Some(Value::Arr(vec![
                Value::Str("a, b".into()),
                Value::Num(2.0),
                Value::Bool(true),
            ]))
        );
        assert_eq!(parse_value("[[1]]"), None); // no nesting
        assert_eq!(parse_value("[1,"), None);
    }

    #[test]
    fn hic_dataset_spec() {
        let cfg = RunConfig::from_str(
            "[dataset]\nkind = \"hic\"\nn = 10000\ncondition = \"auxin\"\n[engine]\ntau = 400\n",
        )
        .unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Hic {
                n_bins: 10000,
                condition: "auxin".into(),
                seed: 1
            }
        );
    }
}
