//! L3 coordinator: config-driven pipeline orchestration.
//!
//! dataset → edge filtration (PJRT Pallas kernel when an artifact fits,
//! native Rust otherwise) → Dory engine (H0/H1*/H2*) → reports (PD CSV /
//! JSON, summary JSON, optional persistence image through the second
//! Pallas kernel). Python never runs here — artifacts were AOT-compiled
//! at build time.

pub mod config;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use config::{DatasetSpec, RunConfig};

use crate::datasets;
use crate::filtration::{EdgeFiltration, FiltrationStats};
use crate::geometry::MetricData;
use crate::hic;
use crate::homology::{self, Algorithm, Engine, EngineOptions};
use crate::io;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::memtrack;
use crate::util::timer::PhaseTimer;

/// Everything a run produces.
pub struct RunReport {
    pub result: homology::PhResult,
    pub edge_source: &'static str,
    pub n_points: usize,
    pub n_edges: usize,
    pub peak_heap_bytes: usize,
    pub pimage: Option<(usize, Vec<f32>)>,
}

/// Materialize the configured dataset.
pub fn build_dataset(spec: &DatasetSpec) -> Result<MetricData> {
    Ok(match spec {
        DatasetSpec::Named { kind, n, seed } => match kind.as_str() {
            "circle" => datasets::circle(*n, 1.0, 0.05, *seed),
            "figure-eight" => datasets::figure_eight(*n, 1.0, 0.02, *seed),
            "sphere" => datasets::sphere(*n, 1.0, 0.0, *seed),
            "torus3" => datasets::torus3(*n, 2.0, 0.7, *seed),
            "torus4" => datasets::torus4(*n, *seed),
            "o3" => datasets::o3(*n, *seed),
            "dragon" => datasets::dragon_like(*n, *seed),
            "fractal" => datasets::fractal_network(5),
            "random" => datasets::random_cloud(*n, 3, *seed),
            "multi-scale" => datasets::multi_scale_demo(*n, *seed),
            other => bail!("unknown dataset kind: {other}"),
        },
        DatasetSpec::Hic {
            n_bins,
            condition,
            seed,
        } => {
            let cond = match condition.as_str() {
                "control" => hic::Condition::Control,
                "auxin" => hic::Condition::Auxin,
                other => bail!("hic condition must be control|auxin, got {other}"),
            };
            let params = hic::HiCParams {
                n_bins: *n_bins,
                seed: *seed,
                ..Default::default()
            };
            MetricData::Sparse(hic::generate(&params, cond))
        }
        DatasetSpec::PointsFile(p) => io::read_points(p)?,
        DatasetSpec::LowerDistanceFile(p) => io::read_lower_distance(p)?,
        DatasetSpec::SparseFile(p) => io::read_sparse_coo(p)?,
    })
}

/// Build the edge filtration, preferring the PJRT distance kernel.
/// Returns the filtration and which path produced it. Serial compat
/// wrapper (no pool, no enclosing truncation) over
/// [`build_filtration_pooled`], which is the engine-pool path the
/// coordinator itself runs — one PJRT dispatch to keep in sync, not
/// two.
pub fn build_filtration(
    data: &MetricData,
    tau: f64,
    runtime: Option<&Runtime>,
) -> (EdgeFiltration, &'static str) {
    let engine = Engine::new(EngineOptions {
        threads: 1,
        enclosing: false,
        ..Default::default()
    });
    build_filtration_pooled(data, tau, runtime, &engine, &mut FiltrationStats::default())
}

/// Build the edge filtration on the engine's worker pool. The PJRT
/// Pallas kernel, when an artifact fits, enumerates the thresholded
/// pair list and the pool key-sorts it; otherwise the native tiled
/// front-end (distance kernel + sort + enclosing truncation per the
/// engine's `f1_tile`/`enclosing` knobs) runs entirely as pool work.
pub fn build_filtration_pooled(
    data: &MetricData,
    tau: f64,
    runtime: Option<&Runtime>,
    engine: &Engine,
    fstats: &mut FiltrationStats,
) -> (EdgeFiltration, &'static str) {
    if let (MetricData::Points(pc), Some(rt)) = (data, runtime) {
        if rt.has_distance_kernel() {
            match rt.distance_edges(pc, tau) {
                Ok(mut raw) => {
                    let n = pc.n();
                    let mut tau_eff = tau;
                    // Enclosing-radius truncation applies to the kernel
                    // path too: at τ = +∞ the returned pair list is
                    // complete (guarded by the exact count, which makes
                    // the radius derivable from the list alone), so the
                    // same cut happens before the key sort — the
                    // accelerated path must not ship a larger edge set
                    // downstream than the native one.
                    if engine.frontend_options().enclosing
                        && tau == f64::INFINITY
                        && n >= 2
                        && raw.len() == n * (n - 1) / 2
                    {
                        let r = crate::filtration::enclosing_radius_of_edges(n, &raw);
                        if r.is_finite() {
                            let before = raw.len() as u64;
                            raw.retain(|&(d, _, _)| d <= r);
                            fstats.enclosing_radius = r;
                            fstats.edges_pruned += before - raw.len() as u64;
                            fstats.edges_considered += before - raw.len() as u64;
                            tau_eff = r;
                        }
                    }
                    return (
                        EdgeFiltration::from_weighted_edges_pooled(
                            pc.n() as u32,
                            raw,
                            tau_eff,
                            engine.pool(),
                            fstats,
                        ),
                        "pjrt-pallas",
                    )
                }
                Err(e) => {
                    eprintln!("[dory] PJRT distance path unavailable ({e}); using native");
                }
            }
        }
    }
    (
        EdgeFiltration::build_pooled(data, tau, engine.pool(), &engine.frontend_options(), fstats),
        "native",
    )
}

/// Execute a full configured run.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    let data = build_dataset(&cfg.dataset)?;
    let runtime = if cfg.use_pjrt {
        match Runtime::load(&cfg.artifacts) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("[dory] PJRT runtime unavailable ({e}); native fallback");
                None
            }
        }
    } else {
        None
    };

    let opts = EngineOptions {
        max_dim: cfg.max_dim,
        threads: cfg.threads,
        batch_size: cfg.batch_size,
        adaptive_batch: cfg.adaptive_batch,
        batch_min: cfg.batch_min,
        batch_max: cfg.batch_max,
        steal_grain: cfg.steal_grain,
        adapt_low: cfg.adapt_low,
        adapt_high: cfg.adapt_high,
        enum_shards: cfg.enum_shards,
        enum_grain: cfg.enum_grain,
        shortcut: cfg.shortcut,
        f1_tile: cfg.f1_tile,
        enclosing: cfg.enclosing,
        dense_lookup: cfg.dense_lookup,
        algorithm: match cfg.algorithm.as_str() {
            "implicit-row" => Algorithm::ImplicitRow,
            _ => Algorithm::FastColumn,
        },
    };
    // The engine (and its persistent pool) exists before the filtration
    // is built, so the whole front-end runs as pool work.
    let engine = Engine::new(opts);
    memtrack::reset_peak();
    let mut timings = PhaseTimer::new();
    let mut fstats = FiltrationStats::default();
    timings.start("F1");
    let (f, edge_source) =
        build_filtration_pooled(&data, cfg.tau, runtime.as_ref(), &engine, &mut fstats);
    timings.stop();
    let mut result = engine.compute_with_stats(&f, timings, fstats);
    result.stats.n = data.n();
    let peak = memtrack::section_peak_bytes();

    // Optional persistence image through the second Pallas kernel.
    let pimage = if cfg.pimage {
        match &runtime {
            Some(rt) if rt.has_pimage_kernel() => {
                let dim = cfg.max_dim.min(1);
                let pairs: Vec<(f32, f32, f32)> = result
                    .diagram
                    .finite(dim)
                    .iter()
                    .map(|p| (p.birth as f32, (p.death - p.birth) as f32, 1.0f32))
                    .collect();
                match rt.persistence_image(&pairs, cfg.pimage_span as f32) {
                    Ok(img) => Some(img),
                    Err(e) => {
                        eprintln!("[dory] persistence image failed: {e}");
                        None
                    }
                }
            }
            _ => None,
        }
    } else {
        None
    };

    if let Some(p) = &cfg.diagram_csv {
        ensure_parent(p)?;
        io::write_diagram_csv(p, &result.diagram)?;
    }
    if let Some(p) = &cfg.diagram_json {
        ensure_parent(p)?;
        io::write_diagram_json(p, &result.diagram)?;
    }
    let report = RunReport {
        n_points: data.n(),
        n_edges: f.n_edges(),
        edge_source,
        peak_heap_bytes: peak,
        pimage,
        result,
    };
    if let Some(p) = &cfg.summary_json {
        ensure_parent(p)?;
        std::fs::write(p, summary_json(cfg, &report).render())?;
    }
    Ok(report)
}

fn ensure_parent(p: &Path) -> Result<()> {
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
    }
    Ok(())
}

/// The machine-readable run summary (consumed by benches and EXPERIMENTS).
pub fn summary_json(cfg: &RunConfig, r: &RunReport) -> Json {
    let d = &r.result.diagram;
    let mut betti = Json::arr();
    for dim in 0..=cfg.max_dim {
        betti.push(
            Json::obj()
                .field("dim", dim)
                .field("finite", d.finite(dim).len())
                .field("essential", d.essential_count(dim)),
        );
    }
    let mut phases = Json::obj();
    let mut phase_rss = Json::obj();
    for p in r.result.timings.phases() {
        phases = phases.field(&p.name, p.duration.as_secs_f64());
        phase_rss = phase_rss.field(&p.name, p.max_rss_end);
    }
    Json::obj()
        .field("n_points", r.n_points)
        .field("n_edges", r.n_edges)
        .field("tau", cfg.tau)
        .field("max_dim", cfg.max_dim)
        .field("threads", cfg.threads)
        .field("algorithm", cfg.algorithm.as_str())
        .field("dense_lookup", cfg.dense_lookup)
        .field("edge_source", r.edge_source)
        .field("peak_heap_bytes", r.peak_heap_bytes)
        .field("max_rss_bytes", memtrack::max_rss_bytes())
        .field("base_memory_model_bytes", r.result.stats.base_memory_bytes)
        .field("betti", betti)
        .field("phase_seconds", phases)
        .field("phase_max_rss_bytes", phase_rss)
        .field("h1", reduction_json(&r.result.stats.h1))
        .field("h2", reduction_json(&r.result.stats.h2))
        .field(
            "filtration",
            r.result
                .stats
                .filtration
                .to_json()
                .field("f1_tile", cfg.f1_tile)
                .field("enclosing", cfg.enclosing)
                .field("front_memory_bytes", r.result.stats.front_memory_bytes),
        )
        .field(
            "scheduler",
            Json::obj()
                .field("adaptive_batch", cfg.adaptive_batch)
                .field("adapt_low", cfg.adapt_low)
                .field("adapt_high", cfg.adapt_high)
                .field("enum_shards", cfg.enum_shards)
                .field("enum_grain", cfg.enum_grain)
                .field("shortcut", cfg.shortcut)
                .field("h1", r.result.stats.h1_sched.to_json())
                .field("h2", r.result.stats.h2_sched.to_json()),
        )
}

/// Per-dimension reduction counters, including the apparent-pair
/// shortcut's skip accounting (columns = streamed into the reduction;
/// shortcut = resolved in-shard; skip_rate = shortcut / (columns +
/// shortcut), the fraction of clearing survivors that never entered a
/// `BucketTable`).
fn reduction_json(s: &crate::reduction::ReduceStats) -> Json {
    Json::obj()
        .field("pairs", s.pairs)
        .field("trivial", s.trivial_pairs)
        .field("essential", s.essential)
        .field("columns", s.columns)
        .field("shortcut", s.shortcut_pairs)
        .field("skip_rate", s.skip_rate())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_run_with_outputs() {
        let dir = std::env::temp_dir().join("dory-coord-test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 80,
                seed: 3,
            },
            tau: 3.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            diagram_csv: Some(dir.join("pd.csv")),
            summary_json: Some(dir.join("summary.json")),
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.edge_source, "native");
        assert_eq!(r.result.diagram.essential_count(0), 1);
        assert!(dir.join("pd.csv").is_file());
        let s = std::fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(s.contains("\"n_points\":80"), "{s}");
        assert!(s.contains("\"filtration\""), "{s}");
        assert!(s.contains("\"edges_pruned\""), "{s}");
        // threads = 2: the front-end must have run as pool work.
        assert!(r.result.stats.filtration.tiles > 0, "front-end ran serially");
    }

    #[test]
    fn infinite_tau_run_prunes_at_enclosing_radius() {
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 60,
                seed: 11,
            },
            tau: f64::INFINITY,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            ..Default::default()
        };
        let on = run(&cfg).unwrap();
        let fs = &on.result.stats.filtration;
        assert!(fs.enclosing_radius.is_finite());
        assert!(fs.edges_pruned > 0, "noisy circle must prune past r_enc");
        assert_eq!(fs.edges_considered, fs.edges_kept + fs.edges_pruned);
        assert!(on.n_edges < 60 * 59 / 2);
        // Exact fallback: full filtration, identical diagram.
        let off = run(&RunConfig {
            enclosing: false,
            ..cfg
        })
        .unwrap();
        assert_eq!(off.n_edges, 60 * 59 / 2);
        assert_eq!(off.result.stats.filtration.edges_pruned, 0);
        assert!(on
            .result
            .diagram
            .multiset_eq(&off.result.diagram, 0.0));
    }

    #[test]
    fn all_named_datasets_build() {
        for kind in [
            "circle",
            "figure-eight",
            "sphere",
            "torus3",
            "torus4",
            "o3",
            "dragon",
            "random",
            "multi-scale",
        ] {
            let spec = DatasetSpec::Named {
                kind: kind.into(),
                n: 64,
                seed: 1,
            };
            let d = build_dataset(&spec).unwrap();
            assert!(d.n() >= 64, "{kind}");
        }
        assert!(build_dataset(&DatasetSpec::Named {
            kind: "nope".into(),
            n: 10,
            seed: 1
        })
        .is_err());
    }

    #[test]
    fn hic_run_counts_loops() {
        let cfg = RunConfig {
            dataset: DatasetSpec::Hic {
                n_bins: 2000,
                condition: "control".into(),
                seed: 7,
            },
            tau: 400.0,
            max_dim: 1,
            threads: 1,
            use_pjrt: false,
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert!(r.result.diagram.significant(1, 50.0).len() > 3);
    }
}
