//! L3 coordinator: config-driven pipeline orchestration over the
//! session layer.
//!
//! dataset → **one ingest** (PJRT Pallas kernel when an artifact fits,
//! native pooled front-end otherwise) → a [`Session`] answering every
//! configured query (`[[query]]` array / repeated `--tau`) from the
//! shared [`FiltrationHandle`] → reports (per-query PD CSV/JSON, one
//! summary JSON with a `queries` array, optional persistence image
//! through the second Pallas kernel). Python never runs here —
//! artifacts were AOT-compiled at build time.
//!
//! Every fallible step returns a typed [`DoryError`]; the CLI maps that
//! to a nonzero exit code instead of a panic backtrace.

pub mod config;

use std::path::{Path, PathBuf};

pub use config::{DatasetSpec, QuerySpec, RunConfig};

use crate::datasets;
use crate::error::DoryError;
use crate::filtration::{sparsify, EdgeFiltration, FiltrationStats, FrontendOptions, SimdMode};
use crate::geometry::MetricData;
use crate::hic;
use crate::homology::{
    self, Algorithm, EngineOptions, PhRequest, PhResponse, Session, SessionStats,
};
use crate::io;
use crate::reduction::pool::ThreadPool;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::memtrack;
use crate::util::timer::PhaseTimer;

type Result<T> = std::result::Result<T, DoryError>;

/// Everything a single-query run produces (legacy shape; see
/// [`BatchReport`] for the multi-query service run).
pub struct RunReport {
    pub result: homology::PhResult,
    pub edge_source: &'static str,
    pub n_points: usize,
    pub n_edges: usize,
    pub peak_heap_bytes: usize,
    pub pimage: Option<(usize, Vec<f32>)>,
}

/// Everything a batch run produces: the shared-ingest facts plus one
/// [`PhResponse`] per configured query.
pub struct BatchReport {
    pub edge_source: &'static str,
    pub n_points: usize,
    /// Edges of the shared ingest (each query serves a prefix of them).
    pub ingest_edges: usize,
    pub peak_heap_bytes: usize,
    /// Front-end report of the one build every query amortizes
    /// (`f1_builds`/`nb_builds` stay 1 regardless of query count).
    pub ingest_stats: FiltrationStats,
    pub session: SessionStats,
    pub responses: Vec<PhResponse>,
    /// Persistence image of the first query's diagram (PJRT kernel),
    /// when requested and available.
    pub pimage: Option<(usize, Vec<f32>)>,
}

/// Materialize the configured dataset.
pub fn build_dataset(spec: &DatasetSpec) -> Result<MetricData> {
    Ok(match spec {
        DatasetSpec::Named { kind, n, seed } => match kind.as_str() {
            "circle" => datasets::circle(*n, 1.0, 0.05, *seed),
            "figure-eight" => datasets::figure_eight(*n, 1.0, 0.02, *seed),
            "sphere" => datasets::sphere(*n, 1.0, 0.0, *seed),
            "torus3" => datasets::torus3(*n, 2.0, 0.7, *seed),
            "torus4" => datasets::torus4(*n, *seed),
            "o3" => datasets::o3(*n, *seed),
            "dragon" => datasets::dragon_like(*n, *seed),
            "fractal" => datasets::fractal_network(5),
            "random" => datasets::random_cloud(*n, 3, *seed),
            "multi-scale" => datasets::multi_scale_demo(*n, *seed),
            other => return Err(DoryError::Dataset(format!("unknown dataset kind: {other}"))),
        },
        DatasetSpec::Hic {
            n_bins,
            condition,
            seed,
        } => {
            let cond = match condition.as_str() {
                "control" => hic::Condition::Control,
                "auxin" => hic::Condition::Auxin,
                other => {
                    return Err(DoryError::Dataset(format!(
                        "hic condition must be control|auxin, got {other}"
                    )))
                }
            };
            let params = hic::HiCParams {
                n_bins: *n_bins,
                seed: *seed,
                ..Default::default()
            };
            MetricData::Sparse(hic::generate(&params, cond))
        }
        DatasetSpec::PointsFile(p) => io::read_points(p)?,
        DatasetSpec::LowerDistanceFile(p) => io::read_lower_distance(p)?,
        DatasetSpec::SparseFile(p) => io::read_sparse_coo(p)?,
    })
}

/// Build the edge filtration, preferring the PJRT distance kernel. The
/// **single** entry for both the serial and the pooled path (the old
/// drifted serial copy is gone): pass the engine's pool (or `None`) and
/// the front-end knobs. The PJRT Pallas kernel, when an artifact fits,
/// enumerates the thresholded pair list and the pool key-sorts it;
/// otherwise the native tiled front-end (distance kernel + sort +
/// enclosing truncation per `fe`) runs entirely as pool work.
pub fn build_filtration(
    data: &MetricData,
    tau: f64,
    runtime: Option<&Runtime>,
    pool: Option<&ThreadPool>,
    fe: &FrontendOptions,
    fstats: &mut FiltrationStats,
) -> (EdgeFiltration, &'static str) {
    if let (MetricData::Points(pc), Some(rt)) = (data, runtime) {
        if rt.has_distance_kernel() {
            match rt.distance_edges(pc, tau) {
                Ok(mut raw) => {
                    let n = pc.n();
                    let mut tau_eff = tau;
                    // Enclosing-radius truncation applies to the kernel
                    // path too: at τ = +∞ the returned pair list is
                    // complete (guarded by the exact count, which makes
                    // the radius derivable from the list alone), so the
                    // same cut happens before the key sort — the
                    // accelerated path must not ship a larger edge set
                    // downstream than the native one.
                    if fe.enclosing
                        && tau == f64::INFINITY
                        && n >= 2
                        && raw.len() == n * (n - 1) / 2
                    {
                        let r = crate::filtration::enclosing_radius_of_edges(n, &raw);
                        if r.is_finite() {
                            let before = raw.len() as u64;
                            raw.retain(|&(d, _, _)| d <= r);
                            fstats.enclosing_radius = r;
                            fstats.edges_pruned += before - raw.len() as u64;
                            fstats.edges_considered += before - raw.len() as u64;
                            tau_eff = r;
                        }
                    }
                    return (
                        EdgeFiltration::from_weighted_edges_pooled(
                            pc.n() as u32,
                            raw,
                            tau_eff,
                            pool,
                            fstats,
                        ),
                        "pjrt-pallas",
                    );
                }
                Err(e) => {
                    eprintln!("[dory] PJRT distance path unavailable ({e}); using native");
                }
            }
        }
    }
    (
        EdgeFiltration::build_pooled(data, tau, pool, fe, fstats),
        "native",
    )
}

/// Execute a full configured run — a thin **deprecated shim** over
/// [`run_batch`] kept for single-query callers and the existing test
/// fixtures: the first (usually only) configured query's response is
/// adapted into the legacy [`RunReport`] shape.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    let mut batch = run_batch(cfg)?;
    let first = batch.responses.remove(0);
    Ok(RunReport {
        n_points: batch.n_points,
        n_edges: first.n_edges,
        edge_source: batch.edge_source,
        peak_heap_bytes: batch.peak_heap_bytes,
        pimage: batch.pimage,
        result: first.result,
    })
}

/// Execute every configured query (`[[query]]` array, or the single
/// `[engine] tau`) over **one** dataset ingest on a [`Session`]. Output
/// files: per-query diagrams (suffixed `.qN` before the extension when
/// more than one query runs) and one summary JSON with a `queries`
/// array plus the session amortization counters.
pub fn run_batch(cfg: &RunConfig) -> Result<BatchReport> {
    // Streaming gate: a sparse edge file with either streaming knob set
    // never goes through `build_dataset` — the raw entry list would be
    // exactly the allocation the budget exists to avoid.
    let streaming = matches!(&cfg.dataset, DatasetSpec::SparseFile(_))
        && (cfg.stream_chunk > 0 || cfg.edge_budget_mb > 0);
    let data = if streaming {
        None
    } else {
        Some(build_dataset(&cfg.dataset)?)
    };
    let runtime = if cfg.use_pjrt {
        match Runtime::load(&cfg.artifacts) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("[dory] PJRT runtime unavailable ({e}); native fallback");
                None
            }
        }
    } else {
        None
    };

    let opts = EngineOptions {
        max_dim: cfg.max_dim,
        threads: cfg.threads,
        batch_size: cfg.batch_size,
        adaptive_batch: cfg.adaptive_batch,
        batch_min: cfg.batch_min,
        batch_max: cfg.batch_max,
        steal_grain: cfg.steal_grain,
        adapt_low: cfg.adapt_low,
        adapt_high: cfg.adapt_high,
        enum_shards: cfg.enum_shards,
        enum_grain: cfg.enum_grain,
        shortcut: cfg.shortcut,
        f1_tile: cfg.f1_tile,
        enclosing: cfg.enclosing,
        simd: SimdMode::parse(&cfg.simd).ok_or_else(|| {
            DoryError::Config(format!(
                "simd must be auto, scalar, avx2 or neon, got {}",
                cfg.simd
            ))
        })?,
        dense_lookup: cfg.dense_lookup,
        algorithm: match cfg.algorithm.as_str() {
            "implicit-row" => Algorithm::ImplicitRow,
            _ => Algorithm::FastColumn,
        },
    };
    // The session (and its persistent pool) exists before the
    // filtration is built, so the whole front-end runs as pool work —
    // once, no matter how many queries follow.
    let session = Session::new(opts);
    memtrack::reset_peak();
    let handle = if streaming {
        let DatasetSpec::SparseFile(p) = &cfg.dataset else {
            unreachable!("streaming gate requires a sparse file dataset");
        };
        let budget_bytes = cfg.edge_budget_mb.checked_mul(1 << 20).ok_or_else(|| {
            DoryError::Config(format!(
                "edge_budget_mb {} overflows the byte budget",
                cfg.edge_budget_mb
            ))
        })?;
        let sopts = io::stream::StreamOptions {
            chunk_lines: cfg.stream_chunk,
            budget_bytes,
            spill_dir: None,
            strict: cfg.strict_spill,
        };
        session.ingest_sparse_file(p, cfg.ingest_tau(), &sopts)?.0
    } else if cfg.edge_budget_mb > 0
        && cfg.knn_k == 0
        && matches!(
            data.as_ref(),
            Some(MetricData::Points(_)) | Some(MetricData::Dense(_))
        )
    {
        // Dense streaming: a point cloud (or distance table) under an
        // edge budget routes its row-band tiles through the spill
        // store instead of materializing the full key array. Output is
        // bit-identical to the in-memory build; only the transient
        // staging profile changes.
        let data = data.as_ref().expect("gate matched on Some");
        let budget_bytes = cfg.edge_budget_mb.checked_mul(1 << 20).ok_or_else(|| {
            DoryError::Config(format!(
                "edge_budget_mb {} overflows the byte budget",
                cfg.edge_budget_mb
            ))
        })?;
        let sopts = io::stream::StreamOptions {
            chunk_lines: cfg.stream_chunk,
            budget_bytes,
            spill_dir: None,
            strict: cfg.strict_spill,
        };
        session.ingest_streamed(data, cfg.ingest_tau(), &sopts)?.0
    } else if let (true, Some(MetricData::Points(pc))) = (cfg.knn_k > 0, data.as_ref()) {
        // Net-graph sparse front-end: build edges from a greedy-net
        // cover instead of materializing all n(n-1)/2 pairs. Cover
        // granularity (~4√n cells) is a perf knob only — the kernel is
        // exact for any cover when uncapped; `knn_k` then caps each
        // vertex to its k nearest incident entries (2ε-stable).
        let mut timings = PhaseTimer::new();
        let mut fstats = FiltrationStats::default();
        timings.start("F1");
        let k_net = (((pc.n() as f64).sqrt().ceil() as usize) * 4).clamp(1, pc.n());
        let cover = sparsify::NetCover::build(pc, k_net, 0.0, 1);
        let tau_ing = cfg.ingest_tau();
        let tau_eff = if tau_ing == f64::INFINITY && cfg.enclosing && pc.n() >= 2 {
            // Net-based upper bound on r_enc: the cone argument holds
            // at any cut ≥ r_enc, so truncating here preserves every
            // diagram while the bound scan stays O(|net|·n).
            sparsify::net_enclosing_bound(pc, &cover)
        } else {
            tau_ing
        };
        let sd = sparsify::net_graph_edges(pc, &cover, tau_eff, cfg.knn_k, session.engine().pool());
        let sdata = MetricData::Sparse(sd);
        let f = EdgeFiltration::build_pooled(
            &sdata,
            tau_eff,
            session.engine().pool(),
            &session.engine().frontend_options(),
            &mut fstats,
        );
        // Sparse builds never run the enclosing sweep themselves, so
        // record the net bound after the build (which resets the field)
        // — queries past the cut then clamp-and-report as truncated.
        if tau_eff.is_finite() && tau_ing == f64::INFINITY {
            fstats.enclosing_radius = tau_eff;
        }
        timings.stop();
        session.ingest_filtration(f, timings, fstats, "knn-net")?
    } else {
        let data = data.as_ref().expect("non-streaming path materializes the dataset");
        let mut timings = PhaseTimer::new();
        let mut fstats = FiltrationStats::default();
        timings.start("F1");
        let (f, edge_source) = build_filtration(
            data,
            cfg.ingest_tau(),
            runtime.as_ref(),
            session.engine().pool(),
            &session.engine().frontend_options(),
            &mut fstats,
        );
        timings.stop();
        session.ingest_filtration(f, timings, fstats, edge_source)?
    };
    let edge_source = handle.edge_source;

    let specs = cfg.effective_queries();
    let multi = specs.len() > 1;
    let mut responses = Vec::with_capacity(specs.len());
    for (i, q) in specs.iter().enumerate() {
        let req = PhRequest {
            tau: q.tau,
            max_dim: q.max_dim,
            shortcut: q.shortcut,
            enclosing: q.enclosing,
            label: q.label.clone(),
            timeout_ms: q.timeout_ms.or(cfg.timeout_ms),
            // Per-query list wins; empty inherits the `[engine]` list.
            features: if q.features.is_empty() {
                cfg.features.clone()
            } else {
                q.features.clone()
            },
        };
        let resp = session.query(&handle, &req)?;
        if let Some(p) = &cfg.diagram_csv {
            let p = query_path(p, i, multi);
            ensure_parent(&p)?;
            io::write_diagram_csv(&p, &resp.result.diagram)?;
        }
        if let Some(p) = &cfg.diagram_json {
            let p = query_path(p, i, multi);
            ensure_parent(&p)?;
            io::write_diagram_json(&p, &resp.result.diagram)?;
        }
        responses.push(resp);
    }
    let peak = memtrack::section_peak_bytes();

    // Optional persistence image (first query) through the second
    // Pallas kernel.
    let pimage = if cfg.pimage {
        match &runtime {
            Some(rt) if rt.has_pimage_kernel() => {
                let q0 = &specs[0];
                let dim = q0.max_dim.unwrap_or(cfg.max_dim).min(1);
                let pairs: Vec<(f32, f32, f32)> = responses[0]
                    .result
                    .diagram
                    .finite(dim)
                    .iter()
                    .map(|p| (p.birth as f32, (p.death - p.birth) as f32, 1.0f32))
                    .collect();
                match rt.persistence_image(&pairs, cfg.pimage_span as f32) {
                    Ok(img) => Some(img),
                    Err(e) => {
                        eprintln!("[dory] persistence image failed: {e}");
                        None
                    }
                }
            }
            _ => None,
        }
    } else {
        None
    };

    let report = BatchReport {
        edge_source,
        n_points: handle.n_points(),
        ingest_edges: handle.n_edges(),
        peak_heap_bytes: peak,
        ingest_stats: *handle.stats(),
        session: session.stats(),
        responses,
        pimage,
    };
    if let Some(p) = &cfg.summary_json {
        ensure_parent(p)?;
        std::fs::write(p, batch_summary_json(cfg, &report).render())
            .map_err(|e| DoryError::io(p, e))?;
    }
    Ok(report)
}

/// `pd.csv` → `pd.q3.csv` when a batch writes one file per query.
fn query_path(p: &Path, i: usize, multi: bool) -> PathBuf {
    if !multi {
        return p.to_path_buf();
    }
    match p.extension().and_then(|e| e.to_str()) {
        Some(ext) => p.with_extension(format!("q{i}.{ext}")),
        None => PathBuf::from(format!("{}.q{i}", p.display())),
    }
}

fn ensure_parent(p: &Path) -> Result<()> {
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| DoryError::io(dir, e))?;
        }
    }
    Ok(())
}

/// The machine-readable run summary (consumed by benches and
/// EXPERIMENTS): shared-ingest facts at the top level (plus the first
/// query's legacy fields, so single-query consumers keep working), a
/// `queries` array with one entry per response, and the session
/// amortization counters.
pub fn batch_summary_json(cfg: &RunConfig, r: &BatchReport) -> Json {
    let first = &r.responses[0];
    let mut queries = Json::arr();
    for (i, resp) in r.responses.iter().enumerate() {
        queries.push(query_json(i, resp));
    }
    // Aggregate feature accounting across every query that asked for
    // derived products (absent when no query did).
    let mut fstats = crate::features::FeatureStats::default();
    let mut any_features = false;
    for resp in &r.responses {
        if let Some(fo) = &resp.features {
            fstats.merge(&fo.stats);
            any_features = true;
        }
    }
    let (phases, phase_rss) = phases_json(&first.result.timings);
    let mut out = Json::obj()
        .field("n_points", r.n_points)
        .field("n_edges", first.n_edges)
        .field("ingest_edges", r.ingest_edges)
        .field("tau", first.tau)
        .field("max_dim", cfg.max_dim)
        .field("threads", cfg.threads)
        .field("algorithm", cfg.algorithm.as_str())
        .field("dense_lookup", cfg.dense_lookup)
        .field("edge_source", r.edge_source)
        .field("peak_heap_bytes", r.peak_heap_bytes)
        .field("max_rss_bytes", memtrack::max_rss_bytes())
        .field(
            "base_memory_model_bytes",
            first.result.stats.base_memory_bytes,
        )
        .field(
            "betti",
            betti_json(&first.result.diagram, first.result.diagram.max_dim()),
        )
        .field("phase_seconds", phases)
        .field("phase_max_rss_bytes", phase_rss)
        .field("h1", reduction_json(&first.result.stats.h1))
        .field("h2", reduction_json(&first.result.stats.h2))
        .field(
            "filtration",
            r.ingest_stats
                .to_json()
                .field("f1_tile", cfg.f1_tile)
                .field("enclosing", cfg.enclosing)
                .field("simd", cfg.simd.as_str())
                .field(
                    "front_memory_bytes",
                    first.result.stats.front_memory_bytes,
                ),
        )
        .field(
            "scheduler",
            Json::obj()
                .field("adaptive_batch", cfg.adaptive_batch)
                .field("adapt_low", cfg.adapt_low)
                .field("adapt_high", cfg.adapt_high)
                .field("enum_shards", cfg.enum_shards)
                .field("enum_grain", cfg.enum_grain)
                .field("shortcut", cfg.shortcut)
                .field("h1", first.result.stats.h1_sched.to_json())
                .field("h2", first.result.stats.h2_sched.to_json()),
        )
        .field("session", r.session.to_json())
        .field("queries", queries);
    if any_features {
        out = out.field("feature_stats", fstats.to_json());
    }
    out
}

/// One `queries[]` entry: the per-query JSON report.
fn query_json(i: usize, resp: &PhResponse) -> Json {
    let mut q = Json::obj()
        .field("index", i)
        .field("tau", resp.tau)
        .field("tau_effective", resp.tau_effective)
        .field("n_edges", resp.n_edges)
        .field("truncated", resp.truncated)
        .field("max_dim", resp.result.diagram.max_dim())
        .field(
            "betti",
            betti_json(&resp.result.diagram, resp.result.diagram.max_dim()),
        )
        .field("phase_seconds", phases_json(&resp.result.timings).0)
        .field("h1", reduction_json(&resp.result.stats.h1))
        .field("h2", reduction_json(&resp.result.stats.h2));
    if let Some(fo) = &resp.features {
        q = q
            .field("features", fo.to_json())
            .field("feature_stats", fo.stats.to_json());
    }
    if let Some(label) = &resp.label {
        q = q.field("label", label.as_str());
    }
    q
}

fn betti_json(d: &homology::Diagram, max_dim: usize) -> Json {
    let mut betti = Json::arr();
    for dim in 0..=max_dim {
        betti.push(
            Json::obj()
                .field("dim", dim)
                .field("finite", d.finite(dim).len())
                .field("essential", d.essential_count(dim)),
        );
    }
    betti
}

fn phases_json(t: &PhaseTimer) -> (Json, Json) {
    let mut phases = Json::obj();
    let mut phase_rss = Json::obj();
    for p in t.phases() {
        phases = phases.field(&p.name, p.duration.as_secs_f64());
        phase_rss = phase_rss.field(&p.name, p.max_rss_end);
    }
    (phases, phase_rss)
}

/// Per-dimension reduction counters, including the apparent-pair
/// shortcut's skip accounting (columns = streamed into the reduction;
/// shortcut = resolved in-shard; skip_rate = shortcut / (columns +
/// shortcut), the fraction of clearing survivors that never entered a
/// `BucketTable`).
fn reduction_json(s: &crate::reduction::ReduceStats) -> Json {
    Json::obj()
        .field("pairs", s.pairs)
        .field("trivial", s.trivial_pairs)
        .field("essential", s.essential)
        .field("columns", s.columns)
        .field("shortcut", s.shortcut_pairs)
        .field("skip_rate", s.skip_rate())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_run_with_outputs() {
        let dir = std::env::temp_dir().join("dory-coord-test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 80,
                seed: 3,
            },
            tau: 3.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            diagram_csv: Some(dir.join("pd.csv")),
            summary_json: Some(dir.join("summary.json")),
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.edge_source, "native");
        assert_eq!(r.result.diagram.essential_count(0), 1);
        assert!(dir.join("pd.csv").is_file());
        let s = std::fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(s.contains("\"n_points\":80"), "{s}");
        assert!(s.contains("\"filtration\""), "{s}");
        assert!(s.contains("\"edges_pruned\""), "{s}");
        assert!(s.contains("\"queries\""), "{s}");
        assert!(s.contains("\"session\""), "{s}");
        // threads = 2: the front-end must have run as pool work.
        assert!(r.result.stats.filtration.tiles > 0, "front-end ran serially");
        // The ingest-once counters: one build for the run.
        assert_eq!(r.result.stats.filtration.f1_builds, 1);
        assert_eq!(r.result.stats.filtration.nb_builds, 1);
    }

    #[test]
    fn infinite_tau_run_prunes_at_enclosing_radius() {
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 60,
                seed: 11,
            },
            tau: f64::INFINITY,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            ..Default::default()
        };
        let on = run(&cfg).unwrap();
        let fs = &on.result.stats.filtration;
        assert!(fs.enclosing_radius.is_finite());
        assert!(fs.edges_pruned > 0, "noisy circle must prune past r_enc");
        assert_eq!(fs.edges_considered, fs.edges_kept + fs.edges_pruned);
        assert!(on.n_edges < 60 * 59 / 2);
        // Exact fallback: full filtration, identical diagram.
        let off = run(&RunConfig {
            enclosing: false,
            ..cfg
        })
        .unwrap();
        assert_eq!(off.n_edges, 60 * 59 / 2);
        assert_eq!(off.result.stats.filtration.edges_pruned, 0);
        assert!(on
            .result
            .diagram
            .multiset_eq(&off.result.diagram, 0.0));
    }

    #[test]
    fn batch_run_serves_queries_from_one_ingest() {
        let dir = std::env::temp_dir().join("dory-coord-batch-test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 70,
                seed: 5,
            },
            tau: 3.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            diagram_csv: Some(dir.join("pd.csv")),
            summary_json: Some(dir.join("summary.json")),
            queries: vec![
                QuerySpec::at(1.0),
                QuerySpec {
                    label: Some("full".into()),
                    ..QuerySpec::at(3.0)
                },
            ],
            ..Default::default()
        };
        let b = run_batch(&cfg).unwrap();
        assert_eq!(b.responses.len(), 2);
        assert_eq!(b.session.ingests, 1);
        assert_eq!(b.session.filtration_builds, 1);
        assert_eq!(b.session.nb_builds, 1);
        assert_eq!(b.session.queries, 2);
        assert!(b.responses[0].truncated);
        assert!(!b.responses[1].truncated);
        // Per-query diagram files, one summary.
        assert!(dir.join("pd.q0.csv").is_file());
        assert!(dir.join("pd.q1.csv").is_file());
        let s = std::fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(s.contains("\"queries\""), "{s}");
        assert!(s.contains("\"label\":\"full\""), "{s}");
        // Each query matches an independent single run at its τ.
        for (i, tau) in [(0usize, 1.0f64), (1, 3.0)] {
            let single = run(&RunConfig {
                tau,
                queries: Vec::new(),
                diagram_csv: None,
                summary_json: None,
                ..cfg.clone()
            })
            .unwrap();
            assert!(
                b.responses[i]
                    .result
                    .diagram
                    .multiset_eq(&single.result.diagram, 0.0),
                "query {i} deviates from the independent run at tau={tau}"
            );
        }
    }

    #[test]
    fn all_named_datasets_build() {
        for kind in [
            "circle",
            "figure-eight",
            "sphere",
            "torus3",
            "torus4",
            "o3",
            "dragon",
            "random",
            "multi-scale",
        ] {
            let spec = DatasetSpec::Named {
                kind: kind.into(),
                n: 64,
                seed: 1,
            };
            let d = build_dataset(&spec).unwrap();
            assert!(d.n() >= 64, "{kind}");
        }
        let e = build_dataset(&DatasetSpec::Named {
            kind: "nope".into(),
            n: 10,
            seed: 1,
        })
        .unwrap_err();
        assert!(matches!(e, DoryError::Dataset(_)), "{e}");
    }

    #[test]
    fn streaming_sparse_file_run_matches_in_memory() {
        // Failpoints are process-global: hold the test lock so an
        // armed sibling test cannot inject into this one.
        let _fp = crate::util::failpoint::test_lock();
        let dir = std::env::temp_dir().join("dory-coord-stream-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cycle.coo");
        let mut text = String::new();
        for i in 0u32..8 {
            text.push_str(&format!("{} {} 1.0\n", i, (i + 1) % 8));
        }
        std::fs::write(&path, text).unwrap();
        let base = RunConfig {
            dataset: DatasetSpec::SparseFile(path),
            tau: 2.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            ..Default::default()
        };
        let inmem = run(&base).unwrap();
        assert_eq!(inmem.edge_source, "native");
        let streamed = run(&RunConfig {
            stream_chunk: 3,
            ..base.clone()
        })
        .unwrap();
        assert_eq!(streamed.edge_source, "stream");
        assert_eq!(streamed.n_edges, inmem.n_edges);
        assert!(streamed
            .result
            .diagram
            .multiset_eq(&inmem.result.diagram, 0.0));
        // The budget knob alone also routes through the stream reader.
        let budgeted = run(&RunConfig {
            edge_budget_mb: 1,
            ..base
        })
        .unwrap();
        assert_eq!(budgeted.edge_source, "stream");
        assert!(budgeted
            .result
            .diagram
            .multiset_eq(&inmem.result.diagram, 0.0));
    }

    #[test]
    fn dense_budgeted_run_streams_and_matches_in_memory() {
        let base = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 72,
                seed: 9,
            },
            tau: f64::INFINITY,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            ..Default::default()
        };
        let inmem = run(&base).unwrap();
        assert_eq!(inmem.edge_source, "native");
        let streamed = run(&RunConfig {
            edge_budget_mb: 1,
            ..base.clone()
        })
        .unwrap();
        assert_eq!(streamed.edge_source, "dense-stream");
        assert_eq!(streamed.n_edges, inmem.n_edges);
        assert!(streamed
            .result
            .diagram
            .multiset_eq(&inmem.result.diagram, 0.0));
        let fs = &streamed.result.stats.filtration;
        assert!(!fs.dist_kernel.is_empty(), "kernel must be recorded");
        assert_eq!(
            fs.enclosing_radius.to_bits(),
            inmem.result.stats.filtration.enclosing_radius.to_bits()
        );
        // knn_k wins over the dense budget route (a capped net graph is
        // sparse; the spill store has nothing dense to stream).
        let knn = run(&RunConfig {
            edge_budget_mb: 1,
            knn_k: 8,
            tau: 3.0,
            ..base
        })
        .unwrap();
        assert_eq!(knn.edge_source, "knn-net");
    }

    #[test]
    fn knn_net_run_keeps_topology_with_fewer_edges() {
        let base = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 90,
                seed: 4,
            },
            tau: 3.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            ..Default::default()
        };
        let dense = run(&base).unwrap();
        let knn = run(&RunConfig {
            knn_k: 8,
            ..base.clone()
        })
        .unwrap();
        assert_eq!(knn.edge_source, "knn-net");
        assert!(
            knn.n_edges < dense.n_edges,
            "cap must drop edges: {} vs {}",
            knn.n_edges,
            dense.n_edges
        );
        assert_eq!(knn.result.diagram.essential_count(0), 1);
        // The dominant circle class survives the k-NN cap.
        assert!(!knn.result.diagram.significant(1, 0.5).is_empty());
        // At τ = +∞ the net bound stands in for the enclosing radius.
        let inf = run(&RunConfig {
            tau: f64::INFINITY,
            knn_k: 8,
            ..base
        })
        .unwrap();
        assert_eq!(inf.edge_source, "knn-net");
        assert!(inf.result.stats.filtration.enclosing_radius.is_finite());
        assert_eq!(inf.result.diagram.essential_count(0), 1);
    }

    #[test]
    fn batch_run_serves_feature_products() {
        use crate::features::{FeatureSpec, FeatureValue};
        let dir = std::env::temp_dir().join("dory-coord-features-test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = RunConfig {
            dataset: DatasetSpec::Named {
                kind: "circle".into(),
                n: 60,
                seed: 2,
            },
            tau: 3.0,
            max_dim: 1,
            threads: 2,
            use_pjrt: false,
            summary_json: Some(dir.join("summary.json")),
            features: vec![FeatureSpec::Entropy],
            queries: vec![
                QuerySpec::at(3.0), // inherits [engine] features
                QuerySpec {
                    features: vec![
                        FeatureSpec::BettiCurve { grid: 8 },
                        FeatureSpec::Representatives { min_persistence: 0.0 },
                    ],
                    ..QuerySpec::at(3.0)
                },
            ],
            ..Default::default()
        };
        let b = run_batch(&cfg).unwrap();
        let f0 = b.responses[0].features.as_ref().expect("inherited features");
        assert_eq!(f0.items.len(), 1);
        assert!(matches!(f0.items[0].value, FeatureValue::Entropy(_)));
        let f1 = b.responses[1].features.as_ref().expect("per-query features");
        assert_eq!(f1.items.len(), 2);
        assert!(f1.stats.cycles >= 1, "circle must yield a representative");
        // One shared ingest regardless of the feature work.
        assert_eq!(b.session.filtration_builds, 1);
        assert_eq!(b.session.feature_queries, 2);
        let s = std::fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(s.contains("\"feature_stats\""), "{s}");
        assert!(s.contains("\"features\""), "{s}");
        assert!(s.contains("\"entropy\""), "{s}");
    }

    #[test]
    fn hic_run_counts_loops() {
        let cfg = RunConfig {
            dataset: DatasetSpec::Hic {
                n_bins: 2000,
                condition: "control".into(),
                seed: 7,
            },
            tau: 400.0,
            max_dim: 1,
            threads: 1,
            use_pjrt: false,
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert!(r.result.diagram.significant(1, 50.0).len() > 3);
    }
}
