//! Shared harness for the paper-table benches (`rust/benches/*.rs`).
//!
//! criterion is not in the offline vendor set, so each bench is a
//! `harness = false` binary built on these helpers: scaled dataset menus,
//! timed+memory-tracked engine runs, table printing, and JSON/CSV dumps
//! under `target/bench_out/`.

use std::path::PathBuf;

use crate::datasets::{self, Dataset};
use crate::filtration::EdgeFiltration;
use crate::geometry::MetricData;
use crate::homology::{compute_ph_from_filtration, EngineOptions, PhResult};
use crate::util::json::Json;
use crate::util::memtrack;

/// Bench scale, from `--full` / `--quick` argv (cargo bench also passes
/// `--bench`, which we ignore along with anything unknown).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

pub fn parse_scale() -> Scale {
    let mut s = Scale::Quick;
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--full" => s = Scale::Full,
            "--quick" => s = Scale::Quick,
            _ => {}
        }
    }
    s
}

/// The Table 1 benchmark suite at bench scale. Quick sizes keep
/// `cargo bench` minutes-scale while preserving the comparisons' shape;
/// `--full` approaches the paper's Table 1 parameters.
pub fn suite(scale: Scale) -> Vec<Dataset> {
    match scale {
        Scale::Quick => vec![
            Dataset {
                name: "dragon".into(),
                data: datasets::dragon_like(600, 1),
                tau: f64::INFINITY,
                max_dim: 1,
            },
            Dataset {
                name: "fractal".into(),
                data: datasets::fractal_network(4), // 123 nodes, dense
                tau: f64::INFINITY,
                max_dim: 2,
            },
            Dataset {
                name: "o3".into(),
                data: datasets::o3(1024, 2),
                tau: 1.0,
                max_dim: 2,
            },
            Dataset {
                name: "torus4(1)".into(),
                data: datasets::torus4(4000, 3),
                tau: 0.3,
                max_dim: 1,
            },
            Dataset {
                name: "torus4(2)".into(),
                data: datasets::torus4(2000, 3),
                tau: 0.4,
                max_dim: 2,
            },
        ],
        Scale::Full => vec![
            Dataset {
                name: "dragon".into(),
                data: datasets::dragon_like(2000, 1),
                tau: f64::INFINITY,
                max_dim: 1,
            },
            Dataset {
                name: "fractal".into(),
                data: datasets::fractal_network(5), // 366 nodes
                tau: f64::INFINITY,
                max_dim: 2,
            },
            Dataset {
                name: "o3".into(),
                data: datasets::o3(8192, 2),
                tau: 1.0,
                max_dim: 2,
            },
            Dataset {
                name: "torus4(1)".into(),
                data: datasets::torus4(50_000, 3),
                tau: 0.15,
                max_dim: 1,
            },
            Dataset {
                name: "torus4(2)".into(),
                data: datasets::torus4(50_000, 3),
                tau: 0.15,
                max_dim: 2,
            },
        ],
    }
}

/// Hi-C bins per scale (paper: 3.09 M).
pub fn hic_bins(scale: Scale) -> usize {
    match scale {
        Scale::Quick => 10_000,
        Scale::Full => 60_000,
    }
}

/// One measured engine run: wall time, section-peak heap, result.
pub struct Measured {
    pub seconds: f64,
    pub peak_bytes: usize,
    pub result: PhResult,
}

pub fn run_engine(data: &MetricData, tau: f64, opts: &EngineOptions) -> Measured {
    memtrack::reset_peak();
    let t0 = std::time::Instant::now();
    // compute_ph times "F1" as its first phase (the Table 2 column).
    let r = crate::homology::compute_ph(data, tau, opts);
    Measured {
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: memtrack::section_peak_bytes(),
        result: r,
    }
}

/// Variant for callers that already built the filtration.
pub fn run_engine_on(f: &EdgeFiltration, opts: &EngineOptions) -> Measured {
    memtrack::reset_peak();
    let t0 = std::time::Instant::now();
    let r = compute_ph_from_filtration(f, opts);
    Measured {
        seconds: t0.elapsed().as_secs_f64(),
        peak_bytes: memtrack::section_peak_bytes(),
        result: r,
    }
}

/// `(time, peak)` cell in the paper's "(2.8 s, 262 MB)" style.
pub fn cell(seconds: f64, bytes: usize) -> String {
    format!("({:.2} s, {})", seconds, memtrack::fmt_bytes(bytes))
}

/// Output directory for machine-readable bench results.
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("target/bench_out");
    std::fs::create_dir_all(&d).expect("create bench_out");
    d
}

pub fn write_json(name: &str, j: &Json) {
    let p = out_dir().join(name);
    std::fs::write(&p, j.render()).expect("write bench json");
    println!("[wrote {p:?}]");
}

/// Simple ASCII horizontal bar (for the Fig 18 rendering).
pub fn bar(value: f64, max: f64, width: usize) -> String {
    let w = if max > 0.0 {
        ((value / max) * width as f64).round() as usize
    } else {
        0
    };
    "#".repeat(w.min(width))
}
