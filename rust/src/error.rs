//! Typed service errors.
//!
//! Every fallible entry point of the service surface —
//! [`crate::homology::Session`] ingestion and queries, the `io` readers,
//! the [`crate::coordinator`] — returns a [`DoryError`] instead of
//! panicking, so a server embedding the crate can branch on the failure
//! class (reject the request, re-ingest, surface a config diagnostic)
//! rather than parse panic messages. The legacy one-shot wrappers
//! (`compute_ph`, `Neighborhoods::build`) keep their panic contract by
//! unwrapping these same errors, so nothing is reported twice.

use std::fmt;

/// The failure classes of the Dory service surface.
#[derive(Clone, Debug, PartialEq)]
pub enum DoryError {
    /// The metric input itself is unusable (NaN coordinates/distances,
    /// malformed sparse entries, ragged point files).
    InvalidInput(String),
    /// A [`crate::homology::PhRequest`] that no handle state could
    /// serve (bad `max_dim`, NaN `tau`, an override that contradicts
    /// how the handle was ingested).
    Request(String),
    /// A query asked for a larger filtration than the handle ingested;
    /// re-ingest at the larger threshold to serve it. `ingested` is the
    /// handle's effective threshold (the enclosing radius when the
    /// ingest truncation fired).
    TauExceedsIngest { requested: f64, ingested: f64 },
    /// A size guard refused an allocation whose index arithmetic or
    /// byte count would overflow (the DoryNS dense edge-order table).
    Overflow(String),
    /// Run-configuration errors: TOML syntax, unknown keys/sections,
    /// out-of-range knob values.
    Config(String),
    /// Filesystem I/O failures, tagged with the offending path.
    Io(String),
    /// Dataset construction failures (unknown kind, bad Hi-C condition).
    Dataset(String),
    /// A server-side fault (worker panic, poisoned invariant) that is
    /// not the client's doing. The request may be retried; the payload
    /// carries the panic message for operator logs.
    Internal(String),
    /// The server refused admission: the global in-flight bound or the
    /// tenant's quota is exhausted. Retry after backoff.
    Overloaded(String),
    /// A request's `timeout_ms` deadline expired before the reduction
    /// finished. The handle stays valid; re-issue with a larger budget.
    DeadlineExceeded(String),
    /// A derived feature product could not be computed from the served
    /// state (e.g. a representative-cycle edge missing from the
    /// truncated filtration view). The diagram itself is unaffected;
    /// re-issue without the offending feature spec to get it.
    Feature(String),
}

impl fmt::Display for DoryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DoryError::InvalidInput(m) => write!(f, "invalid input: {m}"),
            DoryError::Request(m) => write!(f, "bad request: {m}"),
            DoryError::TauExceedsIngest {
                requested,
                ingested,
            } => write!(
                f,
                "tau {requested} exceeds the ingested filtration threshold {ingested}; \
                 re-ingest the dataset at tau >= {requested} to serve this query"
            ),
            DoryError::Overflow(m) => write!(f, "{m}"),
            DoryError::Config(m) => write!(f, "config error: {m}"),
            DoryError::Io(m) => write!(f, "io error: {m}"),
            DoryError::Dataset(m) => write!(f, "dataset error: {m}"),
            DoryError::Internal(m) => write!(f, "internal error: {m}"),
            DoryError::Overloaded(m) => write!(f, "overloaded: {m}"),
            DoryError::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            DoryError::Feature(m) => write!(f, "feature error: {m}"),
        }
    }
}

/// `std::error::Error` so `?` lifts a [`DoryError`] into `anyhow::Error`
/// at the CLI boundary (the vendored shim's blanket `From` applies).
impl std::error::Error for DoryError {}

impl From<std::io::Error> for DoryError {
    fn from(e: std::io::Error) -> Self {
        DoryError::Io(e.to_string())
    }
}

impl DoryError {
    /// Tag an I/O failure with the path it concerned.
    pub fn io(path: &std::path::Path, e: impl fmt::Display) -> Self {
        DoryError::Io(format!("{path:?}: {e}"))
    }

    /// Stable machine-readable failure class, used as the `kind` field
    /// of wire errors (`dory serve`) so clients branch without parsing
    /// messages.
    pub fn kind(&self) -> &'static str {
        match self {
            DoryError::InvalidInput(_) => "InvalidInput",
            DoryError::Request(_) => "Request",
            DoryError::TauExceedsIngest { .. } => "TauExceedsIngest",
            DoryError::Overflow(_) => "Overflow",
            DoryError::Config(_) => "Config",
            DoryError::Io(_) => "Io",
            DoryError::Dataset(_) => "Dataset",
            DoryError::Internal(_) => "Internal",
            DoryError::Overloaded(_) => "Overloaded",
            DoryError::DeadlineExceeded(_) => "DeadlineExceeded",
            DoryError::Feature(_) => "Feature",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_actionable() {
        let e = DoryError::TauExceedsIngest {
            requested: 0.9,
            ingested: 0.5,
        };
        let s = e.to_string();
        assert!(s.contains("0.9") && s.contains("0.5") && s.contains("re-ingest"), "{s}");
        assert!(DoryError::Config("x".into()).to_string().contains("config"));
        assert!(DoryError::io(std::path::Path::new("/nope"), "gone")
            .to_string()
            .contains("/nope"));
    }

    #[test]
    fn converts_into_anyhow_for_the_cli() {
        fn f() -> anyhow::Result<()> {
            let typed: Result<(), DoryError> =
                Err(DoryError::Dataset("unknown kind".into()));
            typed?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("unknown kind"));
    }

    #[test]
    fn io_error_converts() {
        let e: DoryError = std::fs::read_to_string("/definitely/not/here")
            .map_err(DoryError::from)
            .unwrap_err();
        assert!(matches!(e, DoryError::Io(_)));
    }
}
