//! Persistence diagrams, Betti curves and diagram comparison.

/// One off-diagonal point; `death = +∞` for essential classes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub birth: f64,
    pub death: f64,
}

impl Point {
    pub fn persistence(&self) -> f64 {
        self.death - self.birth
    }

    pub fn is_essential(&self) -> bool {
        self.death.is_infinite()
    }
}

/// Persistence diagram holding one multiset of points per dimension.
#[derive(Clone, Debug)]
pub struct Diagram {
    dims: Vec<Vec<Point>>,
}

impl Diagram {
    pub fn new(max_dim: usize) -> Self {
        Self {
            dims: vec![Vec::new(); max_dim + 1],
        }
    }

    pub fn max_dim(&self) -> usize {
        self.dims.len() - 1
    }

    /// Record a (birth, death) point; zero-persistence points are dropped
    /// (they are diagonal points, invisible to any PD metric).
    pub fn push(&mut self, dim: usize, birth: f64, death: f64) {
        if birth != death {
            self.dims[dim].push(Point { birth, death });
        }
    }

    pub fn points(&self, dim: usize) -> &[Point] {
        self.dims.get(dim).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Finite points of `dim`, sorted by (birth, death).
    pub fn finite(&self, dim: usize) -> Vec<Point> {
        let mut v: Vec<Point> = self
            .points(dim)
            .iter()
            .copied()
            .filter(|p| !p.is_essential())
            .collect();
        v.sort_by(|a, b| {
            a.birth
                .partial_cmp(&b.birth)
                .unwrap()
                .then(a.death.partial_cmp(&b.death).unwrap())
        });
        v
    }

    pub fn essential_count(&self, dim: usize) -> usize {
        self.points(dim).iter().filter(|p| p.is_essential()).count()
    }

    /// Betti number at scale `tau`: classes born at or before `tau` that
    /// die strictly after it.
    pub fn betti_at(&self, dim: usize, tau: f64) -> usize {
        self.points(dim)
            .iter()
            .filter(|p| p.birth <= tau && p.death > tau)
            .count()
    }

    /// Betti curve over `ts` (Fig. 21's loop/void counts per threshold).
    pub fn betti_curve(&self, dim: usize, ts: &[f64]) -> Vec<usize> {
        ts.iter().map(|&t| self.betti_at(dim, t)).collect()
    }

    /// Points with persistence above `min_persistence`.
    pub fn significant(&self, dim: usize, min_persistence: f64) -> Vec<Point> {
        self.points(dim)
            .iter()
            .copied()
            .filter(|p| p.persistence() > min_persistence)
            .collect()
    }

    /// Exact multiset equality (within `tol` per coordinate) per
    /// dimension, including essential classes — the cross-engine test.
    pub fn multiset_eq(&self, other: &Diagram, tol: f64) -> bool {
        let md = self.max_dim().max(other.max_dim());
        for d in 0..=md {
            let (mut a, mut b) = (self.finite(d), other.finite(d));
            if a.len() != b.len() {
                return false;
            }
            let cmp = |x: &Point, y: &Point| {
                x.birth
                    .partial_cmp(&y.birth)
                    .unwrap()
                    .then(x.death.partial_cmp(&y.death).unwrap())
            };
            a.sort_by(cmp);
            b.sort_by(cmp);
            for (p, q) in a.iter().zip(&b) {
                if (p.birth - q.birth).abs() > tol || (p.death - q.death).abs() > tol {
                    return false;
                }
            }
            // Essentials compare by birth multiset.
            let mut ea: Vec<f64> = self
                .points(d)
                .iter()
                .filter(|p| p.is_essential())
                .map(|p| p.birth)
                .collect();
            let mut eb: Vec<f64> = other
                .points(d)
                .iter()
                .filter(|p| p.is_essential())
                .map(|p| p.birth)
                .collect();
            if ea.len() != eb.len() {
                return false;
            }
            ea.sort_by(|x, y| x.partial_cmp(y).unwrap());
            eb.sort_by(|x, y| x.partial_cmp(y).unwrap());
            if ea.iter().zip(&eb).any(|(x, y)| (x - y).abs() > tol) {
                return false;
            }
        }
        true
    }

    /// Describe the mismatch (for test failure messages).
    pub fn diff_summary(&self, other: &Diagram) -> String {
        let md = self.max_dim().max(other.max_dim());
        let mut s = String::new();
        for d in 0..=md {
            s.push_str(&format!(
                "dim{d}: finite {} vs {}, essential {} vs {}\n",
                self.finite(d).len(),
                other.finite(d).len(),
                self.essential_count(d),
                other.essential_count(d),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_persistence_dropped() {
        let mut d = Diagram::new(1);
        d.push(1, 0.5, 0.5);
        d.push(1, 0.5, 0.7);
        assert_eq!(d.points(1).len(), 1);
    }

    #[test]
    fn betti_at_counts_alive() {
        let mut d = Diagram::new(1);
        d.push(1, 0.2, 0.8);
        d.push(1, 0.4, f64::INFINITY);
        assert_eq!(d.betti_at(1, 0.1), 0);
        assert_eq!(d.betti_at(1, 0.3), 1);
        assert_eq!(d.betti_at(1, 0.5), 2);
        assert_eq!(d.betti_at(1, 0.9), 1);
    }

    #[test]
    fn multiset_eq_detects_mismatch() {
        let mut a = Diagram::new(1);
        a.push(1, 0.1, 0.9);
        let mut b = Diagram::new(1);
        b.push(1, 0.1, 0.9);
        assert!(a.multiset_eq(&b, 1e-12));
        b.push(1, 0.2, 0.3);
        assert!(!a.multiset_eq(&b, 1e-12));
        let mut c = Diagram::new(1);
        c.push(1, 0.1, f64::INFINITY);
        assert!(!a.multiset_eq(&c, 1e-12));
    }

    #[test]
    fn order_independent_equality() {
        let mut a = Diagram::new(0);
        a.push(0, 0.0, 1.0);
        a.push(0, 0.0, 2.0);
        let mut b = Diagram::new(0);
        b.push(0, 0.0, 2.0);
        b.push(0, 0.0, 1.0);
        assert!(a.multiset_eq(&b, 1e-12));
    }
}
