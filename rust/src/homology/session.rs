//! Session-oriented service API: ingest a dataset once, answer a
//! stream of PH queries from the shared build.
//!
//! The one-shot entry points (`compute_ph`, `coordinator::run`) rebuild
//! the edge filtration, the `Neighborhoods` CSR and — without a held
//! [`Engine`] — the worker pool on every call, even though those builds
//! are the shared, amortizable cost across queries on the same dataset.
//! A [`Session`] holds the persistent engine (and its pool) and splits
//! the pipeline at the natural seam:
//!
//! * [`Session::ingest`] runs the front-end once — pooled distance
//!   tiles, key sort, optional enclosing-radius truncation, pooled CSR
//!   fill, DoryNS table — into a [`FiltrationHandle`];
//! * [`Session::query`] / [`Session::run_batch`] answer typed
//!   [`PhRequest`]s against a handle. A sub-τ request is served by
//!   **prefix-truncating the shared sorted edge set**
//!   ([`EdgeFiltration::prefix`]) and viewing the shared CSR through an
//!   order cap ([`Neighborhoods::truncated`]) — no distance is
//!   recomputed, nothing is re-sorted, no CSR array is rebuilt — yet
//!   the reduction consumes byte-for-byte the stream a fresh build at
//!   that τ would produce, so diagrams are **bit-identical** to
//!   independent one-shot runs (pinned by `rust/tests/session.rs`).
//!
//! **Concurrency.** Every post-ingest structure is immutable, so
//! [`Session::query`] and [`Session::run_batch`] take `&self`: N
//! threads may serve queries against one handle (or several) at once,
//! all sharing the engine's work-stealing pool through its
//! multi-generation scheduler (`reduction::pool`). Per-query state —
//! reduction scratch, bucket tables, phase timers, stat accumulators —
//! lives on the calling thread's stack, and the session counters are
//! atomics, so a concurrent schedule produces byte-for-byte the same
//! diagrams as running the queries back to back (pinned by
//! `rust/tests/concurrent.rs`).
//!
//! Every fallible entry returns a typed [`DoryError`] instead of
//! panicking: NaN inputs are [`DoryError::InvalidInput`], a NaN or
//! negative query τ is [`DoryError::Request`], the DoryNS size guard is
//! [`DoryError::Overflow`], a request beyond the ingested threshold is
//! [`DoryError::TauExceedsIngest`].

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::DoryError;
use crate::filtration::{
    enclosing_radius_of_filtration, EdgeFiltration, FiltrationStats, Neighborhoods,
};
use crate::geometry::MetricData;
use crate::io::stream::{StreamOptions, StreamStats};
use crate::util::timer::PhaseTimer;

use super::engine::{Engine, EngineOptions, PhResult};

/// One dataset, ingested once: the sorted edge set, its neighborhoods
/// (and DoryNS table when the session runs dense lookup), and the
/// front-end report of the single build that produced them. Handles are
/// independent values — one session can serve several datasets — and
/// `Sync`, so any number of query threads may share one.
pub struct FiltrationHandle {
    f: EdgeFiltration,
    nb: Neighborhoods,
    /// Front-end report of the ingest build; its `f1_builds`/`nb_builds`
    /// counters stay at 1 no matter how many queries the handle serves.
    fstats: FiltrationStats,
    /// `F1` (+ sub-phases) and `neighborhoods` phase records of the
    /// ingest; cloned into every response as the shared-build prefix.
    timings: PhaseTimer,
    n_points: usize,
    /// The τ the ingest was asked for (`tau_max` of `f` may be the
    /// enclosing radius instead when the truncation fired).
    tau_requested: f64,
    /// The ingest applied the enclosing-radius truncation.
    enclosing_applied: bool,
    /// The edge set is the complete pair list (τ = +∞, truncation off,
    /// non-sparse input): any τ — and a query-time enclosing cut — can
    /// be served from it.
    complete: bool,
    /// Which path produced the edge list ("native", "pjrt-pallas", …).
    pub edge_source: &'static str,
}

impl FiltrationHandle {
    pub fn n_points(&self) -> usize {
        self.n_points
    }

    pub fn n_edges(&self) -> usize {
        self.f.n_edges()
    }

    /// The largest τ a query can ask for without re-ingesting: +∞ for a
    /// complete or enclosing-truncated handle (the truncation preserves
    /// every diagram), the ingest τ otherwise. A query past an
    /// enclosing-truncated handle's own `r_enc` is *served* — with
    /// unchanged diagrams — but the response reports the clamp through
    /// [`PhResponse::tau_effective`] / [`PhResponse::truncated`].
    pub fn tau_capacity(&self) -> f64 {
        if self.complete || self.enclosing_applied {
            f64::INFINITY
        } else {
            self.f.tau_max
        }
    }

    /// The ingest applied the enclosing-radius truncation (the handle's
    /// edge set ends at `r_enc` even though [`Self::tau_capacity`] is
    /// +∞).
    pub fn enclosing_applied(&self) -> bool {
        self.enclosing_applied
    }

    /// The ingest's front-end report (build counters, stage times,
    /// pruning).
    pub fn stats(&self) -> &FiltrationStats {
        &self.fstats
    }

    /// The shared sorted edge set.
    pub fn filtration(&self) -> &EdgeFiltration {
        &self.f
    }

    /// The shared `Neighborhoods` CSR view (the full, uncapped ingest
    /// build; sub-τ queries view it through an order cap internally).
    /// Feature consumers use it to measure representative cycles
    /// against the same edge set the diagrams came from.
    pub fn neighborhoods(&self) -> &Neighborhoods {
        &self.nb
    }

    /// Heap footprint of the shared structures (edge set + CSR/DoryNS),
    /// the unit the serve layer's byte-budget cache evicts on.
    pub fn memory_bytes(&self) -> usize {
        self.f.memory_bytes() + self.nb.memory_bytes()
    }

    /// The τ the ingest was asked for (the effective build threshold is
    /// `filtration().tau_max`, which is the enclosing radius when the
    /// ingest truncation fired).
    pub fn tau_requested(&self) -> f64 {
        self.tau_requested
    }
}

/// One typed PH query against a [`FiltrationHandle`]. `None` overrides
/// inherit the session's [`EngineOptions`].
#[derive(Clone, Debug, Default)]
pub struct PhRequest {
    /// Filtration threshold; must be servable from the handle
    /// ([`FiltrationHandle::tau_capacity`]). NaN and negative values
    /// are refused with [`DoryError::Request`].
    pub tau: f64,
    /// Highest homology dimension (0..=2); `None` = session default.
    pub max_dim: Option<usize>,
    /// Apparent-pair shortcut override; `None` = session default.
    pub shortcut: Option<bool>,
    /// Query-time enclosing-radius truncation. Only consulted when
    /// `tau` is `+∞`: `Some(true)` on a complete handle derives
    /// `r_enc` from the shared edge set and serves the truncated
    /// prefix; `Some(false)` on a handle whose *ingest* already
    /// truncated is refused (the pruned edges were never ingested).
    /// `None` serves the handle as ingested.
    pub enclosing: Option<bool>,
    /// Caller tag echoed into the response and the batch summary.
    pub label: Option<String>,
    /// Cooperative deadline for the reduction, in milliseconds from the
    /// moment the query starts. Polled between homology dimensions and
    /// at batch-commit boundaries; on expiry the query returns
    /// [`DoryError::DeadlineExceeded`] and the handle stays fully
    /// serviceable (all aborted state was request-local). `None` = no
    /// deadline.
    pub timeout_ms: Option<u64>,
    /// Derived feature products to compute post-reduction from the
    /// served diagram and filtration view (Betti curves, entropy,
    /// landscapes, persistence images, representative loops). Empty =
    /// none. Feature computation never rebuilds anything: the ingest's
    /// `f1_builds`/`nb_builds` counters are unchanged by feature
    /// requests, and every product is bit-identical across thread
    /// counts, schedules, and cached-handle vs fresh-ingest serving.
    pub features: Vec<crate::features::FeatureSpec>,
}

impl PhRequest {
    /// A plain query at `tau` with every knob inherited.
    pub fn at(tau: f64) -> Self {
        Self {
            tau,
            ..Default::default()
        }
    }
}

/// A served query: the full [`PhResult`] (diagram + engine stats +
/// timings, where the timing prefix is the shared ingest's) plus the
/// request echo and how the handle served it.
pub struct PhResponse {
    pub label: Option<String>,
    /// The requested τ.
    pub tau: f64,
    /// The τ the filtration was actually cut at: the enclosing radius
    /// when the request was clamped to an enclosing-truncated handle
    /// (or asked for a query-time truncation), else the requested τ.
    pub tau_effective: f64,
    /// Edges of the served (possibly prefix-truncated) filtration.
    pub n_edges: usize,
    /// The served edge set is smaller than the requested τ nominally
    /// implies: either a proper prefix of the handle (a sub-τ query),
    /// or the handle's enclosing-truncated set standing in for a
    /// requested τ beyond `r_enc` (diagrams unchanged — see
    /// `tau_effective` for the actual cut).
    pub truncated: bool,
    pub result: PhResult,
    /// Derived feature products, present iff the request carried
    /// feature specs ([`PhRequest::features`]).
    pub features: Option<crate::features::FeatureOutputs>,
}

/// Lifetime counters of a session — the service-level proof that N
/// queries cost one build. A snapshot: the live counters are atomics
/// inside the session (queries increment them through `&self`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub ingests: u64,
    pub queries: u64,
    /// Queries served from a smaller edge set than the requested τ
    /// nominally implies (proper prefix, or enclosing clamp).
    pub truncated_queries: u64,
    /// Queries served from a handle's full edge set at its own τ.
    pub full_queries: u64,
    /// F1 builds performed by this session (== `ingests`: queries never
    /// build).
    pub filtration_builds: u64,
    /// `Neighborhoods` CSR builds performed by this session
    /// (== `ingests`).
    pub nb_builds: u64,
    /// Queries that carried feature specs (feature computation never
    /// moves the build counters above).
    pub feature_queries: u64,
}

impl SessionStats {
    /// Machine-readable form for the run summary JSON.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .field("ingests", self.ingests)
            .field("queries", self.queries)
            .field("truncated_queries", self.truncated_queries)
            .field("full_queries", self.full_queries)
            .field("filtration_builds", self.filtration_builds)
            .field("nb_builds", self.nb_builds)
            .field("feature_queries", self.feature_queries)
    }
}

/// Live session counters, bumped through `&self` by concurrent queries.
#[derive(Default)]
struct SessionCounters {
    ingests: AtomicU64,
    queries: AtomicU64,
    truncated_queries: AtomicU64,
    full_queries: AtomicU64,
    filtration_builds: AtomicU64,
    nb_builds: AtomicU64,
    feature_queries: AtomicU64,
}

impl SessionCounters {
    fn snapshot(&self) -> SessionStats {
        SessionStats {
            ingests: self.ingests.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            truncated_queries: self.truncated_queries.load(Ordering::Relaxed),
            full_queries: self.full_queries.load(Ordering::Relaxed),
            filtration_builds: self.filtration_builds.load(Ordering::Relaxed),
            nb_builds: self.nb_builds.load(Ordering::Relaxed),
            feature_queries: self.feature_queries.load(Ordering::Relaxed),
        }
    }
}

/// How [`Session::resolve_cut`] decided to serve a request.
struct Cut {
    /// Edges of the handle's sorted set that serve the request.
    m: usize,
    /// The τ that cut corresponds to.
    tau_effective: f64,
    /// The requested τ exceeded the handle's enclosing-truncated edge
    /// set and was clamped to `r_enc` (served set unchanged, diagrams
    /// unchanged; reported through the response).
    clamped: bool,
}

/// A persistent PH service endpoint: the [`Engine`] (with its worker
/// pool) plus session counters. Create once, ingest datasets into
/// [`FiltrationHandle`]s, answer [`PhRequest`]s — from as many threads
/// as you like: all entry points take `&self`.
pub struct Session {
    engine: Engine,
    counters: SessionCounters,
}

impl Session {
    /// A session running `opts`; `threads > 1` spawns the persistent
    /// pool that every ingest and query reuses.
    pub fn new(opts: EngineOptions) -> Self {
        Self {
            engine: Engine::new(opts),
            counters: SessionCounters::default(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn options(&self) -> &EngineOptions {
        self.engine.options()
    }

    /// Snapshot of the lifetime counters (consistent-enough under
    /// concurrency: each counter is exact; cross-counter sums may lag a
    /// query that is mid-flight).
    pub fn stats(&self) -> SessionStats {
        self.counters.snapshot()
    }

    /// Ingest a metric dataset at threshold `tau`: validate, build the
    /// edge filtration and its neighborhoods once (pooled, with the
    /// session's `f1_tile`/`enclosing`/`dense_lookup` knobs), and
    /// return the reusable handle. NaN inputs are rejected with
    /// [`DoryError::InvalidInput`]; the DoryNS size guard returns
    /// [`DoryError::Overflow`].
    pub fn ingest(&self, data: &MetricData, tau: f64) -> Result<FiltrationHandle, DoryError> {
        if tau.is_nan() {
            return Err(DoryError::Request("ingest tau is NaN".into()));
        }
        data.validate().map_err(DoryError::InvalidInput)?;
        let mut fstats = FiltrationStats::default();
        let mut timings = PhaseTimer::new();
        timings.start("F1");
        let f = EdgeFiltration::build_pooled(
            data,
            tau,
            self.engine.pool(),
            &self.engine.frontend_options(),
            &mut fstats,
        );
        timings.stop();
        let sparse = matches!(data, MetricData::Sparse(_));
        self.finish_ingest(data.n(), f, timings, fstats, "native", tau, sparse)
    }

    /// Stream-ingest a sparse `i j d` COO file at threshold `tau`,
    /// staging at most `opts.budget_bytes` (+ one line chunk) of
    /// transient memory: chunked parse, per-chunk `u128` key packing,
    /// budgeted spill to disk, k-way merge straight into the filtration
    /// arrays. Validation and the resulting diagrams are identical to
    /// `ingest(&io::read_sparse_coo(path)?, tau)` — bit-for-bit at
    /// tol 0 — only the transient memory profile differs. The returned
    /// [`StreamStats`] report spill activity and the staging peak for
    /// budget assertions.
    pub fn ingest_sparse_file(
        &self,
        path: &std::path::Path,
        tau: f64,
        opts: &StreamOptions,
    ) -> Result<(FiltrationHandle, StreamStats), DoryError> {
        if tau.is_nan() {
            return Err(DoryError::Request("ingest tau is NaN".into()));
        }
        let mut fstats = FiltrationStats::default();
        let mut timings = PhaseTimer::new();
        timings.start("F1");
        let (f, sstats) =
            crate::io::stream::stream_sparse_file(path, tau, opts, self.engine.pool(), &mut fstats)?;
        timings.stop();
        let n = f.n as usize;
        let h = self.finish_ingest(n, f, timings, fstats, "stream", tau, true)?;
        Ok((h, sstats))
    }

    /// Stream-ingest an in-memory dense dataset (point cloud or
    /// distance table) at threshold `tau`, staging at most
    /// `opts.budget_bytes` (+ one tile wave) of transient key memory:
    /// row-band tiles bit-pack `u128` keys as they are produced,
    /// pool-sorted runs spill to disk past the budget, and the k-way
    /// merge reproduces the exact in-memory edge order (keys are
    /// strictly unique), so the handle — and every diagram served from
    /// it — is bit-identical to `ingest(data, tau)` for every tile
    /// size and budget. Sparse inputs are refused
    /// ([`DoryError::InvalidInput`]); they have their own streaming
    /// entry ([`Session::ingest_sparse_file`]).
    pub fn ingest_streamed(
        &self,
        data: &MetricData,
        tau: f64,
        opts: &StreamOptions,
    ) -> Result<(FiltrationHandle, StreamStats), DoryError> {
        if tau.is_nan() {
            return Err(DoryError::Request("ingest tau is NaN".into()));
        }
        data.validate().map_err(DoryError::InvalidInput)?;
        let mut fstats = FiltrationStats::default();
        let mut timings = PhaseTimer::new();
        timings.start("F1");
        let (f, sstats) = crate::io::stream::stream_dense_build(
            data,
            tau,
            opts,
            self.engine.pool(),
            &self.engine.frontend_options(),
            &mut fstats,
        )?;
        timings.stop();
        let n = f.n as usize;
        let h = self.finish_ingest(n, f, timings, fstats, "dense-stream", tau, false)?;
        Ok((h, sstats))
    }

    /// Ingest a filtration someone else built — the coordinator's
    /// PJRT/Pallas kernel path, or a caller migrating from
    /// `compute_ph_from_filtration`. `timings`/`fstats` carry whatever
    /// the build recorded (an `F1` phase on the kernel path); the
    /// neighborhoods build is added here.
    pub fn ingest_filtration(
        &self,
        f: EdgeFiltration,
        timings: PhaseTimer,
        fstats: FiltrationStats,
        edge_source: &'static str,
    ) -> Result<FiltrationHandle, DoryError> {
        let n = f.n as usize;
        let tau = f.tau_max;
        // A pre-built filtration carries no truncation provenance; treat
        // a finite tau_max as the plain ingest threshold. Completeness
        // is decidable from the shape alone.
        self.finish_ingest(n, f, timings, fstats, edge_source, tau, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn finish_ingest(
        &self,
        n_points: usize,
        f: EdgeFiltration,
        timings: PhaseTimer,
        fstats: FiltrationStats,
        edge_source: &'static str,
        tau_requested: f64,
        sparse: bool,
    ) -> Result<FiltrationHandle, DoryError> {
        let (nb, timings, fstats) = self.engine.prepare(&f, timings, fstats)?;
        let enclosing_applied = fstats.enclosing_radius.is_finite();
        let n = f.n as usize;
        let complete = !sparse
            && !enclosing_applied
            && f.tau_max == f64::INFINITY
            && n >= 2
            && f.n_edges() == n * (n - 1) / 2;
        self.counters.ingests.fetch_add(1, Ordering::Relaxed);
        self.counters
            .filtration_builds
            .fetch_add(fstats.f1_builds, Ordering::Relaxed);
        self.counters
            .nb_builds
            .fetch_add(fstats.nb_builds, Ordering::Relaxed);
        Ok(FiltrationHandle {
            f,
            nb,
            fstats,
            timings,
            n_points,
            tau_requested,
            enclosing_applied,
            complete,
            edge_source,
        })
    }

    /// Serve one request from a handle. Sub-τ requests reuse the shared
    /// sorted edge set (prefix copy) and CSR (capped view); diagrams are
    /// bit-identical to a fresh one-shot run at the same τ and options.
    ///
    /// Takes `&self`: any number of threads may query one session (and
    /// one handle) concurrently; all per-query state is local to this
    /// call and the pool interleaves the queries' generations fairly.
    pub fn query(&self, h: &FiltrationHandle, req: &PhRequest) -> Result<PhResponse, DoryError> {
        let opts_eff = self.effective_options(req)?;
        let cut = self.resolve_cut(h, req)?;
        // The deadline clock starts after request validation, covering
        // the truncation copy and the whole reduction.
        let cancel = match req.timeout_ms {
            Some(ms) => crate::reduction::CancelToken::with_timeout_ms(ms),
            None => crate::reduction::CancelToken::none(),
        };
        let ne = h.f.n_edges();
        let mut timings = h.timings.clone();
        let prefix = cut.m < ne;
        // The truncated view is kept alive past the reduction when the
        // request asks for features: representatives must be measured
        // against exactly the filtration view the diagram came from.
        let mut cut_view: Option<(EdgeFiltration, Neighborhoods)> = None;
        let mut result = if prefix {
            timings.start("truncate");
            let fq = h.f.prefix(cut.m, cut.tau_effective);
            let nbq = h.nb.truncated(cut.m as u32);
            timings.stop();
            cut_view = Some((fq, nbq));
            let (fq, nbq) = cut_view.as_ref().unwrap();
            self.engine
                .compute_prepared(fq, nbq, timings, h.fstats, &opts_eff, &cancel)?
        } else {
            self.engine
                .compute_prepared(&h.f, &h.nb, timings, h.fstats, &opts_eff, &cancel)?
        };
        result.stats.n = h.n_points;
        let features = if req.features.is_empty() {
            None
        } else {
            let t0 = std::time::Instant::now();
            let (fv, nbv) = match &cut_view {
                Some((fq, nbq)) => (fq, nbq),
                None => (&h.f, &h.nb),
            };
            let out = crate::features::compute(
                &req.features,
                &result,
                fv,
                nbv,
                cut.tau_effective,
                self.engine.pool(),
            )?;
            result.timings.record("features", t0.elapsed());
            self.counters.feature_queries.fetch_add(1, Ordering::Relaxed);
            Some(out)
        };
        let truncated = prefix || cut.clamped;
        self.counters.queries.fetch_add(1, Ordering::Relaxed);
        if truncated {
            self.counters.truncated_queries.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.full_queries.fetch_add(1, Ordering::Relaxed);
        }
        Ok(PhResponse {
            label: req.label.clone(),
            tau: req.tau,
            tau_effective: cut.tau_effective,
            n_edges: cut.m,
            truncated,
            result,
            features,
        })
    }

    /// Serve many requests over the one ingest (and the one pool),
    /// sequentially, failing fast on the first refused request. The
    /// amortization claim of the service mode: N responses, one build —
    /// `stats().filtration_builds` does not move. Callers wanting the
    /// requests *concurrent* simply issue [`Session::query`] calls from
    /// scoped threads — see the serve layer's batch handler.
    pub fn run_batch(
        &self,
        h: &FiltrationHandle,
        reqs: &[PhRequest],
    ) -> Result<Vec<PhResponse>, DoryError> {
        let mut out = Vec::with_capacity(reqs.len());
        for req in reqs {
            out.push(self.query(h, req)?);
        }
        Ok(out)
    }

    /// The session options with this request's overrides applied.
    fn effective_options(&self, req: &PhRequest) -> Result<EngineOptions, DoryError> {
        let mut opts = self.engine.options().clone();
        if let Some(d) = req.max_dim {
            if d > 2 {
                return Err(DoryError::Request(format!(
                    "max_dim must be <= 2 (paper scope), got {d}"
                )));
            }
            opts.max_dim = d;
        }
        if let Some(s) = req.shortcut {
            opts.shortcut = s;
        }
        // A NaN τ would make every `v <= tau` comparison false and
        // silently serve the empty diagram; a negative τ is the same
        // trap one comparison later (distances are non-negative). Both
        // are caller errors, refused before any work is scheduled.
        if req.tau.is_nan() {
            return Err(DoryError::Request("query tau is NaN".into()));
        }
        if req.tau < 0.0 {
            return Err(DoryError::Request(format!(
                "query tau must be non-negative, got {}",
                req.tau
            )));
        }
        Ok(opts)
    }

    /// How many edges of the handle's sorted set serve this request,
    /// the τ that cut corresponds to, and whether the request was
    /// clamped to an enclosing-truncated handle's edge set.
    fn resolve_cut(&self, h: &FiltrationHandle, req: &PhRequest) -> Result<Cut, DoryError> {
        let ne = h.f.n_edges();
        if req.tau == f64::INFINITY {
            if req.enclosing == Some(false) && h.enclosing_applied {
                return Err(DoryError::Request(
                    "enclosing = false requested at tau = inf, but the handle's ingest \
                     already truncated at the enclosing radius; re-ingest with \
                     enclosing off to serve the full filtration"
                        .into(),
                ));
            }
            if req.enclosing == Some(true) && h.complete {
                // Query-time truncation of a complete handle: derive
                // r_enc from the shared edge set (bit-equal to the
                // build-time row-max sweep) and serve the prefix.
                let r = enclosing_radius_of_filtration(&h.f);
                if r.is_finite() {
                    return Ok(Cut {
                        m: h.f.prefix_len(r),
                        tau_effective: r,
                        clamped: false,
                    });
                }
            }
            return if h.tau_capacity() == f64::INFINITY {
                // On an enclosing-truncated handle the requested +∞
                // exceeds the stored set: same clamp as the finite case
                // below, reported the same way.
                Ok(Cut {
                    m: ne,
                    tau_effective: h.f.tau_max,
                    clamped: h.enclosing_applied,
                })
            } else {
                Err(DoryError::TauExceedsIngest {
                    requested: req.tau,
                    ingested: h.f.tau_max,
                })
            };
        }
        // Finite τ at or beyond the ingest's enclosing radius. Past
        // r_enc = min_i max_j d(i,j) the flag complex is a cone: some
        // vertex c is within r_enc of every vertex, so every simplex
        // entering after r_enc has its coface with c entering at the
        // same value, and those simplices pair off into zero-persistence
        // pairs. The truncated set therefore serves ANY τ ≥ r_enc with
        // diagrams identical to a fresh untruncated build at that τ
        // (this is what makes `tau_capacity()` +∞ here) — but the
        // *request* asked for more edges than the handle stores, so the
        // response must report the clamp: `tau_effective` is r_enc, not
        // the requested τ, and `truncated` is set.
        if h.enclosing_applied && req.tau >= h.f.tau_max {
            return Ok(Cut {
                m: ne,
                tau_effective: h.f.tau_max,
                clamped: req.tau > h.f.tau_max,
            });
        }
        // Finite τ: a prefix of the sorted set, as long as the ingest
        // covered it.
        if req.tau > h.f.tau_max && !h.complete {
            return Err(DoryError::TauExceedsIngest {
                requested: req.tau,
                ingested: h.f.tau_max,
            });
        }
        Ok(Cut {
            m: h.f.prefix_len(req.tau),
            tau_effective: req.tau,
            clamped: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointCloud;
    use crate::homology::engine::compute_ph;
    use crate::util::rng::Pcg32;

    fn cloud(n: usize, seed: u64) -> MetricData {
        let mut rng = Pcg32::new(seed);
        MetricData::Points(PointCloud::new(
            3,
            (0..n * 3).map(|_| rng.next_f64()).collect(),
        ))
    }

    fn bits(d: &crate::homology::Diagram) -> Vec<(usize, u64, u64)> {
        let mut out = Vec::new();
        for dim in 0..=d.max_dim() {
            for p in d.points(dim) {
                out.push((dim, p.birth.to_bits(), p.death.to_bits()));
            }
        }
        out
    }

    #[test]
    fn one_ingest_serves_sub_tau_queries_bit_identically() {
        let data = cloud(24, 9);
        let opts = EngineOptions {
            max_dim: 2,
            threads: 2,
            ..Default::default()
        };
        let s = Session::new(opts.clone());
        let h = s.ingest(&data, 0.9).unwrap();
        for tau in [0.2, 0.45, 0.7, 0.9] {
            let resp = s.query(&h, &PhRequest::at(tau)).unwrap();
            let fresh = compute_ph(&data, tau, &opts);
            assert_eq!(
                bits(&resp.result.diagram),
                bits(&fresh.diagram),
                "tau={tau}"
            );
            assert_eq!(resp.result.stats.h1.pairs, fresh.stats.h1.pairs, "tau={tau}");
        }
        let st = s.stats();
        assert_eq!(st.ingests, 1);
        assert_eq!(st.filtration_builds, 1);
        assert_eq!(st.nb_builds, 1);
        assert_eq!(st.queries, 4);
        assert_eq!(st.truncated_queries, 3);
        assert_eq!(st.full_queries, 1);
    }

    #[test]
    fn typed_errors_on_bad_requests() {
        let data = cloud(12, 3);
        let s = Session::new(EngineOptions {
            max_dim: 1,
            threads: 1,
            ..Default::default()
        });
        let h = s.ingest(&data, 0.5).unwrap();
        assert!(matches!(
            s.query(&h, &PhRequest::at(0.8)).unwrap_err(),
            DoryError::TauExceedsIngest { .. }
        ));
        assert!(matches!(
            s.query(&h, &PhRequest::at(f64::INFINITY)).unwrap_err(),
            DoryError::TauExceedsIngest { .. }
        ));
        assert!(matches!(
            s.query(&h, &PhRequest::at(f64::NAN)).unwrap_err(),
            DoryError::Request(_)
        ));
        // Negative τ (including -inf) is refused up front, not served as
        // an empty diagram.
        assert!(matches!(
            s.query(&h, &PhRequest::at(-0.25)).unwrap_err(),
            DoryError::Request(_)
        ));
        assert!(matches!(
            s.query(&h, &PhRequest::at(f64::NEG_INFINITY)).unwrap_err(),
            DoryError::Request(_)
        ));
        let bad_dim = PhRequest {
            tau: 0.3,
            max_dim: Some(3),
            ..Default::default()
        };
        assert!(matches!(
            s.query(&h, &bad_dim).unwrap_err(),
            DoryError::Request(_)
        ));
        // NaN data refused at ingestion.
        let nan = MetricData::Points(PointCloud::new(2, vec![0.0, 0.0, f64::NAN, 1.0]));
        assert!(matches!(
            s.ingest(&nan, 1.0).unwrap_err(),
            DoryError::InvalidInput(_)
        ));
    }

    #[test]
    fn per_request_overrides_apply() {
        let data = cloud(20, 5);
        let s = Session::new(EngineOptions {
            max_dim: 2,
            threads: 1,
            ..Default::default()
        });
        let h = s.ingest(&data, 0.8).unwrap();
        let d1 = s
            .query(
                &h,
                &PhRequest {
                    tau: 0.8,
                    max_dim: Some(1),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(d1.result.diagram.max_dim(), 1);
        let off = s
            .query(
                &h,
                &PhRequest {
                    tau: 0.8,
                    shortcut: Some(false),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(off.result.stats.h1.shortcut_pairs, 0);
        let on = s.query(&h, &PhRequest::at(0.8)).unwrap();
        assert!(on.result.stats.h1.shortcut_pairs > 0);
        assert_eq!(bits(&on.result.diagram), bits(&off.result.diagram));
    }
}
