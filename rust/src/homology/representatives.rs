//! Representative cycles for H1 classes (the paper's §7 extension).
//!
//! "our algorithm can also be extended to compute representative
//! boundaries of the holes and voids in the data set … critical for
//! connecting topology to structural properties" — this module delivers
//! the 1-dimensional case: for an H1 class born at edge `e = {a, b}`, a
//! representative cycle at birth is `e` plus a shortest path from `a` to
//! `b` through edges *earlier than e* (such a path exists precisely
//! because a birth edge is positive — its endpoints are already
//! connected). Hop-count BFS gives a geometrically tight loop.

use std::collections::VecDeque;

use crate::filtration::{EdgeFiltration, Neighborhoods};

/// A representative loop: vertices in cycle order (closed implicitly).
#[derive(Clone, Debug)]
pub struct Cycle {
    pub vertices: Vec<u32>,
    /// Birth value of the class it represents.
    pub birth: f64,
    /// Death value (`f64::INFINITY` for essential classes).
    pub death: f64,
}

impl Cycle {
    pub fn len(&self) -> usize {
        self.vertices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vertices.is_empty()
    }

    /// Total geometric length of the loop under the filtration metric.
    pub fn perimeter(&self, nb: &Neighborhoods, f: &EdgeFiltration) -> f64 {
        let n = self.vertices.len();
        (0..n)
            .map(|i| {
                let (u, v) = (self.vertices[i], self.vertices[(i + 1) % n]);
                nb.edge_order(u, v)
                    .map(|o| f.values[o as usize])
                    .unwrap_or(f64::NAN)
            })
            .sum()
    }
}

/// BFS from `a` to `b` using only edges with order < `max_order`.
/// Returns the path a..=b, or None if disconnected (then the edge was
/// negative — not a birth).
fn bfs_path(
    nb: &Neighborhoods,
    a: u32,
    b: u32,
    max_order: u32,
    scratch: &mut Vec<u32>,
) -> Option<Vec<u32>> {
    const UNSEEN: u32 = u32::MAX;
    let n = nb.n as usize;
    if scratch.len() != n {
        scratch.clear();
        scratch.resize(n, UNSEEN);
    } else {
        scratch.iter_mut().for_each(|x| *x = UNSEEN);
    }
    let parent = scratch;
    let mut queue = VecDeque::new();
    parent[a as usize] = a;
    queue.push_back(a);
    'bfs: while let Some(u) = queue.pop_front() {
        let (vtx, ord) = nb.vn(u);
        for (&v, &o) in vtx.iter().zip(ord) {
            if o < max_order && parent[v as usize] == UNSEEN {
                parent[v as usize] = u;
                if v == b {
                    break 'bfs;
                }
                queue.push_back(v);
            }
        }
    }
    if parent[b as usize] == UNSEEN {
        return None;
    }
    let mut path = vec![b];
    let mut cur = b;
    while cur != a {
        cur = parent[cur as usize];
        path.push(cur);
    }
    path.reverse();
    Some(path)
}

/// Representative cycles for the H1 classes found by the engine.
/// `pairs` are (birth edge, death value) — from
/// [`crate::homology::PhResult::h1_pairs`] (mapped through `key_value`)
/// and `h1_essential_edges`.
pub fn h1_representatives(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    births: &[(u32, f64)],
) -> Vec<Cycle> {
    let mut scratch = Vec::new();
    births
        .iter()
        .filter_map(|&(e, death)| {
            let (a, b) = f.edges[e as usize];
            let path = bfs_path(nb, a, b, e, &mut scratch)?;
            Some(Cycle {
                vertices: path,
                birth: f.values[e as usize],
                death,
            })
        })
        .collect()
}

/// Convenience: cycles for every H1 class of a finished run with
/// persistence above `min_persistence`.
pub fn representatives_from_result(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    r: &crate::homology::PhResult,
    min_persistence: f64,
) -> Vec<Cycle> {
    let mut births: Vec<(u32, f64)> = r
        .h1_pairs
        .iter()
        .map(|&(e, k)| (e, f.key_value(k)))
        .filter(|&(e, d)| d - f.values[e as usize] > min_persistence)
        .collect();
    births.extend(r.h1_essential_edges.iter().map(|&e| (e, f64::INFINITY)));
    h1_representatives(nb, f, &births)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::homology::{compute_ph_from_filtration, EngineOptions};

    fn run(data: &crate::geometry::MetricData, tau: f64) -> (EdgeFiltration, Neighborhoods, crate::homology::PhResult) {
        let f = EdgeFiltration::build(data, tau);
        let nb = Neighborhoods::build(&f, false);
        let r = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 1,
                ..Default::default()
            },
        );
        (f, nb, r)
    }

    #[test]
    fn circle_representative_wraps_the_circle() {
        let data = datasets::circle(40, 1.0, 0.0, 1);
        let (f, nb, r) = run(&data, 3.0);
        let cycles = representatives_from_result(&nb, &f, &r, 0.5);
        assert_eq!(cycles.len(), 1);
        let c = &cycles[0];
        // The dominant loop must use a large fraction of the circle.
        assert!(c.len() >= 20, "cycle too short: {}", c.len());
        // Closed walk: consecutive vertices share filtration edges.
        let per = c.perimeter(&nb, &f);
        assert!(per.is_finite() && per > 4.0, "perimeter {per}");
    }

    #[test]
    fn figure_eight_two_distinct_loops() {
        let data = datasets::figure_eight(80, 1.0, 0.0, 2);
        let (f, nb, r) = run(&data, 1.2);
        let cycles = representatives_from_result(&nb, &f, &r, 0.4);
        assert_eq!(cycles.len(), 2);
        // The two loops live on different halves of the point set
        // (figure_eight places circle 1 on indices < n/2).
        let sides: Vec<usize> = cycles
            .iter()
            .map(|c| c.vertices.iter().filter(|&&v| v < 40).count() * 2 / c.len())
            .collect();
        assert_ne!(sides[0] > 0, sides[1] > 0, "loops must separate: {sides:?}");
    }

    #[test]
    fn cycles_are_genuine_closed_walks() {
        let data = datasets::torus3(300, 2.0, 0.7, 5);
        let (f, nb, r) = run(&data, 1.4);
        for c in representatives_from_result(&nb, &f, &r, 0.3) {
            let n = c.len();
            assert!(n >= 3);
            for i in 0..n {
                let (u, v) = (c.vertices[i], c.vertices[(i + 1) % n]);
                let o = nb.edge_order(u, v).expect("cycle edge must exist");
                // Every edge of the representative exists at birth time.
                assert!(f.values[o as usize] <= c.birth + 1e-12);
            }
            // Simple cycle: no repeated vertices.
            let set: std::collections::HashSet<_> = c.vertices.iter().collect();
            assert_eq!(set.len(), n, "repeated vertex in representative");
        }
    }

    #[test]
    fn negative_edges_yield_no_cycle() {
        // A path graph has no H1 at all; asking for representatives of
        // its (nonexistent) births must yield nothing rather than panic.
        let data = crate::geometry::MetricData::Points(crate::geometry::PointCloud::new(
            1,
            vec![0.0, 1.0, 2.0, 3.0],
        ));
        let (f, nb, r) = run(&data, 10.0);
        assert!(representatives_from_result(&nb, &f, &r, 0.0).is_empty());
    }
}
