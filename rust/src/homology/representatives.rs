//! Representative cycles for H1 classes (the paper's §7 extension).
//!
//! "our algorithm can also be extended to compute representative
//! boundaries of the holes and voids in the data set … critical for
//! connecting topology to structural properties" — this module delivers
//! the 1-dimensional case: for an H1 class born at edge `e = {a, b}`, a
//! representative cycle at birth is `e` plus a path from `a` to `b`
//! through edges *earlier than e* (such a path exists precisely because
//! a birth edge is positive — its endpoints are already connected).
//!
//! Two path rules are provided:
//!
//! * hop-count BFS ([`h1_representatives`]) — the minimal-hop loop;
//! * geodesic Dijkstra ([`h1_tight_representatives`]) — the loop of
//!   minimal total edge length, the "tight" representative in the
//!   spirit of Aggarwal–Periwal's *Tight basis cycle representatives
//!   for persistent homology of large data sets*: among all cycles
//!   containing the birth edge and otherwise using only earlier edges,
//!   it minimizes the geometric perimeter. This is the rule the served
//!   `representatives` feature spec uses
//!   ([`crate::features::cycles`]).
//!
//! Both are single-threaded, deterministic functions of the served
//! filtration view — ties in the Dijkstra frontier break on
//! `(distance bits, vertex id)`, so the emitted loop never depends on
//! schedule or thread count.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::error::DoryError;
use crate::filtration::{EdgeFiltration, Neighborhoods};

/// A representative loop: vertices in cycle order (closed implicitly).
#[derive(Clone, Debug)]
pub struct Cycle {
    pub vertices: Vec<u32>,
    /// Birth value of the class it represents.
    pub birth: f64,
    /// Death value (`f64::INFINITY` for essential classes).
    pub death: f64,
}

impl Cycle {
    pub fn len(&self) -> usize {
        self.vertices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vertices.is_empty()
    }

    /// Total geometric length of the loop under the filtration metric.
    ///
    /// Total: a consecutive vertex pair with no edge in `nb` — e.g. a
    /// cycle re-measured against a *more* truncated `Neighborhoods`
    /// view than it was extracted from — is a typed
    /// [`DoryError::Feature`], never a silent NaN.
    pub fn perimeter(&self, nb: &Neighborhoods, f: &EdgeFiltration) -> Result<f64, DoryError> {
        let n = self.vertices.len();
        let mut total = 0.0f64;
        for i in 0..n {
            let (u, v) = (self.vertices[i], self.vertices[(i + 1) % n]);
            let o = nb.edge_order(u, v).ok_or_else(|| {
                DoryError::Feature(format!(
                    "cycle edge ({u}, {v}) is not present in the served filtration view \
                     (birth {}); the cycle was extracted from a larger prefix",
                    self.birth
                ))
            })?;
            total += f.values[o as usize];
        }
        Ok(total)
    }
}

/// BFS from `a` to `b` using only edges with order < `max_order`.
/// Returns the path a..=b, or None if disconnected (then the edge was
/// negative — not a birth).
fn bfs_path(
    nb: &Neighborhoods,
    a: u32,
    b: u32,
    max_order: u32,
    scratch: &mut Vec<u32>,
) -> Option<Vec<u32>> {
    const UNSEEN: u32 = u32::MAX;
    let n = nb.n as usize;
    if scratch.len() != n {
        scratch.clear();
        scratch.resize(n, UNSEEN);
    } else {
        scratch.iter_mut().for_each(|x| *x = UNSEEN);
    }
    let parent = scratch;
    let mut queue = VecDeque::new();
    parent[a as usize] = a;
    queue.push_back(a);
    'bfs: while let Some(u) = queue.pop_front() {
        let (vtx, ord) = nb.vn(u);
        for (&v, &o) in vtx.iter().zip(ord) {
            if o < max_order && parent[v as usize] == UNSEEN {
                parent[v as usize] = u;
                if v == b {
                    break 'bfs;
                }
                queue.push_back(v);
            }
        }
    }
    if parent[b as usize] == UNSEEN {
        return None;
    }
    let mut path = vec![b];
    let mut cur = b;
    while cur != a {
        cur = parent[cur as usize];
        path.push(cur);
    }
    path.reverse();
    Some(path)
}

/// Geodesic shortest path from `a` to `b` using only edges with order
/// < `max_order`, minimizing total edge *length* (not hop count) —
/// Dijkstra over the truncated neighborhood view. Deterministic: the
/// frontier orders on `(length bits, vertex id)` (lengths are
/// non-negative, so the bit order is the numeric order) and relaxation
/// improves strictly, so equal-length alternatives resolve identically
/// on every run.
fn dijkstra_path(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    a: u32,
    b: u32,
    max_order: u32,
) -> Option<Vec<u32>> {
    const UNSEEN: u32 = u32::MAX;
    let n = nb.n as usize;
    let mut dist = vec![f64::INFINITY; n];
    let mut parent = vec![UNSEEN; n];
    let mut done = vec![false; n];
    let mut heap: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    dist[a as usize] = 0.0;
    parent[a as usize] = a;
    heap.push(Reverse((0, a)));
    while let Some(Reverse((dbits, u))) = heap.pop() {
        if done[u as usize] || dbits != dist[u as usize].to_bits() {
            continue; // stale frontier entry
        }
        done[u as usize] = true;
        if u == b {
            break;
        }
        let (vtx, ord) = nb.vn(u);
        for (&v, &o) in vtx.iter().zip(ord) {
            if o < max_order && !done[v as usize] {
                let nd = dist[u as usize] + f.values[o as usize];
                if nd < dist[v as usize] {
                    dist[v as usize] = nd;
                    parent[v as usize] = u;
                    heap.push(Reverse((nd.to_bits(), v)));
                }
            }
        }
    }
    if !done[b as usize] {
        return None;
    }
    let mut path = vec![b];
    let mut cur = b;
    while cur != a {
        cur = parent[cur as usize];
        path.push(cur);
    }
    path.reverse();
    Some(path)
}

/// Representative cycles for the H1 classes found by the engine.
/// `pairs` are (birth edge, death value) — from
/// [`crate::homology::PhResult::h1_pairs`] (mapped through `key_value`)
/// and `h1_essential_edges`.
pub fn h1_representatives(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    births: &[(u32, f64)],
) -> Vec<Cycle> {
    let mut scratch = Vec::new();
    births
        .iter()
        .filter_map(|&(e, death)| {
            let (a, b) = f.edges[e as usize];
            let path = bfs_path(nb, a, b, e, &mut scratch)?;
            Some(Cycle {
                vertices: path,
                birth: f.values[e as usize],
                death,
            })
        })
        .collect()
}

/// Geodesically tight representative cycles: like
/// [`h1_representatives`], but the closing path minimizes total edge
/// length (Dijkstra) instead of hop count — Aggarwal–Periwal's tight
/// representative.
pub fn h1_tight_representatives(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    births: &[(u32, f64)],
) -> Vec<Cycle> {
    births
        .iter()
        .filter_map(|&(e, death)| {
            let (a, b) = f.edges[e as usize];
            let path = dijkstra_path(nb, f, a, b, e)?;
            Some(Cycle {
                vertices: path,
                birth: f.values[e as usize],
                death,
            })
        })
        .collect()
}

/// The (birth edge, death value) list of every H1 class of a finished
/// run with persistence above `min_persistence` (essential classes
/// always qualify).
fn births_from_result(
    f: &EdgeFiltration,
    r: &crate::homology::PhResult,
    min_persistence: f64,
) -> Vec<(u32, f64)> {
    let mut births: Vec<(u32, f64)> = r
        .h1_pairs
        .iter()
        .map(|&(e, k)| (e, f.key_value(k)))
        .filter(|&(e, d)| d - f.values[e as usize] > min_persistence)
        .collect();
    births.extend(r.h1_essential_edges.iter().map(|&e| (e, f64::INFINITY)));
    births
}

/// Convenience: hop-BFS cycles for every H1 class of a finished run
/// with persistence above `min_persistence`.
pub fn representatives_from_result(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    r: &crate::homology::PhResult,
    min_persistence: f64,
) -> Vec<Cycle> {
    h1_representatives(nb, f, &births_from_result(f, r, min_persistence))
}

/// Convenience: geodesically tight cycles for every H1 class of a
/// finished run with persistence above `min_persistence` — the rule the
/// served `representatives` feature uses.
pub fn tight_representatives_from_result(
    nb: &Neighborhoods,
    f: &EdgeFiltration,
    r: &crate::homology::PhResult,
    min_persistence: f64,
) -> Vec<Cycle> {
    h1_tight_representatives(nb, f, &births_from_result(f, r, min_persistence))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::homology::{compute_ph_from_filtration, EngineOptions};

    fn run(data: &crate::geometry::MetricData, tau: f64) -> (EdgeFiltration, Neighborhoods, crate::homology::PhResult) {
        let f = EdgeFiltration::build(data, tau);
        let nb = Neighborhoods::build(&f, false);
        let r = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 1,
                ..Default::default()
            },
        );
        (f, nb, r)
    }

    #[test]
    fn circle_representative_wraps_the_circle() {
        let data = datasets::circle(40, 1.0, 0.0, 1);
        let (f, nb, r) = run(&data, 3.0);
        let cycles = representatives_from_result(&nb, &f, &r, 0.5);
        assert_eq!(cycles.len(), 1);
        let c = &cycles[0];
        // The dominant loop must use a large fraction of the circle.
        assert!(c.len() >= 20, "cycle too short: {}", c.len());
        // Closed walk: consecutive vertices share filtration edges.
        let per = c.perimeter(&nb, &f).unwrap();
        assert!(per.is_finite() && per > 4.0, "perimeter {per}");
    }

    #[test]
    fn tight_representatives_never_lengthen_the_loop() {
        // The Dijkstra path minimizes geometric length, so for every
        // class the tight perimeter is <= the hop-BFS perimeter — and
        // the tight loop satisfies the same structural invariants.
        let data = datasets::torus3(300, 2.0, 0.7, 5);
        let (f, nb, r) = run(&data, 1.4);
        let bfs = representatives_from_result(&nb, &f, &r, 0.3);
        let tight = tight_representatives_from_result(&nb, &f, &r, 0.3);
        assert_eq!(bfs.len(), tight.len());
        assert!(!tight.is_empty());
        for (b, t) in bfs.iter().zip(&tight) {
            assert_eq!(b.birth, t.birth);
            assert_eq!(b.death, t.death);
            let (pb, pt) = (b.perimeter(&nb, &f).unwrap(), t.perimeter(&nb, &f).unwrap());
            assert!(
                pt <= pb + 1e-12,
                "tight {pt} must not exceed BFS {pb} (birth {})",
                b.birth
            );
            // Same anchors (the path still runs a -> b for edge {a, b}).
            assert_eq!(b.vertices.first(), t.vertices.first());
            assert_eq!(b.vertices.last(), t.vertices.last());
            let n = t.len();
            assert!(n >= 3);
            for i in 0..n {
                let (u, v) = (t.vertices[i], t.vertices[(i + 1) % n]);
                let o = nb.edge_order(u, v).expect("tight cycle edge must exist");
                assert!(f.values[o as usize] <= t.birth + 1e-12);
            }
            let set: std::collections::HashSet<_> = t.vertices.iter().collect();
            assert_eq!(set.len(), n, "repeated vertex in tight representative");
        }
    }

    #[test]
    fn perimeter_is_total_on_truncated_views() {
        // Extract a cycle from the full view, then re-measure it against
        // a harsher truncation: a typed Feature error, not NaN.
        let data = datasets::circle(40, 1.0, 0.0, 1);
        let (f, nb, r) = run(&data, 3.0);
        let cycles = representatives_from_result(&nb, &f, &r, 0.5);
        assert_eq!(cycles.len(), 1);
        let c = &cycles[0];
        let nb_small = nb.truncated(1);
        match c.perimeter(&nb_small, &f) {
            Err(crate::error::DoryError::Feature(m)) => {
                assert!(m.contains("not present"), "{m}")
            }
            other => panic!("expected Feature error, got {other:?}"),
        }
    }

    #[test]
    fn figure_eight_two_distinct_loops() {
        let data = datasets::figure_eight(80, 1.0, 0.0, 2);
        let (f, nb, r) = run(&data, 1.2);
        let cycles = representatives_from_result(&nb, &f, &r, 0.4);
        assert_eq!(cycles.len(), 2);
        // The two loops live on different halves of the point set
        // (figure_eight places circle 1 on indices < n/2).
        let sides: Vec<usize> = cycles
            .iter()
            .map(|c| c.vertices.iter().filter(|&&v| v < 40).count() * 2 / c.len())
            .collect();
        assert_ne!(sides[0] > 0, sides[1] > 0, "loops must separate: {sides:?}");
    }

    #[test]
    fn cycles_are_genuine_closed_walks() {
        let data = datasets::torus3(300, 2.0, 0.7, 5);
        let (f, nb, r) = run(&data, 1.4);
        for c in representatives_from_result(&nb, &f, &r, 0.3) {
            let n = c.len();
            assert!(n >= 3);
            for i in 0..n {
                let (u, v) = (c.vertices[i], c.vertices[(i + 1) % n]);
                let o = nb.edge_order(u, v).expect("cycle edge must exist");
                // Every edge of the representative exists at birth time.
                assert!(f.values[o as usize] <= c.birth + 1e-12);
            }
            // Simple cycle: no repeated vertices.
            let set: std::collections::HashSet<_> = c.vertices.iter().collect();
            assert_eq!(set.len(), n, "repeated vertex in representative");
        }
    }

    #[test]
    fn negative_edges_yield_no_cycle() {
        // A path graph has no H1 at all; asking for representatives of
        // its (nonexistent) births must yield nothing rather than panic.
        let data = crate::geometry::MetricData::Points(crate::geometry::PointCloud::new(
            1,
            vec![0.0, 1.0, 2.0, 3.0],
        ));
        let (f, nb, r) = run(&data, 10.0);
        assert!(representatives_from_result(&nb, &f, &r, 0.0).is_empty());
    }
}
