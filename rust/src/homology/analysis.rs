//! Diagram analysis toolkit: bottleneck distance, persistence entropy,
//! and summary statistics.
//!
//! The bottleneck distance is the standard stability metric for PDs
//! (used here to validate the SimBa-style sparsifier, paper §7 /
//! Dey et al. 2019). Exact computation: binary search over candidate
//! thresholds with a Hopcroft–Karp-style matching feasibility test on
//! the threshold graph (points may also match to the diagonal).

use super::diagram::{Diagram, Point};

/// L∞ distance between two PD points.
fn dinf(a: &Point, b: &Point) -> f64 {
    let dd = if a.death.is_infinite() && b.death.is_infinite() {
        0.0
    } else if a.death.is_infinite() || b.death.is_infinite() {
        f64::INFINITY
    } else {
        (a.death - b.death).abs()
    };
    (a.birth - b.birth).abs().max(dd)
}

/// Distance of a point to the diagonal (its cheapest deletion cost).
fn diag_cost(p: &Point) -> f64 {
    if p.death.is_infinite() {
        f64::INFINITY
    } else {
        (p.death - p.birth) / 2.0
    }
}

/// Exact bottleneck distance between the dim-`dim` parts of two PDs.
/// Returns `f64::INFINITY` when essential-class counts differ.
pub fn bottleneck_distance(a: &Diagram, b: &Diagram, dim: usize) -> f64 {
    let pa: Vec<Point> = a.points(dim).to_vec();
    let pb: Vec<Point> = b.points(dim).to_vec();
    let ess_a = pa.iter().filter(|p| p.is_essential()).count();
    let ess_b = pb.iter().filter(|p| p.is_essential()).count();
    if ess_a != ess_b {
        return f64::INFINITY;
    }
    // Candidate thresholds: all pairwise costs + diagonal costs.
    let mut cands: Vec<f64> = Vec::new();
    for x in &pa {
        for y in &pb {
            let d = dinf(x, y);
            if d.is_finite() {
                cands.push(d);
            }
        }
        if let c @ 0.0..=f64::MAX = diag_cost(x) {
            cands.push(c);
        }
    }
    for y in &pb {
        if let c @ 0.0..=f64::MAX = diag_cost(y) {
            cands.push(c);
        }
    }
    cands.push(0.0);
    cands.sort_by(|x, y| x.partial_cmp(y).unwrap());
    cands.dedup();
    // Binary search the smallest feasible threshold.
    let (mut lo, mut hi) = (0usize, cands.len() - 1);
    if !feasible(&pa, &pb, cands[hi]) {
        return f64::INFINITY;
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(&pa, &pb, cands[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    cands[lo]
}

/// Is there a perfect matching at threshold `eps`? Points may match a
/// partner within `eps` or their own diagonal if `diag_cost <= eps`.
/// Kuhn's augmenting-path matching (sizes here are small: PD points).
fn feasible(pa: &[Point], pb: &[Point], eps: f64) -> bool {
    let eps = eps + 1e-12;
    let na = pa.len();
    let nb = pb.len();
    // Left nodes: pa points; right: pb points. Diagonal absorbs the rest,
    // but a diagonal deletion on one side must be "paid" on the other
    // side too — standard reduction: both diagrams are augmented with one
    // diagonal copy per opposite point.
    // adjacency: a_i ~ b_j if dinf <= eps; a_i ~ its diagonal if
    // diag_cost(a_i) <= eps (then some b_j must also go to diagonal or
    // match elsewhere — handled by the augmented formulation below).
    let can_a: Vec<bool> = pa.iter().map(|p| diag_cost(p) <= eps).collect();
    let can_b: Vec<bool> = pb.iter().map(|p| diag_cost(p) <= eps).collect();
    // Match all of pa: each a either to a compatible b or to diagonal.
    // Then the unmatched b's must all be diagonal-compatible.
    let mut match_b: Vec<Option<usize>> = vec![None; nb];
    let mut matched_a = vec![false; na];
    for i in 0..na {
        let mut seen = vec![false; nb];
        if try_match(i, pa, pb, eps, &mut seen, &mut match_b) {
            matched_a[i] = true;
        }
    }
    // Greedy augmenting above already maximizes; now assign leftovers.
    for i in 0..na {
        if !matched_a[i] && !can_a[i] {
            // Re-attempt with full augmentation before failing.
            let mut seen = vec![false; nb];
            if !try_match(i, pa, pb, eps, &mut seen, &mut match_b) {
                return false;
            }
            matched_a[i] = true;
        }
    }
    for j in 0..nb {
        if match_b[j].is_none() && !can_b[j] {
            return false;
        }
    }
    true
}

fn try_match(
    i: usize,
    pa: &[Point],
    pb: &[Point],
    eps: f64,
    seen: &mut [bool],
    match_b: &mut [Option<usize>],
) -> bool {
    for j in 0..pb.len() {
        if !seen[j] && dinf(&pa[i], &pb[j]) <= eps {
            seen[j] = true;
            let prev = match_b[j];
            match match_b[j] {
                None => {
                    match_b[j] = Some(i);
                    return true;
                }
                Some(k) => {
                    if try_match(k, pa, pb, eps, seen, match_b) {
                        match_b[j] = Some(i);
                        return true;
                    }
                    match_b[j] = prev;
                }
            }
        }
    }
    false
}

/// Persistence entropy (Chintakunta et al.): Shannon entropy of the
/// normalized finite bar lengths — a scalar PD summary.
pub fn persistence_entropy(d: &Diagram, dim: usize) -> f64 {
    let lens: Vec<f64> = d
        .points(dim)
        .iter()
        .filter(|p| !p.is_essential())
        .map(|p| p.persistence())
        .collect();
    let total: f64 = lens.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    -lens
        .iter()
        .filter(|&&l| l > 0.0)
        .map(|&l| {
            let p = l / total;
            p * p.ln()
        })
        .sum::<f64>()
}

/// Max finite persistence in a dimension (the dominant feature's scale).
pub fn max_persistence(d: &Diagram, dim: usize) -> f64 {
    d.points(dim)
        .iter()
        .filter(|p| !p.is_essential())
        .map(|p| p.persistence())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(points: &[(f64, f64)]) -> Diagram {
        let mut d = Diagram::new(1);
        for &(b, dd) in points {
            d.push(1, b, dd);
        }
        d
    }

    #[test]
    fn identical_diagrams_distance_zero() {
        let a = diag(&[(0.1, 0.9), (0.3, 0.5)]);
        assert_eq!(bottleneck_distance(&a, &a, 1), 0.0);
    }

    #[test]
    fn shifted_point_gives_shift() {
        let a = diag(&[(0.0, 1.0)]);
        let b = diag(&[(0.0, 1.2)]);
        let d = bottleneck_distance(&a, &b, 1);
        assert!((d - 0.2).abs() < 1e-9, "{d}");
    }

    #[test]
    fn small_bar_matches_diagonal() {
        // Extra tiny bar costs its half-persistence, not a full match.
        let a = diag(&[(0.0, 1.0)]);
        let b = diag(&[(0.0, 1.0), (0.5, 0.6)]);
        let d = bottleneck_distance(&a, &b, 1);
        assert!((d - 0.05).abs() < 1e-9, "{d}");
    }

    #[test]
    fn essential_mismatch_is_infinite() {
        let a = diag(&[(0.0, f64::INFINITY)]);
        let b = diag(&[(0.0, 1.0)]);
        assert!(bottleneck_distance(&a, &b, 1).is_infinite());
    }

    #[test]
    fn essential_births_compare() {
        let a = diag(&[(0.0, f64::INFINITY)]);
        let b = diag(&[(0.4, f64::INFINITY)]);
        let d = bottleneck_distance(&a, &b, 1);
        assert!((d - 0.4).abs() < 1e-9, "{d}");
    }

    #[test]
    fn symmetric_and_triangleish() {
        let a = diag(&[(0.0, 1.0), (0.2, 0.8)]);
        let b = diag(&[(0.1, 1.05)]);
        let c = diag(&[(0.05, 0.95), (0.2, 0.9)]);
        let ab = bottleneck_distance(&a, &b, 1);
        let ba = bottleneck_distance(&b, &a, 1);
        assert!((ab - ba).abs() < 1e-12);
        let (ac, cb) = (
            bottleneck_distance(&a, &c, 1),
            bottleneck_distance(&c, &b, 1),
        );
        assert!(ab <= ac + cb + 1e-12);
    }

    #[test]
    fn entropy_behaviour() {
        // One bar: entropy 0; two equal bars: ln 2.
        assert_eq!(persistence_entropy(&diag(&[(0.0, 1.0)]), 1), 0.0);
        let e = persistence_entropy(&diag(&[(0.0, 1.0), (2.0, 3.0)]), 1);
        assert!((e - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn max_persistence_picks_dominant() {
        let d = diag(&[(0.0, 0.4), (0.1, 2.0), (0.0, f64::INFINITY)]);
        assert!((max_persistence(&d, 1) - 1.9).abs() < 1e-12);
    }
}
