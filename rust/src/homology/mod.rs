//! The full PH pipeline: H0 (union-find) → H1* → H2* with clearing.

pub mod analysis;
pub mod diagram;
pub mod engine;
pub mod h0;
pub mod representatives;

pub use diagram::Diagram;
pub use engine::{
    compute_ph, compute_ph_from_filtration, Algorithm, Engine, EngineOptions, PhResult,
};
