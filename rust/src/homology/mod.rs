//! The full PH pipeline: H0 (union-find) → H1* → H2* with clearing,
//! served either one-shot (`compute_ph*`, deprecated shims) or through
//! the [`Session`] service API (ingest once, answer many typed
//! [`PhRequest`]s from the shared build).

pub mod analysis;
pub mod diagram;
pub mod engine;
pub mod h0;
pub mod representatives;
pub mod session;

pub use diagram::Diagram;
pub use engine::{
    compute_ph, compute_ph_from_filtration, Algorithm, Engine, EngineOptions, PhResult,
};
pub use session::{FiltrationHandle, PhRequest, PhResponse, Session, SessionStats};
