//! Dory's Algorithm 3: compute H0, H1* and H2* with the clearing strategy.
//!
//! * H0 by union-find over ascending edges; negative edges form the dim-0
//!   clearing set.
//! * H1*: cohomology reduction of non-cleared edges in reverse filtration
//!   order. Pairs `(e, t)` are H1 (birth, death); zero columns are
//!   essential loops.
//! * H2*: triangle columns enumerated per diameter edge (descending), with
//!   both H1-death clearing and the trivial-pair O(1) skip (the death
//!   triangle of a trivial H1 pair is `smallest_tri[e]`); pairs `(t, h)`
//!   are H2 (birth, death).
//!
//! **Apparent-pair shortcut (on by default, `EngineOptions::shortcut`):**
//! the overwhelming majority of surviving columns form zero-persistence
//! apparent pairs — their minimal cofacet shares their diameter and its
//! maximal equal-diameter facet round-trips back to the column. Both
//! shard sources detect this *at enumeration time*, inside the shard
//! fills on pool workers (H1\*: an O(1) `smallest_tri` lookup; H2\*: one
//! `FindSmallesth` probe per candidate via
//! [`crate::coboundary::triangles::apparent_cofacet`]), count the pair,
//! and suppress the column — it never enters the stream, a
//! `BucketTable`, or the batch pipeline. Output is bit-identical with
//! the shortcut on or off (the fallback is the reduction's own
//! first-low trivial test), pinned by the differential harness sweeping
//! both settings.
//!
//! With `threads > 1` the column enumeration of both H1* and H2* is
//! **sharded over the work-stealing pool**: the descending diameter-edge
//! range is tiled into shards ([`crate::reduction::shard_plan`], knobs
//! `enum_shards`/`enum_grain`), workers enumerate each shard into a
//! private buffer (driving `triangles_with_diameter` per edge), and the
//! pipelined scheduler splices the shards back in canonical order while
//! already reducing earlier chunks — see
//! [`crate::reduction::serial_parallel`] for the three-stage pipeline.
//! The [`Engine`] owns one persistent pool, reused across H1*/H2* and
//! across repeated [`Engine::compute`] calls (multi-run service mode).
//!
//! Engine choices (sequential fast-column, serial–parallel fast-column,
//! implicit-row) and the sparse/dense `edge_order` lookup (Dory vs DoryNS)
//! are the paper's ablation axes (Tables 3 & 4).

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coboundary::edges::{edge_columns_in_range, edge_columns_in_range_shortcut};
use crate::coboundary::triangles::{
    apparent_cofacet, triangles_with_diameter, triangles_with_diameter_in_range,
};
use crate::filtration::{
    EdgeFiltration, FiltrationStats, FrontendOptions, Key, Neighborhoods, SimdMode,
};
use crate::geometry::MetricData;
use crate::reduction::pool::ThreadPool;
use crate::reduction::{
    fast_column, implicit_row, serial_parallel, shard_plan, ColumnShards, EdgeColumns,
    ReduceResult, ReduceStats, SchedConfig, SchedStats, TriangleColumns,
};
use crate::util::timer::PhaseTimer;

use super::diagram::Diagram;
use super::h0;

/// Which implicit reduction engine to run (paper Table 4 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Fast implicit column (§4.3.4) — the paper's headline engine.
    FastColumn,
    /// Implicit row (§4.3.2) — the simpler engine, kept for the ablation.
    ImplicitRow,
}

#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Highest homology dimension to compute (0, 1 or 2).
    pub max_dim: usize,
    /// Worker threads for the serial–parallel scheduler; 1 = sequential.
    pub threads: usize,
    /// Serial–parallel batch size (paper default 100 for H1*/H2*); the
    /// starting point when `adaptive_batch` is on.
    pub batch_size: usize,
    /// Adapt the batch size to the observed serial/push time ratio
    /// (pipelined scheduler; output is identical either way).
    pub adaptive_batch: bool,
    /// Batch-size bounds for the adaptation.
    pub batch_min: usize,
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto.
    pub steal_grain: usize,
    /// Serial-fraction bounds steering the batch-size adaptation: below
    /// `adapt_low` the batch doubles, above `adapt_high` it halves.
    pub adapt_low: f64,
    pub adapt_high: f64,
    /// Shards for the pooled H1*/H2* column enumeration; 0 = auto.
    /// Ignored (enumeration is inline) for sequential runs.
    pub enum_shards: usize,
    /// Diameter edges per enumeration shard; 0 = auto. Takes precedence
    /// over `enum_shards` when both are set.
    pub enum_grain: usize,
    /// Apparent-pair shortcut at enumeration time (on by default):
    /// columns whose minimal cofacet round-trips back to them — a
    /// zero-persistence trivial pair — are resolved inside the shard
    /// fills (on pool workers for threaded runs) and never enter the
    /// column stream, a `BucketTable`, or the batch pipeline. Off =
    /// exact fallback: every column is streamed and the reduction's own
    /// first-low trivial test resolves them; output is bit-identical
    /// either way (differential harness sweeps both).
    pub shortcut: bool,
    /// Point rows per front-end distance tile (0 = auto): the
    /// granularity at which `compute_metric`'s F1 build is dealt onto
    /// the worker pool. Output is byte-identical for every tile plan.
    pub f1_tile: usize,
    /// Enclosing-radius truncation (on by default): when no finite
    /// `tau` was requested, cut the filtration at
    /// `r_enc = min_i max_j d(i, j)` — the VR complex is a cone beyond
    /// it, so diagrams are unchanged while the edge set shrinks
    /// (`FiltrationStats::edges_pruned` reports by how much). Off =
    /// exact full-filtration fallback.
    pub enclosing: bool,
    /// Distance microkernel for the dense front-end tiles: `Auto`
    /// (default) probes the CPU at run time and picks the widest
    /// available vector path (AVX2 on x86_64, NEON on aarch64),
    /// `Scalar` forces the portable loop, and a forced vector mode
    /// degrades to scalar when the feature is absent. Emitted edge
    /// bits are identical for every mode
    /// (`FiltrationStats::dist_kernel` reports which one ran).
    pub simd: SimdMode,
    /// DoryNS: O(n²) dense edge-order lookup instead of binary search.
    pub dense_lookup: bool,
    pub algorithm: Algorithm,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            max_dim: 2,
            threads: 1,
            batch_size: 100,
            adaptive_batch: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
            adapt_low: 0.25,
            adapt_high: 0.75,
            enum_shards: 0,
            enum_grain: 0,
            shortcut: true,
            f1_tile: 0,
            enclosing: true,
            simd: SimdMode::Auto,
            dense_lookup: false,
            algorithm: Algorithm::FastColumn,
        }
    }
}

impl EngineOptions {
    /// The scheduler slice of the options.
    pub fn sched_config(&self) -> SchedConfig {
        SchedConfig {
            batch_size: self.batch_size,
            adaptive: self.adaptive_batch,
            batch_min: self.batch_min,
            batch_max: self.batch_max,
            steal_grain: self.steal_grain,
            adapt_low: self.adapt_low,
            adapt_high: self.adapt_high,
        }
    }

    /// The enumeration shard plan over `n_e` diameter edges.
    pub fn enum_plan(&self, n_edges: usize) -> Vec<std::ops::Range<u32>> {
        shard_plan(n_edges, self.threads, self.enum_shards, self.enum_grain)
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub n: usize,
    pub n_edges: usize,
    pub h0_deaths: usize,
    pub h0_essential: usize,
    pub h1: ReduceStats,
    pub h2: ReduceStats,
    pub h1_cleared: usize,
    pub h2_cleared: usize,
    pub base_memory_bytes: usize,
    /// Measured heap bytes of every array the front-end materializes
    /// (the `EdgeFiltration` edge/value arrays plus all `Neighborhoods`
    /// CSR arrays and the optional DoryNS table).
    pub front_memory_bytes: usize,
    /// Pipelined-scheduler reports (all-zero for sequential runs).
    pub h1_sched: SchedStats,
    pub h2_sched: SchedStats,
    /// Front-end report: distance/sort/CSR phase times, tile and chunk
    /// counts, considered/kept/pruned edges, the enclosing radius.
    /// Default (all-zero) when the caller pre-built the filtration
    /// without stats.
    pub filtration: FiltrationStats,
}

impl EngineStats {
    /// Combined scheduler report across the reduction phases.
    pub fn sched_total(&self) -> SchedStats {
        let mut s = self.h1_sched;
        s.merge(&self.h2_sched);
        s
    }
}

/// Full result: diagram + structural pairs + stats + phase timings.
pub struct PhResult {
    pub diagram: Diagram,
    pub stats: EngineStats,
    pub timings: PhaseTimer,
    /// H1 pairs as (edge order, triangle key) — used by callers that need
    /// representative simplices rather than values.
    pub h1_pairs: Vec<(u32, Key)>,
    pub h1_essential_edges: Vec<u32>,
}

/// Sharded H1\* column source: edge orders descending, dim-0 clearing
/// applied inside each shard. With the shortcut on, apparent pairs —
/// edges whose precomputed smallest cofacet shares their diameter — are
/// resolved in-shard too (`skipped`, order-independent atomic) and
/// suppressed from the stream.
struct H1Shards<'a> {
    negative: &'a [bool],
    /// `Some(smallest_tri)` enables the in-shard apparent-pair shortcut.
    shortcut_tri: Option<&'a [Key]>,
    ranges: Vec<std::ops::Range<u32>>,
    skipped: AtomicUsize,
}

impl ColumnShards for H1Shards<'_> {
    fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    fn fill(&self, shard: usize, out: &mut Vec<u64>) {
        match self.shortcut_tri {
            Some(smallest_tri) => {
                let skipped = edge_columns_in_range_shortcut(
                    self.ranges[shard].clone(),
                    self.negative,
                    smallest_tri,
                    out,
                );
                self.skipped.fetch_add(skipped, Ordering::Relaxed);
            }
            None => edge_columns_in_range(self.ranges[shard].clone(), self.negative, out),
        }
    }
}

/// Sharded H2\* column source: triangles grouped by descending diameter
/// edge, with trivial-death and H1-death clearing applied inside each
/// shard. With the shortcut on, each surviving triangle is probed for an
/// apparent pair (minimal cofacet via `FindSmallesth`, maximal
/// equal-diameter facet round-trip) right here on the enumerating pool
/// worker; apparent columns are counted in `skipped` and suppressed, so
/// they never reach a `BucketTable`. Cleared/skipped counts accumulate
/// order-independently into atomics, so totals are deterministic across
/// steal schedules.
struct H2Shards<'a> {
    nb: &'a Neighborhoods,
    f: &'a EdgeFiltration,
    smallest_tri: &'a [Key],
    h1_deaths: &'a HashSet<u64>,
    ranges: Vec<std::ops::Range<u32>>,
    shortcut: bool,
    cleared: AtomicUsize,
    skipped: AtomicUsize,
}

impl ColumnShards for H2Shards<'_> {
    fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    fn fill(&self, shard: usize, out: &mut Vec<u64>) {
        let mut cleared = 0usize;
        let mut skipped = 0usize;
        triangles_with_diameter_in_range(
            self.nb,
            self.f,
            self.ranges[shard].clone(),
            |t| {
                // Clearing first — exactly where the unshortcut stream
                // drops these columns, before any trivial probe.
                if self.smallest_tri[t.p as usize] == t || self.h1_deaths.contains(&t.pack()) {
                    cleared += 1; // death of a trivial or real H1 pair
                    false
                } else if self.shortcut && apparent_cofacet(self.nb, self.f, t).is_some() {
                    skipped += 1; // zero-persistence apparent pair
                    false
                } else {
                    true
                }
            },
            out,
        );
        self.cleared.fetch_add(cleared, Ordering::Relaxed);
        self.skipped.fetch_add(skipped, Ordering::Relaxed);
    }
}

/// A persistent PH engine: options plus the worker pool they imply.
///
/// The pool is created once and reused across the H1\* and H2\* phases
/// *and* across repeated [`Engine::compute`] calls — no worker threads
/// are spawned or torn down between runs, which is what the multi-run
/// service mode needs. `rust/tests/differential.rs` stress-tests that
/// reuse (20 back-to-back runs on one engine, bit-identical output,
/// deterministic generation accounting).
pub struct Engine {
    opts: EngineOptions,
    pool: Option<ThreadPool>,
}

impl Engine {
    pub fn new(opts: EngineOptions) -> Self {
        assert!(opts.max_dim <= 2, "Dory computes up to H2 (paper scope)");
        // Only the fast-column scheduler consumes the pool; implicit-row
        // is sequential by design (Table 4 ablation), so a persistent
        // engine must not park idle workers for it.
        let pool = if opts.threads > 1 && opts.algorithm == Algorithm::FastColumn {
            Some(ThreadPool::new(opts.threads))
        } else {
            None
        };
        Self { opts, pool }
    }

    pub fn options(&self) -> &EngineOptions {
        &self.opts
    }

    /// The engine's persistent pool (`None` for sequential engines).
    pub fn pool(&self) -> Option<&ThreadPool> {
        self.pool.as_ref()
    }

    /// The front-end knobs implied by the options.
    pub fn frontend_options(&self) -> FrontendOptions {
        FrontendOptions {
            tile: self.opts.f1_tile,
            enclosing: self.opts.enclosing,
            simd: self.opts.simd,
        }
    }

    /// Compute PH of a metric input up to `max_dim` with threshold
    /// `tau`. The F1 build (tiled distance kernel, key sort, enclosing
    /// truncation) runs on the engine's pool when it has one.
    pub fn compute_metric(&self, data: &MetricData, tau: f64) -> PhResult {
        let mut timings = PhaseTimer::new();
        let mut fstats = FiltrationStats::default();
        timings.start("F1");
        let f = EdgeFiltration::build_pooled(
            data,
            tau,
            self.pool(),
            &self.frontend_options(),
            &mut fstats,
        );
        timings.stop();
        let mut r = self.compute_with_stats(&f, timings, fstats);
        r.stats.n = data.n();
        r
    }

    /// Compute PH from a pre-built edge filtration.
    pub fn compute(&self, f: &EdgeFiltration) -> PhResult {
        self.compute_with_stats(f, PhaseTimer::new(), FiltrationStats::default())
    }

    /// Compute PH from a filtration the caller built (with whatever
    /// timer/front-end stats that build produced — the coordinator's
    /// PJRT path lands here). The `Neighborhoods` CSR fill still runs
    /// on the engine's pool and is added to `fstats`.
    pub fn compute_with_stats(
        &self,
        f: &EdgeFiltration,
        timings: PhaseTimer,
        fstats: FiltrationStats,
    ) -> PhResult {
        let (nb, timings, fstats) = self
            .prepare(f, timings, fstats)
            .unwrap_or_else(|e| panic!("{e}"));
        self.compute_prepared(
            f,
            &nb,
            timings,
            fstats,
            &self.opts,
            &crate::reduction::CancelToken::none(),
        )
        .unwrap_or_else(|e| panic!("{e}"))
    }

    /// The shared front-end finish every entry path runs exactly once
    /// per build: record the F1 sub-phase breakdown and build the
    /// `Neighborhoods` CSR (pooled) under its own phase. One
    /// implementation serves both the one-shot wrappers (which unwrap)
    /// and the session ingest (which propagates the typed error).
    pub fn prepare(
        &self,
        f: &EdgeFiltration,
        mut timings: PhaseTimer,
        mut fstats: FiltrationStats,
    ) -> Result<(Neighborhoods, PhaseTimer, FiltrationStats), crate::error::DoryError> {
        // Sub-phase records for the front-end breakdown ('/' names are
        // excluded from PhaseTimer::total, so F1 is not double-counted).
        if fstats.dist_ns > 0 || fstats.sort_ns > 0 {
            timings.record("F1/dist", std::time::Duration::from_nanos(fstats.dist_ns));
            timings.record("F1/sort", std::time::Duration::from_nanos(fstats.sort_ns));
        }
        timings.start("neighborhoods");
        let nb = Neighborhoods::try_build_pooled(
            f,
            self.opts.dense_lookup,
            self.pool(),
            &mut fstats,
        )?;
        timings.stop();
        Ok((nb, timings, fstats))
    }

    /// The reduction pipeline (H0 → H1* → H2*) over a filtration whose
    /// `Neighborhoods` the caller already holds — the session layer's
    /// entry: one handle's CSR serves many queries, with `opts` carrying
    /// per-request knob overrides (`max_dim`, `shortcut`, scheduler
    /// knobs; `threads`/`algorithm` stay engine-level, the persistent
    /// pool is `self`'s). `fstats` is carried into the result verbatim —
    /// for session queries it is the *shared ingest's* front-end report,
    /// not per-query work (its `f1_builds`/`nb_builds` counters pin the
    /// ingest-once guarantee).
    ///
    /// `cancel` is polled between homology dimensions and at every
    /// batch-commit boundary inside the pipelined reduction; a tripped
    /// deadline returns a typed
    /// [`DoryError::DeadlineExceeded`](crate::error::DoryError) with all
    /// request-local state dropped — the shared `f`/`nb` are never
    /// mutated, so the owning handle keeps serving.
    pub fn compute_prepared(
        &self,
        f: &EdgeFiltration,
        nb: &Neighborhoods,
        mut timings: PhaseTimer,
        fstats: FiltrationStats,
        opts: &EngineOptions,
        cancel: &crate::reduction::CancelToken,
    ) -> Result<PhResult, crate::error::DoryError> {
        let mut stats = EngineStats {
            n: f.n as usize,
            n_edges: f.n_edges(),
            base_memory_bytes: f.base_memory_model_bytes(),
            ..Default::default()
        };
        let mut diagram = Diagram::new(opts.max_dim);
        stats.filtration = fstats;
        stats.front_memory_bytes = f.memory_bytes() + nb.memory_bytes();

        // ---- H0 ---------------------------------------------------------
        cancel.check()?;
        timings.start("H0");
        let h0r = h0::compute(f);
        for &e in &h0r.death_edges {
            diagram.push(0, 0.0, f.values[e as usize]);
        }
        for _ in 0..h0r.essential {
            diagram.push(0, 0.0, f64::INFINITY);
        }
        stats.h0_deaths = h0r.death_edges.len();
        stats.h0_essential = h0r.essential;
        timings.stop();

        let mut h1_pairs = Vec::new();
        let mut h1_essential_edges = Vec::new();

        if opts.max_dim >= 1 {
            // ---- H1* ----------------------------------------------------
            cancel.check()?;
            timings.start("H1*");
            let space = EdgeColumns::new(nb, f);
            let ne = f.n_edges();
            let h1_src = H1Shards {
                negative: &h0r.negative,
                shortcut_tri: opts.shortcut.then_some(&space.smallest_tri[..]),
                ranges: opts.enum_plan(ne),
                skipped: AtomicUsize::new(0),
            };
            // H1 keeps zero-persistence pairs: their death triangles feed
            // the dim-2 clearing set. (Trivial pairs are not stored, so
            // in-shard shortcut columns feed dim-2 clearing through
            // `smallest_tri` exactly as before.)
            let mut res = self.run_reduction(&space, &h1_src, true, f, opts, cancel)?;
            let h1_skipped = h1_src.skipped.load(Ordering::Relaxed);
            res.stats.shortcut_pairs = h1_skipped;
            res.stats.trivial_pairs += h1_skipped;
            res.sched.shortcut_columns = h1_skipped as u64;
            stats.h1_cleared = ne - res.stats.columns - h1_skipped;
            stats.h1_sched = res.sched;
            for &(col, key) in &res.pairs {
                let e = col as u32;
                diagram.push(1, f.values[e as usize], f.key_value(key));
                h1_pairs.push((e, key));
            }
            for &col in &res.essential {
                let e = col as u32;
                diagram.push(1, f.values[e as usize], f64::INFINITY);
                h1_essential_edges.push(e);
            }
            stats.h1 = res.stats;
            timings.stop();

            if opts.max_dim >= 2 {
                // ---- H2* ------------------------------------------------
                // Triangle columns are enumerated in reverse filtration
                // order with clearing applied on the fly (the trivial-
                // death skip is O(1)); with a pool, the enumeration runs
                // sharded on the workers inside the reduction pipeline.
                cancel.check()?;
                timings.start("H2*");
                let h1_deaths: HashSet<u64> =
                    res.pairs.iter().map(|&(_, k)| k.pack()).collect();
                let tspace = TriangleColumns::new(nb, f);
                let h2_src = H2Shards {
                    nb,
                    f,
                    smallest_tri: &space.smallest_tri,
                    h1_deaths: &h1_deaths,
                    ranges: opts.enum_plan(ne),
                    shortcut: opts.shortcut,
                    cleared: AtomicUsize::new(0),
                    skipped: AtomicUsize::new(0),
                };
                let mut res2 = self.run_reduction(&tspace, &h2_src, false, f, opts, cancel)?;
                let h2_skipped = h2_src.skipped.load(Ordering::Relaxed);
                res2.stats.shortcut_pairs = h2_skipped;
                res2.stats.trivial_pairs += h2_skipped;
                res2.sched.shortcut_columns = h2_skipped as u64;
                stats.h2_cleared = h2_src.cleared.load(Ordering::Relaxed);
                stats.h2_sched = res2.sched;
                for &(col, key) in &res2.pairs {
                    let t = Key::unpack(col);
                    diagram.push(2, f.key_value(t), f.key_value(key));
                }
                for &col in &res2.essential {
                    let t = Key::unpack(col);
                    diagram.push(2, f.key_value(t), f64::INFINITY);
                }
                stats.h2 = res2.stats;
                timings.stop();
            }
        }

        timings.stop();
        Ok(PhResult {
            diagram,
            stats,
            timings,
            h1_pairs,
            h1_essential_edges,
        })
    }

    fn run_reduction<S: crate::reduction::ColumnSpace, Src: ColumnShards>(
        &self,
        space: &S,
        src: &Src,
        keep_zero_pairs: bool,
        f: &EdgeFiltration,
        opts: &EngineOptions,
        cancel: &crate::reduction::CancelToken,
    ) -> Result<ReduceResult, crate::error::DoryError> {
        // Column birth value: for edges the id *is* the order; for
        // triangles the id is a packed key whose primary carries the
        // value. Both cases are covered by inspecting the id width: edge
        // ids < 2^32.
        let value_of = |col: u64| -> f64 {
            if col <= u32::MAX as u64 {
                f.values[col as usize]
            } else {
                f.key_value(Key::unpack(col))
            }
        };
        let key_value = |k: Key| f.key_value(k);
        match (opts.algorithm, &self.pool) {
            (Algorithm::FastColumn, Some(pool)) => serial_parallel::reduce_stream(
                space,
                src,
                &opts.sched_config(),
                pool,
                keep_zero_pairs,
                cancel,
                value_of,
                key_value,
            ),
            (algorithm, _) => {
                // Sequential paths materialize the stream inline through
                // the same shard primitives, so the column sequence is
                // identical by construction. Cancellation is coarser
                // here: one poll per enumerated shard plus one before
                // the (monolithic) reduction.
                let mut cols: Vec<u64> = Vec::new();
                for s in 0..src.n_shards() {
                    cancel.check()?;
                    src.fill(s, &mut cols);
                }
                cancel.check()?;
                Ok(match algorithm {
                    Algorithm::ImplicitRow => implicit_row::reduce_all(
                        space,
                        cols.iter().copied(),
                        keep_zero_pairs,
                        value_of,
                        key_value,
                    ),
                    Algorithm::FastColumn => fast_column::reduce_all(
                        space,
                        cols.iter().copied(),
                        keep_zero_pairs,
                        value_of,
                        key_value,
                    ),
                })
            }
        }
    }
}

/// Compute PH of a metric input up to `opts.max_dim` with threshold
/// `tau`, on a transient one-query [`super::Session`].
///
/// **Deprecated shim** (kept so existing tests and fixtures pin
/// behavior): every call pays a full ingest — filtration, CSR, pool
/// spin-up. Services answering more than one query should hold a
/// [`super::Session`], [`super::Session::ingest`] once, and query the
/// handle; fallible paths then surface as typed
/// [`crate::error::DoryError`]s instead of the panics this wrapper
/// re-raises.
pub fn compute_ph(data: &MetricData, tau: f64, opts: &EngineOptions) -> PhResult {
    let session = super::Session::new(opts.clone());
    let handle = session
        .ingest(data, tau)
        .unwrap_or_else(|e| panic!("{e}"));
    session
        .query(&handle, &super::PhRequest::at(tau))
        .unwrap_or_else(|e| panic!("{e}"))
        .result
}

/// Compute PH from a pre-built edge filtration, on a transient
/// one-query [`super::Session`].
///
/// **Deprecated shim**: copies the filtration into a throwaway handle
/// and queries its full capacity. Assumes the documented
/// [`EdgeFiltration::from_weighted_edges`] contract (every edge value
/// `<= tau_max`), under which the capacity query serves the whole edge
/// set. Callers computing many filtrations (or many τ on one
/// filtration) should hold a [`super::Session`] and use
/// [`super::Session::ingest_filtration`] to keep the pool and the CSR
/// alive across queries.
pub fn compute_ph_from_filtration(f: &EdgeFiltration, opts: &EngineOptions) -> PhResult {
    let session = super::Session::new(opts.clone());
    let handle = session
        .ingest_filtration(
            f.clone(),
            PhaseTimer::new(),
            FiltrationStats::default(),
            "caller",
        )
        .unwrap_or_else(|e| panic!("{e}"));
    let tau = handle.tau_capacity();
    session
        .query(&handle, &super::PhRequest::at(tau))
        .unwrap_or_else(|e| panic!("{e}"))
        .result
}

/// Count simplices of the flag complex (Table 1's `N` column).
pub fn count_simplices(f: &EdgeFiltration, nb: &Neighborhoods, max_dim: usize) -> u64 {
    let mut total = f.n as u64 + f.n_edges() as u64;
    if max_dim >= 1 {
        // Triangles, grouped by diameter edge.
        let mut tris = 0u64;
        let mut tets = 0u64;
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            let vs = triangles_with_diameter(nb, e, a, b);
            tris += vs.len() as u64;
            if max_dim >= 2 {
                // Tetrahedra with diameter e: pairs (v, w) of case-1
                // vertices whose connecting edge is also < e.
                for i in 0..vs.len() {
                    for j in (i + 1)..vs.len() {
                        if let Some(o) = nb.edge_order(vs[i], vs[j]) {
                            if o < e {
                                tets += 1;
                            }
                        }
                    }
                }
            }
        }
        total += tris + tets;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointCloud;
    use crate::reduction::explicit::oracle_diagram;
    use crate::util::rng::Pcg32;

    fn random_cloud(n: usize, dim: usize, seed: u64) -> MetricData {
        let mut rng = Pcg32::new(seed);
        MetricData::Points(PointCloud::new(
            dim,
            (0..n * dim).map(|_| rng.next_f64()).collect(),
        ))
    }

    fn check_vs_oracle(data: &MetricData, tau: f64, opts: &EngineOptions, label: &str) {
        let f = EdgeFiltration::build(data, tau);
        let nb = Neighborhoods::build(&f, false);
        let got = compute_ph_from_filtration(&f, opts).diagram;
        let want = oracle_diagram(&f, &nb, opts.max_dim);
        assert!(
            got.multiset_eq(&want, 1e-9),
            "{label}:\n got: {}\nwant: {}",
            got.diff_summary(&want),
            want.diff_summary(&got),
        );
    }

    #[test]
    fn matches_oracle_on_random_clouds_dim1() {
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        for seed in 0..10 {
            let data = random_cloud(25, 2, seed);
            check_vs_oracle(&data, 0.5, &opts, &format!("dim1 seed={seed}"));
        }
    }

    #[test]
    fn matches_oracle_on_random_clouds_dim2() {
        let opts = EngineOptions::default();
        for seed in 0..10 {
            let data = random_cloud(18, 3, seed);
            check_vs_oracle(&data, 0.8, &opts, &format!("dim2 seed={seed}"));
        }
    }

    #[test]
    fn all_engine_configurations_agree() {
        let data = random_cloud(20, 3, 42);
        let f = EdgeFiltration::build(&data, 0.9);
        let reference = compute_ph_from_filtration(&f, &EngineOptions::default()).diagram;
        for algorithm in [Algorithm::FastColumn, Algorithm::ImplicitRow] {
            for threads in [1usize, 4] {
                for dense in [false, true] {
                    for (batch, adaptive) in [(1usize, false), (7, false), (100, false), (8, true)]
                    {
                        for (enum_shards, enum_grain) in [(0usize, 0usize), (3, 0), (0, 2)] {
                            for shortcut in [true, false] {
                                let opts = EngineOptions {
                                    max_dim: 2,
                                    threads,
                                    batch_size: batch,
                                    adaptive_batch: adaptive,
                                    batch_min: 2,
                                    enum_shards,
                                    enum_grain,
                                    shortcut,
                                    dense_lookup: dense,
                                    algorithm,
                                    ..Default::default()
                                };
                                let got = compute_ph_from_filtration(&f, &opts).diagram;
                                assert!(
                                    got.multiset_eq(&reference, 1e-9),
                                    "algo={algorithm:?} threads={threads} dense={dense} batch={batch} adaptive={adaptive} shards={enum_shards} grain={enum_grain} shortcut={shortcut}:\n{}",
                                    got.diff_summary(&reference)
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_enumeration_runs_on_workers() {
        // With a pool, both H1* and H2* column enumeration must execute
        // as pool tasks (nonzero shards and worker busy time), not on
        // the scheduler thread.
        let data = random_cloud(24, 3, 7);
        let f = EdgeFiltration::build(&data, 0.9);
        let opts = EngineOptions {
            max_dim: 2,
            threads: 4,
            enum_shards: 5,
            ..Default::default()
        };
        let r = compute_ph_from_filtration(&f, &opts);
        for (label, s) in [("h1", &r.stats.h1_sched), ("h2", &r.stats.h2_sched)] {
            assert!(s.enum_shards > 0, "{label}: no enumeration shards on the pool");
            assert!(s.tasks >= s.enum_shards, "{label}: shards must be pool tasks");
        }
        // H1 always has surviving (non-negative) edge columns here; H2
        // column counts depend on clearing, so only H1 is asserted.
        assert!(r.stats.h1_sched.enum_columns > 0);
        assert_eq!(
            r.stats.h1_sched.enum_columns as usize
                + r.stats.h1_cleared
                + r.stats.h1.shortcut_pairs,
            f.n_edges(),
            "streamed + cleared + shortcut H1 columns must cover every edge"
        );
        // Sequential runs enumerate inline: shard stats stay zero.
        let seq = compute_ph_from_filtration(
            &f,
            &EngineOptions {
                max_dim: 2,
                threads: 1,
                ..Default::default()
            },
        );
        assert_eq!(seq.stats.h2_sched.enum_shards, 0);
        assert!(r.diagram.multiset_eq(&seq.diagram, 0.0));
    }

    #[test]
    fn shortcut_accounting_is_exact_and_output_invariant() {
        // The same instance with the shortcut on/off: identical diagram
        // at zero tolerance; trivial-pair totals invariant; the on-run
        // moves columns from the stream into `shortcut_pairs` one for
        // one; clearing untouched.
        let data = random_cloud(22, 3, 31);
        let f = EdgeFiltration::build(&data, 0.85);
        for threads in [1usize, 4] {
            let mk = |shortcut: bool| EngineOptions {
                max_dim: 2,
                threads,
                shortcut,
                ..Default::default()
            };
            let on = compute_ph_from_filtration(&f, &mk(true));
            let off = compute_ph_from_filtration(&f, &mk(false));
            assert!(
                on.diagram.multiset_eq(&off.diagram, 0.0),
                "threads={threads}: shortcut must not change the diagram"
            );
            for (label, s_on, s_off) in [
                ("h1", &on.stats.h1, &off.stats.h1),
                ("h2", &on.stats.h2, &off.stats.h2),
            ] {
                assert_eq!(s_off.shortcut_pairs, 0, "{label} threads={threads}");
                assert_eq!(
                    s_on.trivial_pairs, s_off.trivial_pairs,
                    "{label} threads={threads}: trivial totals must be invariant"
                );
                assert_eq!(
                    s_on.columns + s_on.shortcut_pairs,
                    s_off.columns,
                    "{label} threads={threads}: shortcut columns must leave the stream 1:1"
                );
                assert_eq!(s_on.pairs, s_off.pairs, "{label} threads={threads}");
                assert_eq!(s_on.essential, s_off.essential, "{label} threads={threads}");
            }
            // Every trivial pair is apparent at its first low, so with
            // the shortcut on none should survive into the reduction.
            assert_eq!(on.stats.h1.shortcut_pairs, on.stats.h1.trivial_pairs);
            assert_eq!(on.stats.h2.shortcut_pairs, on.stats.h2.trivial_pairs);
            // A dense-enough cloud always has apparent pairs in both dims.
            assert!(on.stats.h1.shortcut_pairs > 0, "threads={threads}");
            assert!(on.stats.h2.shortcut_pairs > 0, "threads={threads}");
            assert_eq!(on.stats.h1_cleared, off.stats.h1_cleared, "threads={threads}");
            assert_eq!(on.stats.h2_cleared, off.stats.h2_cleared, "threads={threads}");
            assert!(on.stats.h1.skip_rate() > 0.0 && on.stats.h1.skip_rate() <= 1.0);
        }
    }

    #[test]
    fn engine_reuses_pool_across_runs() {
        let data = random_cloud(22, 3, 13);
        let f = EdgeFiltration::build(&data, 0.85);
        let engine = Engine::new(EngineOptions {
            max_dim: 2,
            threads: 3,
            adaptive_batch: false,
            batch_size: 9,
            ..Default::default()
        });
        let gens0 = engine.pool().unwrap().stats().generations;
        let first = engine.compute(&f);
        let gens1 = engine.pool().unwrap().stats().generations;
        assert!(gens1 > gens0, "pooled run must submit generations");
        let second = engine.compute(&f);
        assert!(first.diagram.multiset_eq(&second.diagram, 0.0));
        // With adaptation off the generation structure is deterministic,
        // so a repeated run submits exactly as many generations again.
        let gens2 = engine.pool().unwrap().stats().generations;
        assert_eq!(gens2 - gens1, gens1 - gens0);
    }

    #[test]
    fn circle_loop_detected() {
        let mut coords = Vec::new();
        for i in 0..24 {
            let t = 2.0 * std::f64::consts::PI * i as f64 / 24.0;
            coords.push(t.cos());
            coords.push(t.sin());
        }
        let data = MetricData::Points(PointCloud::new(2, coords));
        let r = compute_ph(&data, 3.0, &EngineOptions::default());
        let sig = r.diagram.significant(1, 0.5);
        assert_eq!(sig.len(), 1, "one dominant loop: {:?}", r.diagram.points(1));
        assert_eq!(r.diagram.essential_count(0), 1);
    }

    #[test]
    fn sphere_void_detected() {
        // Fibonacci sphere sample: one dominant H2 class.
        let n = 60;
        let mut coords = Vec::new();
        let phi = std::f64::consts::PI * (3.0 - 5f64.sqrt());
        for i in 0..n {
            let y = 1.0 - 2.0 * (i as f64 + 0.5) / n as f64;
            let r = (1.0 - y * y).sqrt();
            let t = phi * i as f64;
            coords.push(r * t.cos());
            coords.push(y);
            coords.push(r * t.sin());
        }
        let data = MetricData::Points(PointCloud::new(3, coords));
        let r = compute_ph(&data, 2.5, &EngineOptions::default());
        let sig = r.diagram.significant(2, 0.5);
        assert_eq!(sig.len(), 1, "one dominant void: {:?}", r.diagram.points(2));
    }

    #[test]
    fn simplex_counts_match_binomials_on_full_filtration() {
        // Complete filtration on n points: C(n,k+1) simplices per dim.
        let data = random_cloud(10, 2, 5);
        let f = EdgeFiltration::build(&data, 10.0);
        let nb = Neighborhoods::build(&f, false);
        let n = 10u64;
        let expect = n + n * (n - 1) / 2 + n * (n - 1) * (n - 2) / 6
            + n * (n - 1) * (n - 2) * (n - 3) / 24;
        assert_eq!(count_simplices(&f, &nb, 2), expect);
    }
}
