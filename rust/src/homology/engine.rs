//! Dory's Algorithm 3: compute H0, H1* and H2* with the clearing strategy.
//!
//! * H0 by union-find over ascending edges; negative edges form the dim-0
//!   clearing set.
//! * H1*: cohomology reduction of non-cleared edges in reverse filtration
//!   order. Pairs `(e, t)` are H1 (birth, death); zero columns are
//!   essential loops.
//! * H2*: triangle columns enumerated per diameter edge (descending), with
//!   both H1-death clearing and the trivial-pair O(1) skip (the death
//!   triangle of a trivial H1 pair is `smallest_tri[e]`); pairs `(t, h)`
//!   are H2 (birth, death).
//!
//! Engine choices (sequential fast-column, serial–parallel fast-column,
//! implicit-row) and the sparse/dense `edge_order` lookup (Dory vs DoryNS)
//! are the paper's ablation axes (Tables 3 & 4).

use std::collections::HashSet;

use crate::coboundary::triangles::triangles_with_diameter;
use crate::filtration::{EdgeFiltration, Key, Neighborhoods};
use crate::geometry::MetricData;
use crate::reduction::pool::ThreadPool;
use crate::reduction::{
    fast_column, implicit_row, serial_parallel, EdgeColumns, ReduceResult, ReduceStats,
    SchedConfig, SchedStats, TriangleColumns,
};
use crate::util::timer::PhaseTimer;

use super::diagram::Diagram;
use super::h0;

/// Which implicit reduction engine to run (paper Table 4 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Fast implicit column (§4.3.4) — the paper's headline engine.
    FastColumn,
    /// Implicit row (§4.3.2) — the simpler engine, kept for the ablation.
    ImplicitRow,
}

#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Highest homology dimension to compute (0, 1 or 2).
    pub max_dim: usize,
    /// Worker threads for the serial–parallel scheduler; 1 = sequential.
    pub threads: usize,
    /// Serial–parallel batch size (paper default 100 for H1*/H2*); the
    /// starting point when `adaptive_batch` is on.
    pub batch_size: usize,
    /// Adapt the batch size to the observed serial/push time ratio
    /// (pipelined scheduler; output is identical either way).
    pub adaptive_batch: bool,
    /// Batch-size bounds for the adaptation.
    pub batch_min: usize,
    pub batch_max: usize,
    /// Columns per work-stealing task; 0 = auto.
    pub steal_grain: usize,
    /// DoryNS: O(n²) dense edge-order lookup instead of binary search.
    pub dense_lookup: bool,
    pub algorithm: Algorithm,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            max_dim: 2,
            threads: 1,
            batch_size: 100,
            adaptive_batch: true,
            batch_min: 16,
            batch_max: 8192,
            steal_grain: 0,
            dense_lookup: false,
            algorithm: Algorithm::FastColumn,
        }
    }
}

impl EngineOptions {
    /// The scheduler slice of the options.
    pub fn sched_config(&self) -> SchedConfig {
        SchedConfig {
            batch_size: self.batch_size,
            adaptive: self.adaptive_batch,
            batch_min: self.batch_min,
            batch_max: self.batch_max,
            steal_grain: self.steal_grain,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub n: usize,
    pub n_edges: usize,
    pub h0_deaths: usize,
    pub h0_essential: usize,
    pub h1: ReduceStats,
    pub h2: ReduceStats,
    pub h1_cleared: usize,
    pub h2_cleared: usize,
    pub base_memory_bytes: usize,
    /// Pipelined-scheduler reports (all-zero for sequential runs).
    pub h1_sched: SchedStats,
    pub h2_sched: SchedStats,
}

impl EngineStats {
    /// Combined scheduler report across the reduction phases.
    pub fn sched_total(&self) -> SchedStats {
        let mut s = self.h1_sched;
        s.merge(&self.h2_sched);
        s
    }
}

/// Full result: diagram + structural pairs + stats + phase timings.
pub struct PhResult {
    pub diagram: Diagram,
    pub stats: EngineStats,
    pub timings: PhaseTimer,
    /// H1 pairs as (edge order, triangle key) — used by callers that need
    /// representative simplices rather than values.
    pub h1_pairs: Vec<(u32, Key)>,
    pub h1_essential_edges: Vec<u32>,
}

/// Compute PH of a metric input up to `opts.max_dim` with threshold `tau`.
pub fn compute_ph(data: &MetricData, tau: f64, opts: &EngineOptions) -> PhResult {
    let mut timings = PhaseTimer::new();
    timings.start("F1");
    let f = EdgeFiltration::build(data, tau);
    timings.stop();
    let mut r = compute_ph_from_filtration_timed(&f, opts, timings);
    r.stats.n = data.n();
    r
}

/// Compute PH from a pre-built edge filtration.
pub fn compute_ph_from_filtration(f: &EdgeFiltration, opts: &EngineOptions) -> PhResult {
    compute_ph_from_filtration_timed(f, opts, PhaseTimer::new())
}

fn compute_ph_from_filtration_timed(
    f: &EdgeFiltration,
    opts: &EngineOptions,
    mut timings: PhaseTimer,
) -> PhResult {
    assert!(opts.max_dim <= 2, "Dory computes up to H2 (paper scope)");
    let mut stats = EngineStats {
        n: f.n as usize,
        n_edges: f.n_edges(),
        base_memory_bytes: f.base_memory_model_bytes(),
        ..Default::default()
    };
    let mut diagram = Diagram::new(opts.max_dim);

    timings.start("neighborhoods");
    let nb = Neighborhoods::build(f, opts.dense_lookup);
    timings.stop();

    // ---- H0 -------------------------------------------------------------
    timings.start("H0");
    let h0r = h0::compute(f);
    for &e in &h0r.death_edges {
        diagram.push(0, 0.0, f.values[e as usize]);
    }
    for _ in 0..h0r.essential {
        diagram.push(0, 0.0, f64::INFINITY);
    }
    stats.h0_deaths = h0r.death_edges.len();
    stats.h0_essential = h0r.essential;
    timings.stop();

    let mut h1_pairs = Vec::new();
    let mut h1_essential_edges = Vec::new();

    let pool = if opts.threads > 1 {
        Some(ThreadPool::new(opts.threads))
    } else {
        None
    };

    if opts.max_dim >= 1 {
        // ---- H1* ---------------------------------------------------------
        timings.start("H1*");
        let space = EdgeColumns::new(&nb, f);
        let ne = f.n_edges();
        let cols: Vec<u64> = (0..ne as u64)
            .rev()
            .filter(|&e| !h0r.negative[e as usize])
            .collect();
        stats.h1_cleared = ne - cols.len();
        // H1 keeps zero-persistence pairs: their death triangles feed the
        // dim-2 clearing set.
        let res = run_reduction(&space, &cols, opts, &pool, true, f);
        stats.h1_sched = res.sched;
        for &(col, key) in &res.pairs {
            let e = col as u32;
            diagram.push(1, f.values[e as usize], f.key_value(key));
            h1_pairs.push((e, key));
        }
        for &col in &res.essential {
            let e = col as u32;
            diagram.push(1, f.values[e as usize], f64::INFINITY);
            h1_essential_edges.push(e);
        }
        stats.h1 = res.stats;
        timings.stop();

        if opts.max_dim >= 2 {
            // ---- H2* -------------------------------------------------------
            timings.start("H2*");
            let h1_deaths: HashSet<u64> = res.pairs.iter().map(|&(_, k)| k.pack()).collect();
            let tspace = TriangleColumns::new(&nb, f);
            // Enumerate triangle columns in reverse filtration order,
            // applying clearing on the fly (trivial-death skip is O(1)).
            let mut cols: Vec<u64> = Vec::new();
            let mut cleared = 0usize;
            for e in (0..ne as u32).rev() {
                let (a, b) = f.edges[e as usize];
                let tris = triangles_with_diameter(&nb, e, a, b);
                for &v in tris.iter().rev() {
                    let t = Key::new(e, v);
                    if space.smallest_tri[e as usize] == t {
                        cleared += 1; // death of a trivial H1 pair
                        continue;
                    }
                    if h1_deaths.contains(&t.pack()) {
                        cleared += 1;
                        continue;
                    }
                    cols.push(t.pack());
                }
            }
            stats.h2_cleared = cleared;
            let res2 = run_reduction(&tspace, &cols, opts, &pool, false, f);
            stats.h2_sched = res2.sched;
            for &(col, key) in &res2.pairs {
                let t = Key::unpack(col);
                diagram.push(2, f.key_value(t), f.key_value(key));
            }
            for &col in &res2.essential {
                let t = Key::unpack(col);
                diagram.push(2, f.key_value(t), f64::INFINITY);
            }
            stats.h2 = res2.stats;
            timings.stop();
        }
    }

    timings.stop();
    PhResult {
        diagram,
        stats,
        timings,
        h1_pairs,
        h1_essential_edges,
    }
}

fn run_reduction<S: crate::reduction::ColumnSpace>(
    space: &S,
    cols: &[u64],
    opts: &EngineOptions,
    pool: &Option<ThreadPool>,
    keep_zero_pairs: bool,
    f: &EdgeFiltration,
) -> ReduceResult {
    // Column birth value: for edges the id *is* the order; for triangles
    // the id is a packed key whose primary carries the value. Both cases
    // are covered by inspecting the id width: edge ids < 2^32.
    let value_of = |col: u64| -> f64 {
        if col <= u32::MAX as u64 {
            f.values[col as usize]
        } else {
            f.key_value(Key::unpack(col))
        }
    };
    let key_value = |k: Key| f.key_value(k);
    match (opts.algorithm, pool) {
        (Algorithm::ImplicitRow, _) => {
            implicit_row::reduce_all(space, cols.iter().copied(), keep_zero_pairs, value_of, key_value)
        }
        (Algorithm::FastColumn, None) => {
            fast_column::reduce_all(space, cols.iter().copied(), keep_zero_pairs, value_of, key_value)
        }
        (Algorithm::FastColumn, Some(pool)) => serial_parallel::reduce_all(
            space,
            cols,
            &opts.sched_config(),
            pool,
            keep_zero_pairs,
            value_of,
            key_value,
        ),
    }
}

/// Count simplices of the flag complex (Table 1's `N` column).
pub fn count_simplices(f: &EdgeFiltration, nb: &Neighborhoods, max_dim: usize) -> u64 {
    let mut total = f.n as u64 + f.n_edges() as u64;
    if max_dim >= 1 {
        // Triangles, grouped by diameter edge.
        let mut tris = 0u64;
        let mut tets = 0u64;
        for e in 0..f.n_edges() as u32 {
            let (a, b) = f.edges[e as usize];
            let vs = triangles_with_diameter(nb, e, a, b);
            tris += vs.len() as u64;
            if max_dim >= 2 {
                // Tetrahedra with diameter e: pairs (v, w) of case-1
                // vertices whose connecting edge is also < e.
                for i in 0..vs.len() {
                    for j in (i + 1)..vs.len() {
                        if let Some(o) = nb.edge_order(vs[i], vs[j]) {
                            if o < e {
                                tets += 1;
                            }
                        }
                    }
                }
            }
        }
        total += tris + tets;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointCloud;
    use crate::reduction::explicit::oracle_diagram;
    use crate::util::rng::Pcg32;

    fn random_cloud(n: usize, dim: usize, seed: u64) -> MetricData {
        let mut rng = Pcg32::new(seed);
        MetricData::Points(PointCloud::new(
            dim,
            (0..n * dim).map(|_| rng.next_f64()).collect(),
        ))
    }

    fn check_vs_oracle(data: &MetricData, tau: f64, opts: &EngineOptions, label: &str) {
        let f = EdgeFiltration::build(data, tau);
        let nb = Neighborhoods::build(&f, false);
        let got = compute_ph_from_filtration(&f, opts).diagram;
        let want = oracle_diagram(&f, &nb, opts.max_dim);
        assert!(
            got.multiset_eq(&want, 1e-9),
            "{label}:\n got: {}\nwant: {}",
            got.diff_summary(&want),
            want.diff_summary(&got),
        );
    }

    #[test]
    fn matches_oracle_on_random_clouds_dim1() {
        let opts = EngineOptions {
            max_dim: 1,
            ..Default::default()
        };
        for seed in 0..10 {
            let data = random_cloud(25, 2, seed);
            check_vs_oracle(&data, 0.5, &opts, &format!("dim1 seed={seed}"));
        }
    }

    #[test]
    fn matches_oracle_on_random_clouds_dim2() {
        let opts = EngineOptions::default();
        for seed in 0..10 {
            let data = random_cloud(18, 3, seed);
            check_vs_oracle(&data, 0.8, &opts, &format!("dim2 seed={seed}"));
        }
    }

    #[test]
    fn all_engine_configurations_agree() {
        let data = random_cloud(20, 3, 42);
        let f = EdgeFiltration::build(&data, 0.9);
        let reference = compute_ph_from_filtration(&f, &EngineOptions::default()).diagram;
        for algorithm in [Algorithm::FastColumn, Algorithm::ImplicitRow] {
            for threads in [1usize, 4] {
                for dense in [false, true] {
                    for (batch, adaptive) in [(1usize, false), (7, false), (100, false), (8, true)]
                    {
                        let opts = EngineOptions {
                            max_dim: 2,
                            threads,
                            batch_size: batch,
                            adaptive_batch: adaptive,
                            batch_min: 2,
                            dense_lookup: dense,
                            algorithm,
                            ..Default::default()
                        };
                        let got = compute_ph_from_filtration(&f, &opts).diagram;
                        assert!(
                            got.multiset_eq(&reference, 1e-9),
                            "algo={algorithm:?} threads={threads} dense={dense} batch={batch} adaptive={adaptive}:\n{}",
                            got.diff_summary(&reference)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn circle_loop_detected() {
        let mut coords = Vec::new();
        for i in 0..24 {
            let t = 2.0 * std::f64::consts::PI * i as f64 / 24.0;
            coords.push(t.cos());
            coords.push(t.sin());
        }
        let data = MetricData::Points(PointCloud::new(2, coords));
        let r = compute_ph(&data, 3.0, &EngineOptions::default());
        let sig = r.diagram.significant(1, 0.5);
        assert_eq!(sig.len(), 1, "one dominant loop: {:?}", r.diagram.points(1));
        assert_eq!(r.diagram.essential_count(0), 1);
    }

    #[test]
    fn sphere_void_detected() {
        // Fibonacci sphere sample: one dominant H2 class.
        let n = 60;
        let mut coords = Vec::new();
        let phi = std::f64::consts::PI * (3.0 - 5f64.sqrt());
        for i in 0..n {
            let y = 1.0 - 2.0 * (i as f64 + 0.5) / n as f64;
            let r = (1.0 - y * y).sqrt();
            let t = phi * i as f64;
            coords.push(r * t.cos());
            coords.push(y);
            coords.push(r * t.sin());
        }
        let data = MetricData::Points(PointCloud::new(3, coords));
        let r = compute_ph(&data, 2.5, &EngineOptions::default());
        let sig = r.diagram.significant(2, 0.5);
        assert_eq!(sig.len(), 1, "one dominant void: {:?}", r.diagram.points(2));
    }

    #[test]
    fn simplex_counts_match_binomials_on_full_filtration() {
        // Complete filtration on n points: C(n,k+1) simplices per dim.
        let data = random_cloud(10, 2, 5);
        let f = EdgeFiltration::build(&data, 10.0);
        let nb = Neighborhoods::build(&f, false);
        let n = 10u64;
        let expect = n + n * (n - 1) / 2 + n * (n - 1) * (n - 2) / 6
            + n * (n - 1) * (n - 2) * (n - 3) / 24;
        assert_eq!(count_simplices(&f, &nb, 2), expect);
    }
}
