//! H0 via union-find over the edge filtration.
//!
//! Processing edges in ascending filtration order, an edge is *negative*
//! when it merges two components (a dim-0 death at its value) and
//! *positive* otherwise (it creates a loop, becoming a column in the H1*
//! reduction). All vertices are born at 0, so the elder rule is moot for
//! VR point clouds. The negative-edge set is exactly the dim-0 clearing
//! set of Algorithm 3 ("if e is in a persistence pair in H0: continue").

use crate::filtration::EdgeFiltration;

pub struct H0Result {
    /// `negative[o]` — edge `o` killed a component.
    pub negative: Vec<bool>,
    /// Edge orders of the deaths, ascending (birth is always 0).
    pub death_edges: Vec<u32>,
    /// Number of connected components at τ_m (essential classes).
    pub essential: usize,
}

struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
}

impl UnionFind {
    fn new(n: u32) -> Self {
        Self {
            parent: (0..n).collect(),
            rank: vec![0; n as usize],
        }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        // Path halving.
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Returns true when a merge happened.
    fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        true
    }
}

/// Compute H0 pairs and the negative-edge clearing set.
pub fn compute(f: &EdgeFiltration) -> H0Result {
    let mut uf = UnionFind::new(f.n);
    let mut negative = vec![false; f.n_edges()];
    let mut death_edges = Vec::new();
    for (o, &(a, b)) in f.edges.iter().enumerate() {
        if uf.union(a, b) {
            negative[o] = true;
            death_edges.push(o as u32);
        }
    }
    let essential = f.n as usize - death_edges.len();
    H0Result {
        negative,
        death_edges,
        essential,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{MetricData, PointCloud};

    #[test]
    fn path_graph_merges_in_order() {
        let pc = PointCloud::new(1, vec![0.0, 1.0, 2.5, 4.5]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 10.0);
        let r = compute(&f);
        assert_eq!(r.death_edges.len(), 3);
        assert_eq!(r.essential, 1);
        // First three edges (the consecutive gaps) are the negative ones.
        assert!(r.negative[0] && r.negative[1] && r.negative[2]);
        assert!(!r.negative[3]);
    }

    #[test]
    fn disconnected_components_stay_essential() {
        let pc = PointCloud::new(1, vec![0.0, 0.5, 100.0, 100.5, 200.0]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 1.0);
        let r = compute(&f);
        assert_eq!(r.essential, 3);
        assert_eq!(r.death_edges.len(), 2);
    }

    #[test]
    fn triangle_last_edge_positive() {
        // Equilateral-ish triangle: two edges merge everything, third is
        // positive (creates the loop).
        let pc = PointCloud::new(2, vec![0.0, 0.0, 1.0, 0.0, 0.5, 0.9]);
        let f = EdgeFiltration::build(&MetricData::Points(pc), 3.0);
        let r = compute(&f);
        assert_eq!(r.death_edges.len(), 2);
        assert_eq!(r.essential, 1);
        assert!(!r.negative[2], "largest edge closes the triangle");
    }

    #[test]
    fn counts_match_oracle_on_random_clouds() {
        use crate::util::rng::Pcg32;
        for seed in 0..5 {
            let mut rng = Pcg32::new(seed);
            let coords: Vec<f64> = (0..20 * 2).map(|_| rng.next_f64()).collect();
            let f = EdgeFiltration::build(
                &MetricData::Points(PointCloud::new(2, coords)),
                0.3,
            );
            let nb = crate::filtration::Neighborhoods::build(&f, false);
            let r = compute(&f);
            let d = crate::reduction::explicit::oracle_diagram(&f, &nb, 0);
            assert_eq!(r.essential, d.essential_count(0), "seed={seed}");
            assert_eq!(r.death_edges.len(), d.finite(0).len(), "seed={seed}");
        }
    }
}
