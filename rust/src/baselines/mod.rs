//! Independent comparator implementations (paper §5, Tables 3 & 5).
//!
//! * [`ripser_like`] — the Ripser strategy: combinatorial simplex
//!   indexing over a dense distance matrix, heap-based implicit
//!   cohomology reduction with clearing. Overflow of the combinatorial
//!   index and the O(n²) matrix are *faithful* failure modes (Ripser
//!   crashed / was stopped on the Hi-C sets).
//! * [`gudhi_like`] — the Gudhi strategy: an explicit simplex tree of the
//!   whole filtration plus boundary-matrix reduction; memory O(#simplices)
//!   a priori (the Table 5 profile).
//!
//! Both double as *independent cross-checks* of the Dory engine: same
//! PDs, completely different code paths.

pub mod gudhi_like;
pub mod ripser_like;
