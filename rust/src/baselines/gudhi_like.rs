//! Gudhi-style baseline: explicit simplex tree + boundary reduction.
//!
//! Gudhi materializes the whole filtration in a simplex tree (Boissonnat &
//! Maria 2014) before reducing — `O(#simplices)` memory *a priori*, which
//! is the Table 5 profile (3 GB on torus4(1), 30 GB on torus4(2), NA on
//! dragon/fractal in the paper). We build a genuine node-based simplex
//! tree (children sorted by vertex, parent links) and then run the
//! standard column algorithm over the explicit boundary matrix.

use std::collections::HashMap;

use crate::filtration::{EdgeFiltration, Neighborhoods};
use crate::geometry::MetricData;
use crate::homology::diagram::Diagram;

/// A node of the simplex tree. The simplex it represents is the path of
/// vertex labels from the root; `filtration` is its VR filtration value.
#[derive(Debug)]
pub struct Node {
    pub vertex: u32,
    pub filtration: f64,
    pub parent: u32,
    /// Children indices into the arena, sorted by vertex label.
    pub children: Vec<u32>,
}

pub const ROOT: u32 = u32::MAX;

/// Arena-allocated simplex tree.
pub struct SimplexTree {
    pub nodes: Vec<Node>,
    /// Root children (dim-0 simplices), one per vertex.
    pub top: Vec<u32>,
    pub max_dim: usize,
}

impl SimplexTree {
    /// Build the flag complex of `f` up to simplices of dim `top_dim`.
    pub fn build(f: &EdgeFiltration, nb: &Neighborhoods, top_dim: usize) -> Self {
        let mut tree = SimplexTree {
            nodes: Vec::new(),
            top: Vec::new(),
            max_dim: top_dim,
        };
        // Dim 0.
        for v in 0..f.n {
            let id = tree.push(Node {
                vertex: v,
                filtration: 0.0,
                parent: ROOT,
                children: Vec::new(),
            });
            tree.top.push(id);
        }
        // Flag-complex expansion: recursively attach cofaces using sorted
        // upper neighbor lists (the simplex-tree expansion algorithm).
        for v in 0..f.n {
            let node = tree.top[v as usize];
            tree.expand(node, v, 0.0, 0, top_dim, nb, f);
        }
        tree
    }

    fn push(&mut self, n: Node) -> u32 {
        self.nodes.push(n);
        (self.nodes.len() - 1) as u32
    }

    /// Attach all simplices extending `node`'s simplex by upper neighbors
    /// common to every vertex of it. `last` is the max vertex on the path.
    fn expand(
        &mut self,
        node: u32,
        last: u32,
        filt: f64,
        dim: usize,
        top_dim: usize,
        nb: &Neighborhoods,
        f: &EdgeFiltration,
    ) {
        if dim >= top_dim {
            return;
        }
        // Candidate extensions: upper neighbors of `last` adjacent to all
        // vertices on the path (checked against the path via edge_order).
        let path = self.path_of(node);
        let (vtx, _ord) = nb.vn(last);
        let start = vtx.partition_point(|&x| x <= last);
        for &w in &vtx[start..] {
            let mut val = filt;
            let mut ok = true;
            for &u in &path {
                match nb.edge_order(u, w) {
                    Some(o) => val = val.max(f.values[o as usize]),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            let child = self.push(Node {
                vertex: w,
                filtration: val,
                parent: node,
                children: Vec::new(),
            });
            self.nodes[node as usize].children.push(child);
            self.expand(child, w, val, dim + 1, top_dim, nb, f);
        }
    }

    /// Vertices of the simplex represented by `node` (root -> node).
    pub fn path_of(&self, mut node: u32) -> Vec<u32> {
        let mut p = Vec::new();
        while node != ROOT {
            p.push(self.nodes[node as usize].vertex);
            node = self.nodes[node as usize].parent;
        }
        p.reverse();
        p
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Approximate heap use of the tree (Table 5's memory axis).
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<Node>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.capacity() * 4)
                .sum::<usize>()
    }
}

/// Full Gudhi-like computation: simplex tree + standard column reduction.
pub fn compute_ph(data: &MetricData, tau: f64, max_dim: usize) -> Diagram {
    let f = EdgeFiltration::build(data, tau);
    let nb = Neighborhoods::build(&f, false);
    compute_ph_from_filtration(&f, &nb, max_dim)
}

pub fn compute_ph_from_filtration(
    f: &EdgeFiltration,
    nb: &Neighborhoods,
    max_dim: usize,
) -> Diagram {
    let tree = SimplexTree::build(f, nb, max_dim + 1);
    // Order simplices: (filtration value, dim, vertices).
    let mut order: Vec<u32> = (0..tree.len() as u32).collect();
    let paths: Vec<Vec<u32>> = order.iter().map(|&i| tree.path_of(i)).collect();
    order.sort_by(|&x, &y| {
        let (nx, ny) = (&tree.nodes[x as usize], &tree.nodes[y as usize]);
        nx.filtration
            .partial_cmp(&ny.filtration)
            .unwrap()
            .then(paths[x as usize].len().cmp(&paths[y as usize].len()))
            .then(paths[x as usize].cmp(&paths[y as usize]))
    });
    let mut rank = vec![0usize; tree.len()];
    for (r, &i) in order.iter().enumerate() {
        rank[i as usize] = r;
    }
    // Boundary matrix in filtration order.
    let mut index: HashMap<&[u32], u32> = HashMap::new();
    for (i, p) in paths.iter().enumerate() {
        index.insert(p.as_slice(), i as u32);
    }
    let mut cols: Vec<Vec<usize>> = vec![Vec::new(); tree.len()];
    for (i, p) in paths.iter().enumerate() {
        if p.len() > 1 {
            let mut col = Vec::with_capacity(p.len());
            for omit in 0..p.len() {
                let mut face = p.clone();
                face.remove(omit);
                col.push(rank[index[face.as_slice()] as usize]);
            }
            col.sort_unstable();
            cols[rank[i]] = col;
        }
    }
    let low = crate::reduction::explicit::standard_column_algorithm(cols);
    // Convert pivots to a diagram.
    let mut diagram = Diagram::new(max_dim);
    let n = tree.len();
    let mut is_pivot_row = vec![false; n];
    for j in 0..n {
        if low[j] != usize::MAX {
            is_pivot_row[low[j]] = true;
            let i = low[j];
            let (si, sj) = (order[i] as usize, order[j] as usize);
            let d = paths[si].len() - 1;
            if d <= max_dim {
                diagram.push(d, tree.nodes[si].filtration, tree.nodes[sj].filtration);
            }
        }
    }
    for j in 0..n {
        if low[j] == usize::MAX && !is_pivot_row[j] {
            let sj = order[j] as usize;
            let d = paths[sj].len() - 1;
            if d <= max_dim {
                diagram.push(d, tree.nodes[sj].filtration, f64::INFINITY);
            }
        }
    }
    diagram
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    #[test]
    fn tree_counts_match_flag_complex() {
        let data = datasets::random_cloud(12, 2, 3);
        let f = EdgeFiltration::build(&data, 0.6);
        let nb = Neighborhoods::build(&f, false);
        let tree = SimplexTree::build(&f, &nb, 3);
        let expect = crate::homology::engine::count_simplices(&f, &nb, 2);
        assert_eq!(tree.len() as u64, expect);
    }

    #[test]
    fn matches_dory_on_random_clouds() {
        use crate::homology::{compute_ph as dory_ph, EngineOptions};
        for seed in 0..5 {
            let data = datasets::random_cloud(16, 3, seed);
            let want = dory_ph(&data, 0.8, &EngineOptions::default()).diagram;
            let got = compute_ph(&data, 0.8, 2);
            assert!(
                got.multiset_eq(&want, 1e-9),
                "seed={seed}:\n{}",
                got.diff_summary(&want)
            );
        }
    }

    #[test]
    fn circle_loop() {
        let data = datasets::circle(20, 1.0, 0.0, 1);
        let d = compute_ph(&data, 3.0, 1);
        assert_eq!(d.significant(1, 0.5).len(), 1);
    }

    #[test]
    fn memory_grows_with_simplices() {
        let small = {
            let data = datasets::random_cloud(10, 2, 1);
            let f = EdgeFiltration::build(&data, 0.4);
            let nb = Neighborhoods::build(&f, false);
            SimplexTree::build(&f, &nb, 3).memory_bytes()
        };
        let large = {
            let data = datasets::random_cloud(40, 2, 1);
            let f = EdgeFiltration::build(&data, 0.8);
            let nb = Neighborhoods::build(&f, false);
            SimplexTree::build(&f, &nb, 3).memory_bytes()
        };
        assert!(large > small * 4, "{small} vs {large}");
    }
}
